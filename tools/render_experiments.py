"""Regenerate EXPERIMENTS.md from dry-run artifacts, the benchmark-harness
JSONL (``results/bench/latest.jsonl``), and the perf log.

    PYTHONPATH=src python tools/render_experiments.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.core.report import (bench_summary, fmt_gb, fmt_s,  # noqa: E402
                               load_bench_records, md_table)

RDIR = REPO / "results" / "dryrun"
BENCH_JSONL = REPO / "results" / "bench" / "latest.jsonl"


def load(mesh: str):
    recs = []
    for f in sorted(RDIR.glob(f"*_{mesh}.json")):
        if "_nolicm" in f.name or "_opt" in f.name:
            continue
        recs.append(json.loads(f.read_text()))
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    return recs


def dryrun_table(recs):
    rows = []
    for r in recs:
        m = r.get("memory", {})
        h = r.get("hlo", {})
        rows.append([
            r["arch"], r["shape"], r["mesh"], r["exec_mode"],
            r["microbatches"], f"{r['compile_s']:.0f}s",
            fmt_gb(m.get("peak_gb")), fmt_gb(m.get("tpu_adjusted_peak_gb")),
            f"{h.get('flops_per_device', 0):.2e}",
            f"{h.get('bytes_per_device', 0):.2e}",
            f"{h.get('collective_ici_bytes', 0):.2e}",
            h.get("n_collectives", 0),
        ])
    return md_table(
        ["arch", "shape", "mesh", "mode", "mb", "compile",
         "peak GB", "TPU-adj GB", "FLOPs/dev", "bytes/dev",
         "ICI B/dev", "#coll"], rows)


def roofline_table(recs):
    rows = []
    for r in recs:
        rl = r.get("roofline", {})
        rows.append([
            r["arch"], r["shape"],
            fmt_s(rl.get("compute_s")), fmt_s(rl.get("memory_s")),
            fmt_s(rl.get("collective_s")), rl.get("dominant", "-"),
            f"{rl.get('model_flops', 0):.2e}",
            f"{(rl.get('useful_flops_ratio') or 0):.2f}",
            f"{(rl.get('mfu') or 0):.3f}",
        ])
    return md_table(
        ["arch", "shape", "compute", "memory", "collective", "dominant",
         "MODEL_FLOPS", "useful", "MFU"], rows)


def trace_tables(bench):
    """§Trace-replay: identity predicted-vs-measured per captured matrix
    cell (CI-gated, DESIGN.md §3) and the ungated cross-split what-if
    report (DESIGN.md §4)."""
    cells = [r for r in bench
             if r.group == "trace_replay" and "rel_err" in r.derived
             and not r.name.startswith("trace_replay/whatif_")]
    whatif = [r for r in bench
              if r.name.startswith("trace_replay/whatif_")]
    if not cells:
        return ("No trace-replay records in the bench JSONL; "
                "`python -m benchmarks.run --only trace_replay` "
                "regenerates them.\n")
    parts = []
    rows = []
    for r in sorted(cells, key=lambda r: r.name):
        d = r.derived
        measured = d.get("measured_us", d.get("busy_us", 0.0))
        rows.append([
            r.name.split("/", 1)[1], r.mesh or "-",
            f"{measured / 1e3:.2f}", f"{d['predicted_us'] / 1e3:.2f}",
            f"{d['rel_err']:.4f}", d.get("dominant", "-"),
            d.get("n_events", "-"),
        ])
    parts.append("Identity replay of each captured cell's DAG vs the "
                 "measurement it was decomposed from (gated at 25% by "
                 "`tools/ci_checks.py trace-replay-error`; DESIGN.md §3):\n")
    parts.append(md_table(
        ["cell", "split", "measured ms", "predicted ms", "rel_err",
         "dominant", "events"], rows))
    if whatif:
        rows = []
        for r in sorted(whatif, key=lambda r: r.name):
            d = r.derived
            rows.append([
                r.mesh, f"{d['measured_us'] / 1e3:.2f}",
                f"{d['predicted_us'] / 1e3:.2f}", f"{d['ratio']:.3f}",
                d.get("dominant", "-"),
            ])
        parts.append("\n\nCross-split what-if predictions from the 1x1 "
                     "trace alone — REPORTED, not gated: simulated-host "
                     "cells include shared-core contention no per-device "
                     "model represents (DESIGN.md §4):\n")
        parts.append(md_table(
            ["split", "measured ms", "predicted ms", "pred/meas",
             "dominant"], rows))
    return "".join(parts) + "\n"


def skips_table():
    from repro.configs import ARCHS
    rows = [[a.name, "long_500k",
             "full attention: O(S^2) + 500k KV cache exceeds v5e HBM"]
            for a in ARCHS.values() if not a.sub_quadratic]
    return md_table(["arch", "shape", "reason (DESIGN.md §4)"], rows)


HEADER = """# EXPERIMENTS

All compiled-artifact numbers come from `launch/dryrun.py`
(`jax.jit(...).lower().compile()` with 512 placeholder host devices) and the
`core/hlo_analysis.py` analyzer (while-loop trip counts expanded; see
DESIGN.md §2 for why XLA's own `cost_analysis` cannot be used directly).
Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI,
16 GB HBM/chip.

Caveats stated once:
* The memory term is an UPPER BOUND: XLA:CPU materializes bf16<->f32
  conversions a TPU would fuse (the `TPU-adj GB` column discounts the
  measurable f32 duplicates; byte traffic keeps them, so memory-bound
  verdicts are conservative).
* `useful` = MODEL_FLOPS / HLO_FLOPs (remat/attention overhead shows up
  here); `MFU` = MODEL_FLOPS / (chips x peak x max-term step time).
"""


def main():
    single = load("16x16")
    multi = load("2x16x16")
    parts = [HEADER]
    not_reproduced = (
        " **Not reproduced at this checkout**: dry-run artifacts "
        "(`results/dryrun/`) are not checked in; an empty table means "
        "`launch/dryrun.py --all` has not been run here, not that cells "
        "failed."
    )
    parts.append("\n## §Dry-run — single pod (16x16 = 256 chips)\n")
    parts.append(dryrun_table(single))
    parts.append(f"\n{len(single)}/32 runnable cells compiled."
                 + (not_reproduced if not single else "")
                 + " 8 `long_500k` cells are noted skips:\n")
    parts.append(skips_table())
    parts.append("\n\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    parts.append(dryrun_table(multi))
    parts.append(f"\n{len(multi)}/32 runnable cells compiled"
                 + ("." + not_reproduced if not multi else
                    " — the `pod` axis shards (batch over (pod, data); "
                    "verified by "
                    "tests/test_parallel.py::test_multi_pod_axis_shards).")
                 + "\n")
    parts.append("\n## §Roofline — single pod, per (arch x shape)\n")
    parts.append(roofline_table(single))
    bench = load_bench_records(BENCH_JSONL)
    if bench:
        parts.append("\n\n## §Benchmark harness — "
                     f"`python -m benchmarks.run` ({len(bench)} records)\n")
        parts.append(bench_summary(bench))
    parts.append("\n\n## §Trace-replay — predicted vs measured "
                 "(DAG replay cost model)\n")
    parts.append(trace_tables(bench))
    findings = REPO / "results" / "findings.md"
    if findings.exists():
        parts.append("\n\n" + findings.read_text())
    perf = REPO / "results" / "perf_log.md"
    if perf.exists():
        parts.append("\n\n" + perf.read_text())
    (REPO / "EXPERIMENTS.md").write_text("\n".join(parts) + "\n")
    print(f"wrote EXPERIMENTS.md ({len(single)} + {len(multi)} cells)")


if __name__ == "__main__":
    main()
