"""Committed, locally runnable CI assertion checks.

Each subcommand replays one of the structural checks the CI workflow
gates on, straight from the benchmark JSONL — so a red CI step reproduces
locally with one command instead of digging a heredoc out of the
workflow file:

    PYTHONPATH=src python tools/ci_checks.py serving-goodput
    PYTHONPATH=src python tools/ci_checks.py tuned-cache
    PYTHONPATH=src python tools/ci_checks.py scaling-efficiency
    PYTHONPATH=src python tools/ci_checks.py paged-parity
    PYTHONPATH=src python tools/ci_checks.py prefix-parity
    PYTHONPATH=src python tools/ci_checks.py chaos-parity
    PYTHONPATH=src python tools/ci_checks.py pd-parity
    PYTHONPATH=src python tools/ci_checks.py trace-replay-error
    PYTHONPATH=src python tools/ci_checks.py doc-refs
    PYTHONPATH=src python tools/ci_checks.py inject-slowdown --factor 2
    PYTHONPATH=src python tools/ci_checks.py regression-gate

``inject-slowdown`` rewrites the JSONL with every timing multiplied by
the factor; ``regression-gate`` is the whole CI gate loop in one
command (compare vs restored baselines, re-bless, then self-test that a
scratch-copy slowdown makes the compare exit exactly 3).
``paged-parity`` and ``prefix-parity`` are standalone (no JSONL):
``paged-parity`` builds a tiny monolithic and paged engine pair at
equal KV memory budget and asserts greedy token parity plus
strictly-more concurrent admissions on the paged side; ``prefix-parity``
does the same for the prefix-sharing radix cache (cache on vs off at
equal page budget: token parity on a shared-prompt burst and a
multi-turn replay, strictly-more admissions, warm TTFT < cold TTFT);
``chaos-parity`` runs a deadline/priority burst under the default
seeded fault plan and asserts every survivor is token-identical to the
fault-free run with zero leaked pages, then self-tests its own leak
detector by no-op'ing the engine's page-release seam;
``pd-parity`` runs the same tiny model through the interleaved paged
engine and the disaggregated P/D engine and asserts greedy token parity
on a mixed burst, one page handoff per request reaching decode, and a
strictly lower decode-step p95 stall under a chunked-prefill-heavy
staggered workload (doctored self-tests for both gates).

``trace-replay-error`` gates the trace→DAG→replay cost model: every
captured scaling-matrix cell's identity replay must land within
``--max-rel-err`` (default 25%) of the measurement it decomposed, and a
doctored prediction must make the gate trip (self-test).
``doc-refs`` is the documentation lint: ``FILE.md §N``-style references
must resolve to an existing file with that section heading, and CLI
flags named in README/EXPERIMENTS/DESIGN prose must be defined by some
``launch/*``/``benchmarks/run``/``tools`` argparse; a planted dangling
reference must fire (self-test).

Every check takes ``--jsonl`` (default ``results/bench/latest.jsonl``)
and exits 0/1; assertion messages name the offending record.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))  # for `import benchmarks.run` (gate)

DEFAULT_JSONL = REPO / "results" / "bench" / "latest.jsonl"
DEFAULT_BASELINES = REPO / "results" / "baselines"


def _records(jsonl: str):
    from repro.bench import read_jsonl

    path = Path(jsonl)
    if not path.exists():
        raise SystemExit(f"no bench records at {path}; run benchmarks.run")
    return read_jsonl(path)


def check_serving_goodput(args: argparse.Namespace) -> int:
    """Continuous batching must beat the static scheduler on the shared
    mixed-budget burst, and every serving record needs sane latencies."""
    recs = {r.name: r for r in _records(args.jsonl) if r.group == "serving"}
    for need in ("serving/sched_static", "serving/sched_continuous"):
        assert need in recs, f"missing record {need}"
    for r in recs.values():
        assert r.ttft_us > 0, f"{r.name}: missing ttft_us"
        assert r.p95_us >= r.p50_us > 0, f"{r.name}: bad percentiles"
    st = recs["serving/sched_static"].derived["goodput_rps"]
    ct = recs["serving/sched_continuous"].derived["goodput_rps"]
    assert ct > st, f"continuous goodput {ct} <= static {st}"
    print(f"serving-goodput: continuous {ct} > static {st} OK")
    return 0


def check_tuned_cache(args: argparse.Namespace) -> int:
    """The autotuner sweep must have persisted a winner that the kernel
    tuning lookup layer resolves for the swept rmsnorm shape."""
    import numpy as np

    from repro.kernels import tuning

    sig = tuning.rmsnorm_signature(args.rows, args.d, np.float32)
    cfg = tuning.lookup("rmsnorm_fwd", sig)
    assert cfg and "block_rows" in cfg, f"no tuned entry for {sig}"
    rows = tuning.resolve_rmsnorm_rows(
        None,
        rows=args.rows,
        d=args.d,
        dtype=np.float32,
    )
    assert rows == cfg["block_rows"], (rows, cfg)
    print(f"tuned-cache: {sig} -> {cfg} OK")
    return 0


def check_scaling_efficiency(args: argparse.Namespace) -> int:
    """Structural claims of the measured multi-device scaling matrix:

    * DP/TP/mixed records exist for the full device sweep with in-range
      efficiency/collective/balance metrics;
    * PP throughput follows the most-loaded-stage model within tolerance
      and ordering (Fig. 11c).
    """
    recs = {
        r.name: r
        for r in _records(args.jsonl)
        if r.group == "scaling_matrix" and r.status == "ok"
    }
    for n in (1, 2, 4, 8):
        assert f"scaling_matrix/dp{n}" in recs, f"missing dp{n} record"
    for n in (2, 4, 8):
        assert f"scaling_matrix/tp{n}" in recs, f"missing tp{n} record"
    for name, r in recs.items():
        d = r.derived
        if "efficiency" in d:
            assert 0 < d["efficiency"] <= args.max_efficiency, (
                f"{name}: efficiency {d['efficiency']} out of range"
            )
            assert 0 <= d["collective_frac"] < 1, name
            assert 0 <= d["shard_balance"] <= 1, name
    pp = sorted(
        (r for name, r in recs.items() if "/pp_" in name),
        key=lambda r: r.derived["max_stage"],
    )
    assert len(pp) >= 3, f"expected >=3 PP splits, got {len(pp)}"
    for r in pp:
        d = r.derived
        assert d["model_ok"], (
            f"{r.name}: measured/model ratio {d['model_ratio']} escapes "
            f"the most-loaded-stage tolerance band"
        )
    # most-loaded stage governs: a more loaded split must not beat a less
    # loaded one (10% slack absorbs wall-clock noise on shared runners;
    # the model_ratio band above is the primary gate)
    for a, b in zip(pp, pp[1:]):
        if a.derived["max_stage"] < b.derived["max_stage"]:
            assert a.derived["tok_s"] > 0.9 * b.derived["tok_s"], (
                f"{b.name} (max_stage {b.derived['max_stage']}) should be "
                f"slower than {a.name} ({a.derived['max_stage']})"
            )
    ratios = " ".join(
        f"pp[{r.derived['max_stage']}]={r.derived['model_ratio']}"
        for r in pp
    )
    print("scaling-efficiency:", ratios, "OK")
    return 0


def check_paged_parity(args: argparse.Namespace) -> int:
    """The paged-KV correctness gate, self-contained on a tiny model:

    * greedy outputs of the paged engine are token-identical to the
      monolithic continuous engine for every request — across mixed
      decode budgets AND mixed prompt lengths (chunked prefill included);
    * at equal KV memory budget (slots x span tokens on both sides) the
      paged engine admits strictly more concurrent requests on the
      mixed-budget burst.
    """
    from repro.data.pipeline import synth_requests
    from repro.launch.serve import build_engine
    from repro.serving import SimClock

    reduce_kw = dict(layers=2, d_model=64, vocab=128, d_ff=128)
    prompt, budget_max, slots, ps = 8, 24, 4, args.page_size
    span = prompt + budget_max
    cont, cfg = build_engine(
        "granite-3-8b",
        batch=slots,
        prompt_len=prompt,
        max_new_tokens=budget_max,
        scheduler="continuous",
        reduce_kw=reduce_kw,
        clock=SimClock(),
    )
    paged, _ = build_engine(
        "granite-3-8b",
        batch=2 * slots,
        prompt_len=prompt,
        max_new_tokens=budget_max,
        scheduler="paged",
        page_size=ps,
        num_pages=slots * span // ps,
        prefill_chunk_tokens=prompt // 2,
        reduce_kw=reduce_kw,
        clock=SimClock(),
    )
    # mixed budgets (burst) + a second wave with a shorter prompt, so
    # parity also covers chunked prefill ending on a partial chunk
    reqs = synth_requests(cfg, 8, prompt, max_new_tokens=(2, budget_max))
    short = synth_requests(cfg, 4, prompt - 3, max_new_tokens=5, seed=1)
    for r in short:
        r.rid += 100
    reqs = reqs + short
    rc = cont.run(reqs)
    rp = paged.run(reqs)
    toks_c = {m.rid: [int(t) for t in m.tokens] for m in rc.metrics}
    toks_p = {m.rid: [int(t) for t in m.tokens] for m in rp.metrics}
    assert rc.completed == rp.completed == len(reqs), (
        f"incomplete runs: continuous {rc.completed}, paged {rp.completed}"
    )
    for rid, want in toks_c.items():
        assert toks_p[rid] == want, (
            f"request {rid}: paged tokens {toks_p[rid]} != monolithic {want}"
        )
    assert rp.peak_concurrency > rc.peak_concurrency, (
        f"paged peak_concurrency {rp.peak_concurrency} <= monolithic "
        f"{rc.peak_concurrency} at equal KV budget ({slots * span} tokens)"
    )
    print(
        f"paged-parity: {len(reqs)} requests token-identical; "
        f"concurrency {rp.peak_concurrency} > {rc.peak_concurrency} "
        f"at {slots * span}-token budget OK"
    )
    return 0


def check_prefix_parity(args: argparse.Namespace) -> int:
    """The prefix-sharing correctness gate, standalone on a tiny model:

    * greedy outputs of the prefix-cached paged engine are
      token-identical to the cache-free paged engine on a
      shared-system-prompt burst plus a multi-turn session replay
      (covers read-only page attach, warm-suffix chunked prefill, AND
      the copy-on-write path when a whole prompt is cached);
    * at equal page budget the cached engine admits strictly more
      concurrent requests on the shared burst and reports
      prefill_tokens_saved > 0.
    """
    import numpy as np

    from repro.data.pipeline import synth_sessions
    from repro.launch.serve import build_engine
    from repro.serving import Request, SimClock

    reduce_kw = dict(layers=2, d_model=64, vocab=128, d_ff=128)
    ps, budget, lanes = args.page_size, 8, 8
    system_len, suffix_len, turns = 16, 8, 3
    span = 32 + turns * 16 + budget      # covers the longest replay turn
    engines = {}
    for pc in (False, True):
        engines[pc], cfg = build_engine(
            "granite-3-8b",
            batch=lanes,
            prompt_len=span - budget,
            max_new_tokens=budget,
            scheduler="paged",
            page_size=ps,
            num_pages=args.num_pages,
            prefill_chunk_tokens=2 * ps,
            prefix_cache=pc,
            reduce_kw=reduce_kw,
            clock=SimClock(),
        )
    # shared-system-prompt burst: one system prefix, distinct suffixes,
    # duplicated prompts included so the whole-prompt CoW path runs
    rng = np.random.default_rng(7)
    system = rng.integers(1, cfg.vocab_size, system_len).astype(np.int32)
    burst = []
    for i in range(8):
        sfx = rng.integers(1, cfg.vocab_size, suffix_len).astype(np.int32)
        burst.append(Request(rid=i, prompt=np.concatenate([system, sfx]),
                             max_new_tokens=budget))
    burst.append(Request(rid=8, prompt=burst[0].prompt.copy(),
                         max_new_tokens=budget, arrival_s=1.0))
    replay = synth_sessions(cfg, 2, turns, max_new_tokens=budget,
                            think_s=200.0, stagger_s=60.0, seed=3)
    for r in replay:
        r.rid += 1000
    reports = {}
    for label, reqs in (("burst", burst), ("replay", replay)):
        for pc in (False, True):
            rep = reports[label, pc] = engines[pc].run(list(reqs))
            assert rep.completed == len(reqs), (
                f"{label} cache={pc}: {rep.completed}/{len(reqs)} finished"
            )
        toks_off = {m.rid: [int(t) for t in m.tokens]
                    for m in reports[label, False].metrics}
        toks_on = {m.rid: [int(t) for t in m.tokens]
                   for m in reports[label, True].metrics}
        for rid, want in toks_off.items():
            assert toks_on[rid] == want, (
                f"{label} request {rid}: cached tokens {toks_on[rid]} "
                f"!= uncached {want}"
            )
    off, on = reports["burst", False], reports["burst", True]
    assert on.peak_concurrency > off.peak_concurrency, (
        f"cached peak_concurrency {on.peak_concurrency} <= uncached "
        f"{off.peak_concurrency} at equal {args.num_pages}-page budget"
    )
    assert on.prefill_tokens_saved > 0, "cache on but no prefill saved"
    warm = reports["replay", True].ttft_warm_samples_s()
    cold = reports["replay", True].ttft_cold_samples_s()
    assert warm and cold and max(warm) < min(cold), (
        f"replay warm TTFT {warm} not strictly below cold {cold}"
    )
    print(
        f"prefix-parity: {len(burst) + len(replay)} requests "
        f"token-identical; burst concurrency {on.peak_concurrency} > "
        f"{off.peak_concurrency} at {args.num_pages}-page budget, "
        f"saved {on.prefill_tokens_saved} prefill tokens; replay warm "
        f"TTFT {max(warm)}s < cold {min(cold)}s OK"
    )
    return 0


def check_chaos_parity(args: argparse.Namespace) -> int:
    """The fault-injection correctness gate, standalone on a tiny model:

    * a deadline/priority burst through the paged engine under the
      default seeded :class:`FaultPlan` must inject every scheduled
      fault, recover all of them, and leak zero pages;
    * every request that still completes under chaos is token-identical
      to the fault-free run (faults perturb scheduling and timing, never
      numerics — the chaos-parity contract);
    * self-test: with ``PagedEngine._release_pages`` no-op'd the leak
      detector MUST report leaked pages — proving the gate can actually
      trip, not just that this workload happens to be clean.
    """
    import numpy as np

    from repro.launch.serve import build_engine
    from repro.serving import FaultPlan, PagedEngine, Request, SimClock

    reduce_kw = dict(layers=2, d_model=64, vocab=128, d_ff=128)

    def make(num_pages):
        return build_engine(
            "granite-3-8b",
            batch=2,
            prompt_len=18,
            max_new_tokens=6,
            scheduler="paged",
            page_size=4,
            num_pages=num_pages,
            prefill_chunk_tokens=4,
            reduce_kw=reduce_kw,
            clock=SimClock(),
        )

    def workload(cfg, mixed_priority=True):
        rng = np.random.default_rng(11)
        return [
            Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 6 + 2 * (i % 3)
                                        ).astype(np.int32),
                    max_new_tokens=5 + (i % 2), arrival_s=0.5 * i,
                    deadline_s=500.0,
                    priority=2 if mixed_priority and i == 3 else 0)
            for i in range(5)
        ]

    eng, cfg = make(13)
    base = eng.run(workload(cfg))
    assert base.completed == len(base.metrics), (
        f"fault-free run incomplete: {base.completed}/{len(base.metrics)}"
    )
    want = {m.rid: [int(t) for t in m.tokens] for m in base.metrics}

    eng.fault_plan = FaultPlan.default(args.seed)
    chaos = eng.run(workload(cfg))
    s = chaos.summary()
    assert s["faults_injected"] > 0, "fault plan injected nothing"
    assert s["fault_recoveries"] == s["faults_injected"], (
        f"unrecovered faults: {s['fault_recoveries']}/{s['faults_injected']}"
    )
    survivors = [m for m in chaos.metrics if m.outcome == "completed"]
    assert survivors, "no request survived the default fault plan"
    for m in survivors:
        got = [int(t) for t in m.tokens]
        assert got == want[m.rid], (
            f"request {m.rid}: tokens under chaos {got} != fault-free "
            f"{want[m.rid]}"
        )
    assert s["pages_leaked"] == 0, (
        f"{s['pages_leaked']} pages leaked after the chaos run"
    )

    # self-test: break the one page-release seam; uniform priorities and
    # no fault plan so nothing requeues (a requeue would re-allocate a
    # never-freed rid and crash instead of leaking), and a pool sized so
    # the leaky run still completes — the leak metric is REQUIRED to trip
    leaky_eng, cfg2 = make(64)
    orig = PagedEngine._release_pages
    PagedEngine._release_pages = lambda self, alloc, rid: None
    try:
        leaky = leaky_eng.run(workload(cfg2, mixed_priority=False))
        leaked = leaky.pages_leaked
    finally:
        PagedEngine._release_pages = orig
    assert leaked > 0, (
        "self-test: page release no-op'd but the leak detector reported "
        "0 leaked pages — the gate cannot trip"
    )
    print(
        f"chaos-parity: {s['faults_injected']} faults injected+recovered, "
        f"{len(survivors)}/{len(want)} survivors token-identical, 0 pages "
        f"leaked; self-test leaked {leaked} pages when release was "
        f"disabled OK"
    )
    return 0


def _assert_pd_token_parity(toks_paged: dict, toks_disagg: dict) -> None:
    """Per-request greedy token parity between the interleaved and
    disaggregated runs; raises AssertionError naming the first
    divergence (extracted so the doctored self-test can call it)."""
    assert set(toks_paged) == set(toks_disagg), (
        f"rid sets differ: {sorted(toks_paged)} vs {sorted(toks_disagg)}"
    )
    for rid, want in sorted(toks_paged.items()):
        assert toks_disagg[rid] == want, (
            f"request {rid}: disaggregated tokens {toks_disagg[rid]} != "
            f"interleaved {want}"
        )


def _assert_stall_improvement(p95_disagg: float,
                              p95_interleaved: float) -> None:
    """Disaggregation must strictly reduce the decode-step p95 stall —
    the whole point of splitting the roles (extracted for the
    self-test)."""
    assert p95_disagg < p95_interleaved, (
        f"disaggregated decode-step p95 stall {p95_disagg}s is not "
        f"strictly below interleaved {p95_interleaved}s"
    )


def check_pd_parity(args: argparse.Namespace) -> int:
    """The P/D-disaggregation gate, standalone on a tiny model:

    * greedy outputs of the disaggregated engine (separate prefill and
      decode worker pools over one shared page pool) are token-identical
      to the interleaved paged engine for every request on a mixed
      burst — across mixed decode budgets AND mixed prompt lengths
      (chunked prefill included);
    * every request that reaches decode does so through exactly one
      PageHandoff transfer;
    * under a chunked-prefill-heavy staggered workload the decode-step
      p95 stall (time a decode lane with live requests spends waiting on
      the loop's prefill dispatches) is strictly lower disaggregated
      than interleaved — prefill interference actually left the decode
      path;
    * self-test: a doctored token stream MUST trip the parity check, and
      the interleaved stalls compared against themselves MUST trip the
      strict-improvement check — proving both gates can fire.
    """
    import numpy as np

    from repro.data.pipeline import synth_requests
    from repro.launch.serve import build_engine
    from repro.serving import Request, SimClock

    reduce_kw = dict(layers=2, d_model=64, vocab=128, d_ff=128)

    # -- token parity on the mixed burst ------------------------------
    prompt, budget_max, slots, ps = 8, 24, 4, args.page_size

    def make(scheduler, **kw):
        return build_engine(
            "granite-3-8b",
            batch=slots,
            prompt_len=prompt,
            max_new_tokens=budget_max,
            scheduler=scheduler,
            page_size=ps,
            prefill_chunk_tokens=prompt // 2,
            reduce_kw=reduce_kw,
            clock=SimClock(),
            **kw,
        )

    paged, cfg = make("paged")
    disagg, _ = make("disaggregated", prefill_workers=2, decode_workers=2)
    reqs = synth_requests(cfg, 8, prompt, max_new_tokens=(2, budget_max))
    short = synth_requests(cfg, 4, prompt - 3, max_new_tokens=5, seed=1)
    for r in short:
        r.rid += 100
    reqs = reqs + short
    rp = paged.run(reqs)
    rd = disagg.run(reqs)
    assert rp.completed == rd.completed == len(reqs), (
        f"incomplete runs: interleaved {rp.completed}, "
        f"disaggregated {rd.completed}"
    )
    toks_p = {m.rid: [int(t) for t in m.tokens] for m in rp.metrics}
    toks_d = {m.rid: [int(t) for t in m.tokens] for m in rd.metrics}
    _assert_pd_token_parity(toks_p, toks_d)
    assert rd.handoffs == len(reqs), (
        f"{rd.handoffs} handoffs for {len(reqs)} requests reaching "
        "decode — pages did not change roles exactly once per request"
    )

    # -- decode interference under a chunked-prefill-heavy stagger ----
    pl, budget, chunk = 16, 12, 4

    def make_hot(scheduler, **kw):
        return build_engine(
            "granite-3-8b",
            batch=2,
            prompt_len=pl,
            max_new_tokens=budget,
            scheduler=scheduler,
            page_size=4,
            prefill_chunk_tokens=chunk,
            reduce_kw=reduce_kw,
            clock=SimClock(),
            **kw,
        )

    inter, cfg2 = make_hot("paged")
    dis2, _ = make_hot("disaggregated")
    rng = np.random.default_rng(5)
    stagger = [
        Request(rid=i,
                prompt=rng.integers(1, cfg2.vocab_size, pl).astype(np.int32),
                max_new_tokens=budget, arrival_s=45.0 * i)
        for i in range(8)
    ]
    si = inter.run(list(stagger)).summary()
    sd = dis2.run(list(stagger)).summary()
    assert si.get("decode_stall_p95_s", 0.0) > 0, (
        "interleaved run recorded no positive decode-step stalls — the "
        "workload does not exercise prefill interference"
    )
    p95_i = si["decode_stall_p95_s"]
    p95_d = sd.get("decode_stall_p95_s", 0.0)
    _assert_stall_improvement(p95_d, p95_i)

    # -- self-tests: both gates must be able to trip ------------------
    doctored = {rid: list(t) for rid, t in toks_d.items()}
    victim = sorted(doctored)[0]
    doctored[victim][-1] ^= 1
    try:
        _assert_pd_token_parity(toks_p, doctored)
    except AssertionError:
        pass
    else:
        raise AssertionError(
            "self-test: a flipped token passed the parity check — "
            "pd-parity cannot trip"
        )
    try:
        _assert_stall_improvement(p95_i, p95_i)
    except AssertionError:
        pass
    else:
        raise AssertionError(
            "self-test: equal stall p95s passed the strict-improvement "
            "check — pd-parity cannot trip"
        )
    print(
        f"pd-parity: {len(reqs)} requests token-identical with "
        f"{rd.handoffs} handoffs; decode-step p95 stall "
        f"{p95_d:.1f}s (disaggregated) < {p95_i:.1f}s (interleaved); "
        "self-tests tripped OK"
    )
    return 0


def check_static_analysis(args: argparse.Namespace) -> int:
    """The static-analysis gate, self-testing like chaos-parity:

    * the repo itself must be clean under every ``repro.analysis`` layer
      (seam AST lint, kernel tile contracts, traced hot-path audit);
    * self-test 1: the planted-violation fixtures under
      ``tests/fixtures/analysis/`` MUST trip every RS rule — proving the
      lint can fire, not just that the tree happens to be clean;
    * self-test 2: one deliberately illegal tile config per kernel MUST
      be rejected by the contract checker (VMEM overflow on flash/rwkv/
      rmsnorm/paged), while the shipped DEFAULTS stay accepted.
    """
    from repro.analysis import __main__ as analysis_cli
    from repro.analysis import kernel_lint, seams

    layers = (
        ("seams", "kernels")
        if args.skip_graphs
        else ("seams", "kernels", "graphs")
    )
    findings = analysis_cli.run_layers(layers)
    assert not findings, "repo not clean:\n" + "\n".join(
        str(f) for f in findings
    )

    fixtures = REPO / "tests" / "fixtures" / "analysis"
    tripped = {f.rule for f in seams.scan_tree(fixtures)}
    expected = {"RS101", "RS102", "RS103", "RS104", "RS105"}
    missing = expected - tripped
    assert not missing, (
        f"self-test: planted fixtures under {fixtures} did not trip "
        f"{sorted(missing)} — the lint cannot fire"
    )

    illegal = [
        (
            "flash_attention_fwd",
            dict(B=1, Sq=2048, Sk=2048, Hq=32, Hkv=8, D=128, dtype="float32"),
            {"block_q": 2048, "block_k": 2048},
        ),
        (
            "wkv6_fwd",
            dict(B=1, T=2048, H=32, K=64, V=64, dtype="float32"),
            {"chunk": 1024},
        ),
        (
            "rmsnorm_fwd",
            dict(rows=65536, d=512, dtype="float32"),
            {"block_rows": 65536},
        ),
        (
            "paged_attention_fwd",
            dict(B=8, Hq=32, Hkv=8, D=128, P=512, ps=16, npag=512, dtype="float32"),
            {"pages_per_block": 512},
        ),
    ]
    for kernel, dims, cfg in illegal:
        bad = kernel_lint.check_config(kernel, dims, cfg, "tpu")
        assert bad, (
            f"self-test: illegal tile config {cfg} for {kernel} was "
            "accepted — the contract checker cannot trip"
        )
    defaults_bad = kernel_lint.check_defaults("tpu")
    assert not defaults_bad, "shipped DEFAULTS rejected: " + "; ".join(
        str(f) for f in defaults_bad
    )
    print(
        f"static-analysis: repo clean across {','.join(layers)}; "
        f"self-test tripped {sorted(tripped & expected)} on fixtures and "
        f"rejected {len(illegal)} illegal tile configs OK"
    )
    return 0


_TRACE_CELLS = (
    "trace_replay/dp1", "trace_replay/dp2", "trace_replay/dp4",
    "trace_replay/dp8", "trace_replay/tp2", "trace_replay/tp4",
    "trace_replay/tp8", "trace_replay/mix_4x2", "trace_replay/mix_2x4",
)


def _trace_cell_errors(recs, max_rel_err: float) -> dict:
    """name -> recomputed rel_err for every gated trace-replay record;
    raises AssertionError on a missing cell or an out-of-bound error.
    Recomputes from predicted_us/measured_us so a doctored prediction
    cannot hide behind a stale stored rel_err."""
    by_name = {r.name: r for r in recs if r.group == "trace_replay"}
    out = {}
    for name in _TRACE_CELLS + ("trace_replay/serve_paged",):
        assert name in by_name, f"missing record {name}"
        d = by_name[name].derived
        measured = float(d.get("measured_us", d.get("busy_us", 0.0)))
        predicted = float(d["predicted_us"])
        assert measured > 0, f"{name}: non-positive measured_us {measured}"
        rel = abs(predicted - measured) / measured
        assert rel <= max_rel_err, (
            f"{name}: replay predicted {predicted:.1f}us vs measured "
            f"{measured:.1f}us — rel_err {rel:.4f} > {max_rel_err}"
        )
        out[name] = rel
    return out


def check_trace_replay(args: argparse.Namespace) -> int:
    """The trace→DAG→replay prediction gate (DESIGN.md §3):

    * every captured scaling-matrix cell (dp1..8, tp2..8, 4x2, 2x4) and
      the serving dispatch trace must be present in the JSONL with an
      identity-replay prediction within ``--max-rel-err`` of the
      measurement the DAG was decomposed from — the bound on how much
      the lane decomposition is allowed to drift from what was measured;
    * cross-split what-if records (``trace_replay/whatif_*``) must exist
      but are REPORTED, not gated (simulated-host contention, see
      DESIGN.md §4) — the gate only insists they carry both numbers;
    * self-test: doctoring one cell's predicted_us by 2x the bound MUST
      trip the checker — proving the gate can fire.
    """
    import copy

    recs = _records(args.jsonl)
    errors = _trace_cell_errors(recs, args.max_rel_err)
    whatif = [r for r in recs if r.name.startswith("trace_replay/whatif_")]
    assert whatif, "no trace_replay/whatif_* records (cross-split report)"
    for r in whatif:
        assert "predicted_us" in r.derived and "measured_us" in r.derived, (
            f"{r.name}: what-if record lacks predicted/measured pair"
        )

    doctored = copy.deepcopy(recs)
    victim = next(r for r in doctored if r.name == _TRACE_CELLS[0])
    victim.derived["predicted_us"] = (
        float(victim.derived["measured_us"]) * (1.0 + 2.0 * args.max_rel_err)
    )
    try:
        _trace_cell_errors(doctored, args.max_rel_err)
    except AssertionError:
        pass
    else:
        raise AssertionError(
            "self-test: a doctored prediction passed the gate — "
            "trace-replay-error cannot trip"
        )
    worst = max(errors, key=lambda k: errors[k])
    print(
        f"trace-replay-error: {len(errors)} cells within "
        f"{args.max_rel_err:.0%} (worst {worst} at {errors[worst]:.4f}), "
        f"{len(whatif)} what-if rows reported; self-test tripped OK"
    )
    return 0


# ------------------------------------------------------------- doc-refs
_MD_EXCLUDE = {"ISSUE.md", "PAPER.md", "PAPERS.md", "SNIPPETS.md",
               "CHANGES.md"}
# files whose prose names CLI flags that must exist in some argparse
_FLAG_CHECKED = {"README.md", "EXPERIMENTS.md", "DESIGN.md", "findings.md"}
# flags documented but owned by other programs (XLA, pytest, pip, git)
_FLAG_ALLOW_PREFIXES = ("--xla",)
_FLAG_ALLOW = {"--check"}  # `ruff format --check` in the pre-push recipe
_SECTION_REF_RE = None  # compiled lazily (module import stays cheap)


def _doc_ref_findings(root: Path) -> list:
    """All dangling ``FILE.md §N`` references and undefined CLI flags
    under ``root``. Pure function of the tree so the self-test can run
    it over a planted fixture directory."""
    import re

    ref_re = re.compile(r"([A-Za-z0-9_\-./]+\.md)\s*§\s*(\d+)")
    flag_re = re.compile(r"(--[a-z][a-z0-9][a-z0-9-]*)")
    heading_re_tmpl = r"(?m)^#{{1,6}}[^\n]*§\s*{n}\b"

    md_files = [
        p for p in sorted(root.rglob("*.md"))
        if p.name not in _MD_EXCLUDE
        and not any(part.startswith(".") for part in p.relative_to(root).parts)
    ]

    # argparse-defined flags across every CLI the docs may reference
    defined = set()
    cli_sources = [
        *sorted((root / "src" / "repro" / "launch").glob("*.py")),
        *sorted((root / "src" / "repro" / "analysis").glob("__main__.py")),
        root / "benchmarks" / "run.py",
        root / "tools" / "ci_checks.py",
    ]
    arg_re = re.compile(r"add_argument\(\s*[\"'](--[A-Za-z0-9][A-Za-z0-9-]*)")
    for src in cli_sources:
        if not src.exists():
            continue
        text = src.read_text()
        for m in arg_re.finditer(text):
            defined.add(m.group(1))
            # BooleanOptionalAction also registers the --no- negation
            if "BooleanOptionalAction" in text[m.start():m.start() + 300]:
                defined.add("--no-" + m.group(1)[2:])

    findings = []
    for md in md_files:
        rel = md.relative_to(root)
        text = md.read_text()
        for m in ref_re.finditer(text):
            fname, sec = m.group(1), m.group(2)
            target = root / fname
            if not target.exists():
                target = md.parent / fname
            if not target.exists():
                findings.append(
                    f"{rel}: reference '{m.group(0)}' -> missing file "
                    f"{fname}"
                )
                continue
            if not re.search(heading_re_tmpl.format(n=sec),
                             target.read_text()):
                findings.append(
                    f"{rel}: reference '{m.group(0)}' -> {fname} has no "
                    f"'§{sec}' heading"
                )
        if md.name in _FLAG_CHECKED:
            for m in flag_re.finditer(text):
                flag = m.group(1)
                if flag in defined or flag in _FLAG_ALLOW:
                    continue
                if flag.startswith(_FLAG_ALLOW_PREFIXES):
                    continue
                findings.append(
                    f"{rel}: CLI flag '{flag}' is not defined by any "
                    "launch/*, benchmarks/run, repro.analysis, or "
                    "ci_checks argparse"
                )
    return findings


def check_doc_refs(args: argparse.Namespace) -> int:
    """The documentation-reference lint:

    * every ``FILE.md §N`` citation in tracked markdown must point at an
      existing file containing a ``§N`` heading (the DESIGN.md contract:
      EXPERIMENTS.md cites §2/§4 by number, so the numbers are API);
    * every ``--flag`` named in README/EXPERIMENTS/DESIGN/findings prose
      must be defined by an ``add_argument`` in ``launch/*``,
      ``benchmarks/run``, ``repro.analysis``, or ``tools/ci_checks``;
    * self-test: a planted fixture tree with a dangling §-reference and
      an undefined flag MUST produce findings — proving the lint fires.
    """
    import tempfile

    root = Path(args.root).resolve()
    findings = _doc_ref_findings(root)
    assert not findings, "dangling doc references:\n" + "\n".join(
        f"  {f}" for f in findings
    )

    with tempfile.TemporaryDirectory() as td:
        planted = Path(td)
        (planted / "DESIGN.md").write_text("## §1 Real section\n")
        (planted / "README.md").write_text(
            "See DESIGN.md §1, DESIGN.md §99, GHOST.md §2, and pass "
            "--definitely-not-a-flag to the CLI.\n"
        )
        tripped = _doc_ref_findings(planted)
    assert len(tripped) == 3, (
        f"self-test: planted fixtures produced {len(tripped)} findings "
        f"(wanted 3: missing section, missing file, undefined flag): "
        f"{tripped}"
    )
    n_md = len([p for p in root.rglob('*.md')
                if p.name not in _MD_EXCLUDE])
    print(
        f"doc-refs: {n_md} markdown files clean; self-test tripped "
        f"{len(tripped)} planted findings OK"
    )
    return 0


def _inject(jsonl: str, factor: float) -> int:
    from repro.bench import write_jsonl

    recs = _records(jsonl)
    for r in recs:
        r.us_per_call *= factor
        r.p50_us *= factor
        r.p95_us *= factor
        r.ttft_us *= factor
        r.samples_us = [s * factor for s in r.samples_us]
    write_jsonl(recs, Path(jsonl))
    return len(recs)


def inject_slowdown(args: argparse.Namespace) -> int:
    """Multiply every timing in the JSONL by --factor (default 2x) —
    the regression-gate self-test injects this to prove --compare trips."""
    n = _inject(args.jsonl, args.factor)
    print(f"inject-slowdown: {n} records slowed {args.factor}x")
    return 0


def regression_gate(args: argparse.Namespace) -> int:
    """The whole CI gate loop in one command: compare fresh records
    against the (restored) baselines, re-bless them, then inject a
    --factor slowdown into a SCRATCH copy and require the compare to
    exit with exactly 3 (run.py's reserved regression code — 1/2 would
    mean the gate itself is broken, not that it tripped)."""
    import shutil
    import tempfile

    import benchmarks.run as bench_run

    base = ["--json", args.jsonl, "--baseline-dir", args.baseline_dir]
    with tempfile.TemporaryDirectory() as td:
        # only the cross-commit compare lands a real trajectory point;
        # the bless and the self-test write to scratch so one gate run
        # never double-counts a commit in the uploaded history
        scratch_traj = ["--trajectory", str(Path(td) / "trajectory.jsonl")]
        real_traj = (
            ["--trajectory", args.trajectory] if args.trajectory else []
        )
        rc = bench_run.main(["--compare-only", *base, *real_traj])
        if rc == 3:
            print(
                "regression-gate: PERFORMANCE REGRESSION vs the restored "
                "baselines (see report above)",
                file=sys.stderr,
            )
            return 3
        assert rc == 0, f"compare against restored baselines exited {rc}"
        rc = bench_run.main(
            ["--compare-only", "--bless", *base, *scratch_traj]
        )
        assert rc == 0, f"bless exited {rc}"
        scratch = str(Path(td) / "slowdown.jsonl")
        shutil.copy(args.jsonl, scratch)
        _inject(scratch, args.factor)
        rc = bench_run.main([
            "--compare-only",
            "--json",
            scratch,
            *scratch_traj,
            "--baseline-dir",
            args.baseline_dir,
        ])
    assert rc == 3, (
        f"expected regression exit 3 on a {args.factor}x slowdown, got {rc}"
    )
    print(f"regression-gate: pass -> bless -> {args.factor}x -> exit 3 OK")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser(
        "serving-goodput",
        help="continuous-batching goodput must beat the static scheduler",
    )
    p.set_defaults(fn=check_serving_goodput)

    p = sub.add_parser(
        "tuned-cache",
        help="autotuner winners resolve through the lookup",
    )
    p.add_argument("--rows", type=int, default=4096)
    p.add_argument("--d", type=int, default=512)
    p.set_defaults(fn=check_tuned_cache)

    p = sub.add_parser(
        "scaling-efficiency",
        help="scaling-matrix records obey the most-loaded-stage model",
    )
    p.add_argument("--max-efficiency", type=float, default=4.0)
    p.set_defaults(fn=check_scaling_efficiency)

    p = sub.add_parser(
        "paged-parity",
        help="paged engine: token parity + admits-more at equal KV budget",
    )
    p.add_argument("--page-size", type=int, default=8)
    p.set_defaults(fn=check_paged_parity)

    p = sub.add_parser(
        "prefix-parity",
        help="prefix cache: token parity + admits-more + warm TTFT wins",
    )
    p.add_argument("--page-size", type=int, default=8)
    p.add_argument("--num-pages", type=int, default=16)
    p.set_defaults(fn=check_prefix_parity)

    p = sub.add_parser(
        "chaos-parity",
        help="fault injection: survivors token-identical + zero page leaks",
    )
    p.add_argument("--seed", type=int, default=0)
    p.set_defaults(fn=check_chaos_parity)

    p = sub.add_parser(
        "pd-parity",
        help="P/D disaggregation: token parity + lower decode p95 stall",
    )
    p.add_argument("--page-size", type=int, default=8)
    p.set_defaults(fn=check_pd_parity)

    p = sub.add_parser(
        "static-analysis",
        help="repo clean under repro.analysis + planted violations trip",
    )
    p.add_argument(
        "--skip-graphs",
        action="store_true",
        help="skip the traced hot-path audit (the slow layer)",
    )
    p.set_defaults(fn=check_static_analysis)

    p = sub.add_parser(
        "trace-replay-error",
        help="trace DAG identity replay within tolerance per matrix cell",
    )
    p.add_argument("--max-rel-err", type=float, default=0.25)
    p.set_defaults(fn=check_trace_replay)

    p = sub.add_parser(
        "doc-refs",
        help="markdown §-references and CLI flags must resolve",
    )
    p.add_argument("--root", default=str(REPO))
    p.set_defaults(fn=check_doc_refs)

    p = sub.add_parser(
        "inject-slowdown",
        help="multiply every recorded timing by --factor",
    )
    p.add_argument("--factor", type=float, default=2.0)
    p.set_defaults(fn=inject_slowdown)

    p = sub.add_parser(
        "regression-gate",
        help="compare vs baselines, re-bless, self-test the gate trips",
    )
    p.add_argument("--factor", type=float, default=2.0)
    p.add_argument("--baseline-dir", default=str(DEFAULT_BASELINES))
    p.add_argument("--trajectory", default=None)
    p.set_defaults(fn=regression_gate)

    for sp in sub.choices.values():
        sp.add_argument(
            "--jsonl",
            default=str(DEFAULT_JSONL),
            help="bench JSONL path",
        )

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except AssertionError as e:
        print(f"CHECK FAILED [{args.cmd}]: {e}", file=sys.stderr)
        return 1
    except SystemExit as e:  # _records: missing JSONL
        if isinstance(e.code, int):
            return e.code
        print(f"CHECK FAILED [{args.cmd}]: {e.code}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    raise SystemExit(main())
