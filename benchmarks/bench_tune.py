"""Kernel autotuning sweeps (`python -m benchmarks.run --tune`).

Each scenario sweeps the Pallas tiling configs of one kernel family over
its bench shapes via :mod:`repro.bench.tune`, persists the winners to
``results/tuned/<backend>.json``, and yields a tuned-vs-default
comparison as a first-class BenchRecord — so the speedup story lands in
``results/bench/latest.jsonl`` next to every other measurement.

Tagged ``tune``: excluded from normal runs (the sweep times many
configs), opt in with ``--tune``. After a tune, any pallas-backed run of
the same shape resolves its "auto" block sizes from the cache (see
``repro.kernels.tuning``).
"""
from __future__ import annotations

from repro.bench import BenchRecord, Workload, scenario

_TAGS = ("tune", "kernel", "kernels", "measured")

# One workload per swept shape; labels keyed to the shape signature.
_ATTN_SHAPES = [("B1_S512_H4_KV2_D64", dict(B=1, S=512, Hq=4, Hkv=2, D=64))]
_WKV_SHAPES = [("B1_T256_H2_K64", dict(B=1, T=256, H=2, K=64))]
_NORM_SHAPES = [("r4096_d512", dict(rows=4096, d=512)),
                ("r1024_d256", dict(rows=1024, d=256))]
_PAGED_SHAPES = [("B4_P64_ps16_H4_KV2_D64",
                  dict(B=4, P=64, ps=16, Hq=4, Hkv=2, D=64, npag=16))]


def _record(kind: str, label: str, res) -> BenchRecord:
    """Fold a TuneResult into a BenchRecord (tuned >= default by
    construction: the default config is always candidate 0)."""
    return BenchRecord(
        name=f"tune/{kind}/{label}", us_per_call=res.us,
        knobs=dict(res.config),
        derived={"tuned_us": float(res.us),
                 "default_us": float(res.default_us),
                 "speedup": float(res.speedup),
                 "signature": res.signature,
                 "n_candidates": res.n_candidates,
                 "rejected_vmem": res.rejected_vmem})


def _attn_inputs(spec):
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal(
        (spec["B"], spec["S"], spec["Hq"], spec["D"])), jnp.float32)
    k = jnp.asarray(rng.standard_normal(
        (spec["B"], spec["S"], spec["Hkv"], spec["D"])), jnp.float32)
    v = jnp.asarray(rng.standard_normal(
        (spec["B"], spec["S"], spec["Hkv"], spec["D"])), jnp.float32)
    return q, k, v


@scenario(
    "tune/attention", tags=_TAGS, paper_ref="guidance for perf opts",
    workloads=[Workload(label=lbl, knobs=dict(spec))
               for lbl, spec in _ATTN_SHAPES])
def tune_attention(wl: Workload):
    """Sweep flash-attention forward block_q/block_k; persist winner."""
    from repro.bench import tune

    q, k, v = _attn_inputs(wl.knobs)
    res = tune.tune_flash_attention(q, k, v, causal=True, iters=2,
                                    warmup=1)
    tune.save([res])
    yield _record("attention", wl.label, res)


@scenario(
    "tune/attention_bwd", tags=_TAGS, paper_ref="guidance for perf opts",
    workloads=[Workload(label=lbl, knobs=dict(spec))
               for lbl, spec in _ATTN_SHAPES])
def tune_attention_bwd(wl: Workload):
    """Sweep the dq/dkv backward kernels' block shapes; persist winner."""
    from repro.bench import tune

    q, k, v = _attn_inputs(wl.knobs)
    res = tune.tune_flash_attention_bwd(q, k, v, causal=True, iters=1,
                                        warmup=1)
    tune.save([res])
    yield _record("attention_bwd", wl.label, res)


@scenario(
    "tune/wkv6", tags=_TAGS + ("ssm",), paper_ref="guidance for perf opts",
    workloads=[Workload(label=lbl, knobs=dict(spec))
               for lbl, spec in _WKV_SHAPES])
def tune_wkv6(wl: Workload):
    """Sweep the wkv6 chunk size; persist winner."""
    import numpy as np
    import jax.numpy as jnp

    from repro.bench import tune

    spec = wl.knobs
    rng = np.random.default_rng(0)
    shape = (spec["B"], spec["T"], spec["H"], spec["K"])
    q = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    k = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    v = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    ld = jnp.asarray(-np.exp(rng.standard_normal(shape)), jnp.float32)
    res = tune.tune_wkv6(q, k, v, ld, iters=2, warmup=1)
    tune.save([res])
    yield _record("wkv6", wl.label, res)


@scenario(
    "tune/paged_attention", tags=_TAGS + ("serving",),
    paper_ref="guidance for perf opts",
    workloads=[Workload(label=lbl, knobs=dict(spec))
               for lbl, spec in _PAGED_SHAPES])
def tune_paged_attention(wl: Workload):
    """Sweep the paged decode-attention pages_per_block; persist winner."""
    import numpy as np
    import jax.numpy as jnp

    from repro.bench import tune

    s = wl.knobs
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal(
        (s["B"], 1, s["Hq"], s["D"])), jnp.float32)
    kp = jnp.asarray(rng.standard_normal(
        (s["P"], s["ps"], s["Hkv"], s["D"])), jnp.float32)
    vp = jnp.asarray(rng.standard_normal(
        (s["P"], s["ps"], s["Hkv"], s["D"])), jnp.float32)
    btab = jnp.asarray(rng.integers(1, s["P"], (s["B"], s["npag"])),
                       jnp.int32)
    lens = jnp.asarray(
        rng.integers(1, s["npag"] * s["ps"] + 1, s["B"]), jnp.int32)
    res = tune.tune_paged_attention(q, kp, vp, btab, lens, iters=2,
                                    warmup=1)
    tune.save([res])
    yield _record("paged_attention", wl.label, res)


@scenario(
    "tune/rmsnorm", tags=_TAGS, paper_ref="guidance for perf opts",
    workloads=[Workload(label=lbl, knobs=dict(spec))
               for lbl, spec in _NORM_SHAPES])
def tune_rmsnorm(wl: Workload):
    """Sweep rmsnorm block_rows; persist winner."""
    import numpy as np
    import jax.numpy as jnp

    from repro.bench import tune

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(
        (wl.knobs["rows"], wl.knobs["d"])), jnp.float32)
    sc = jnp.ones((wl.knobs["d"],), jnp.float32)
    res = tune.tune_rmsnorm(x, sc, iters=5, warmup=2)
    tune.save([res])
    yield _record("rmsnorm", wl.label, res)
