"""Paper Fig. 9: compute performance (TFLOPs) + memory interaction vs
model size. Measured on CPU for reduced blocks (wall-clock TFLOP/s) and
projected at full scale from the Tier-1 roofline terms in the dry-run
artifacts."""
from __future__ import annotations

import json
from pathlib import Path

from repro.bench import BenchRecord, Workload, scenario, timeit_us

RDIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


@scenario(
    "efficiency/measured", tags=("measured", "fig9"),
    paper_ref="Fig. 9 (measured, reduced)",
    workloads=[Workload(label=f"layers{L}", arch="granite-3-8b",
                        knobs={"num_layers": L})
               for L in (2, 4, 8)])
def efficiency_measured(wl: Workload):
    """Loss fwd+bwd TFLOP/s vs layer count on a reduced granite block."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.models import Runtime, build
    from repro.models.frontends import synth_batch

    L = wl.knobs["num_layers"]
    cfg = reduced(ARCHS[wl.arch], layers=L, d_model=256, d_ff=1024,
                  vocab=1024)
    model = build(cfg, Runtime(attention_backend="dense"), jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, 4, 128, kind="train")
    g = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    us = timeit_us(g, params, batch)
    flops = 6.0 * cfg.param_count() * 4 * 128
    yield BenchRecord(
        name=f"efficiency/{wl.label}/measured", us_per_call=us,
        derived={"gflops_s": round(flops / (us * 1e-6) / 1e9, 2)})


@scenario(
    "efficiency/projected", tags=("projected", "fig9"),
    paper_ref="Fig. 9 (full-scale projection)",
    workloads=[Workload(label="train_4k_16x16", mesh=None,
                        knobs={"glob": "*_train_4k_16x16.json"})])
def efficiency_projected(wl: Workload):
    """Full-scale roofline-step-time TFLOP/s per arch from the dry-run
    artifacts (when present)."""
    for f in sorted(RDIR.glob(wl.knobs["glob"])):
        rec = json.loads(f.read_text())
        rl = rec["roofline"]
        tf = rl["model_flops"] / max(rl["step_time_s"], 1e-12) / 1e12
        yield BenchRecord(
            name=f"efficiency/{rec['arch']}/projected",
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            derived={"tflops": round(tf, 1), "mfu": round(rl["mfu"], 3)})
