"""Paper Fig. 9: compute performance (TFLOPs) + memory interaction vs
model size. Measured on CPU for reduced blocks (wall-clock TFLOP/s) and
projected at full scale from the Tier-1 roofline terms."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.configs import ARCHS, MeshConfig, SHAPES, reduced
from repro.core.profiler import model_flops_for
from repro.models import build, Runtime
from repro.models.frontends import synth_batch


def run():
    rows = []
    # measured: loss fwd+bwd TFLOP/s vs layer count (reduced granite)
    for L in (2, 4, 8):
        cfg = reduced(ARCHS["granite-3-8b"], layers=L, d_model=256,
                      d_ff=1024, vocab=1024)
        model = build(cfg, Runtime(attention_backend="dense"), jnp.float32)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = synth_batch(cfg, 4, 128, kind="train")
        g = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
        us = timeit_us(g, params, batch)
        flops = 6.0 * cfg.param_count() * 4 * 128
        rows.append((f"efficiency/layers{L}/measured", us,
                     f"gflops_s={flops / (us * 1e-6) / 1e9:.2f}"))
    # projected full-scale: roofline-step-time TFLOP/s per arch (from the
    # dry-run artifacts when present)
    import json
    from pathlib import Path
    rdir = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    for f in sorted(rdir.glob("*_train_4k_16x16.json")):
        rec = json.loads(f.read_text())
        rl = rec["roofline"]
        tf = rl["model_flops"] / max(rl["step_time_s"], 1e-12) / 1e12
        rows.append((f"efficiency/{rec['arch']}/projected", 0.0,
                     f"tflops={tf:.1f};mfu={rl['mfu']:.3f}"))
    return rows
