"""Paper Fig. 8: load imbalance (LI, Eq. 3/4) vs layer count and hidden
size under O1/O3 partitioning, plus MoE expert-load LI measured on a real
routed forward pass (a dimension the paper's dense blocks don't have)."""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.configs import ARCHS, MeshConfig, ShapeConfig, reduced
from repro.core import metrics, sections


def run():
    rows = []
    mesh = MeshConfig()
    base = ARCHS["granite-3-8b"]
    shape = ShapeConfig("bench", "train", 1024, 64)
    for L in (6, 12, 24, 48):
        cfg = dataclasses.replace(base, num_layers=L)
        for m in ("O1", "O3"):
            rep = sections.analyze(cfg, shape, mesh, m)
            rows.append((f"load_balance/layers{L}/{m}", 0.0,
                         f"LI={rep.load_imbalance:.4f}"))
    for hs in (512, 1024, 2048, 4096):
        nq = max(4, hs // 128)
        cfg = dataclasses.replace(base, d_model=hs, d_ff=4 * hs,
                                  num_heads=nq, num_kv_heads=max(1, nq // 4),
                                  head_dim=128, num_layers=12)
        for m in ("O1", "O3"):
            rep = sections.analyze(cfg, shape, mesh, m)
            rows.append((f"load_balance/hs{hs}/{m}", 0.0,
                         f"LI={rep.load_imbalance:.4f}"))

    # measured MoE expert-load LI on a reduced arctic block
    cfg = reduced(ARCHS["arctic-480b"], experts=8)
    from repro.models import moe as moe_mod
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model)) * 0.1
    fn = jax.jit(lambda p, x: moe_mod.moe_ffn(p, x, cfg)[1]["expert_load"])
    us = timeit_us(fn, p, x)
    load = np.asarray(fn(p, x))
    li = metrics.expert_load_imbalance(load)
    rows.append(("load_balance/moe_experts/measured", us,
                 f"LI={li:.4f}"))
    return rows
