"""Paper Fig. 8: load imbalance (LI, Eq. 3/4) vs layer count and hidden
size under O1/O3 partitioning, plus MoE expert-load LI measured on a real
routed forward pass (a dimension the paper's dense blocks don't have)."""
from __future__ import annotations

import dataclasses

from repro.bench import (BENCH_MESH, BENCH_SHAPE, BenchRecord, Workload,
                         scenario, timeit_us)
from repro.configs import ARCHS, reduced

PARTITION_MODES = ("O1", "O3")


@scenario(
    "load_balance/layers", tags=("tier1", "structural", "fig8"),
    paper_ref="Fig. 8a",
    workloads=[Workload(label=f"layers{L}", arch="granite-3-8b",
                        shape=BENCH_SHAPE, mesh=BENCH_MESH,
                        knobs={"num_layers": L})
               for L in (6, 12, 24, 48)])
def load_balance_layers(wl: Workload):
    """LI vs layer count under O1/O3 partitioning."""
    from repro.core import sections

    cfg = dataclasses.replace(ARCHS[wl.arch],
                              num_layers=wl.knobs["num_layers"])
    for m in PARTITION_MODES:
        rep = sections.analyze(cfg, wl.shape, wl.mesh, m)
        yield BenchRecord(name=f"load_balance/{wl.label}/{m}",
                          knobs={"mode": m},
                          derived={"LI": round(rep.load_imbalance, 4)})


@scenario(
    "load_balance/hidden", tags=("tier1", "structural", "fig8"),
    paper_ref="Fig. 8b",
    workloads=[Workload(label=f"hs{hs}", arch="granite-3-8b",
                        shape=BENCH_SHAPE, mesh=BENCH_MESH,
                        knobs={"d_model": hs})
               for hs in (512, 1024, 2048, 4096)])
def load_balance_hidden(wl: Workload):
    """LI vs hidden size at fixed depth under O1/O3 partitioning."""
    from repro.core import sections

    hs = wl.knobs["d_model"]
    nq = max(4, hs // 128)
    cfg = dataclasses.replace(ARCHS[wl.arch], d_model=hs, d_ff=4 * hs,
                              num_heads=nq, num_kv_heads=max(1, nq // 4),
                              head_dim=128, num_layers=12)
    for m in PARTITION_MODES:
        rep = sections.analyze(cfg, wl.shape, wl.mesh, m)
        yield BenchRecord(name=f"load_balance/{wl.label}/{m}",
                          knobs={"mode": m},
                          derived={"LI": round(rep.load_imbalance, 4)})


@scenario(
    "load_balance/moe", tags=("tier1", "measured", "fig8", "moe"),
    paper_ref="Fig. 8 (MoE extension)",
    workloads=[Workload(label="experts8", arch="arctic-480b",
                        knobs={"experts": 8})])
def load_balance_moe(wl: Workload):
    """Expert-load LI measured on a real routed forward of a reduced
    arctic block."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import metrics
    from repro.models import moe as moe_mod

    cfg = reduced(ARCHS[wl.arch], experts=wl.knobs["experts"])
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model)) * 0.1
    fn = jax.jit(lambda p, x: moe_mod.moe_ffn(p, x, cfg)[1]["expert_load"])
    us = timeit_us(fn, p, x)
    load = np.asarray(fn(p, x))
    li = metrics.expert_load_imbalance(load)
    yield BenchRecord(name="load_balance/moe_experts/measured",
                      us_per_call=us, derived={"LI": round(float(li), 4)})
