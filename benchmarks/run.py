"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <substr>]

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

| module                 | paper artifact                          |
|------------------------|-----------------------------------------|
| bench_allocation       | Table I / Fig. 6-7 (allocation ratio)    |
| bench_load_balance     | Fig. 8 (load imbalance, Eq. 3/4)         |
| bench_efficiency       | Fig. 9 (TFLOPs vs model size)            |
| bench_roofline         | Fig. 10 (roofline models)                |
| bench_scalability      | Table III / Fig. 11 (DP/TP/PP, streaming)|
| bench_batch_precision  | Fig. 12 / Table IV (deployment knobs)    |
| bench_kernels          | kernel-level microbenchmarks             |
"""
from __future__ import annotations

import argparse
import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

MODULES = [
    "bench_allocation",
    "bench_load_balance",
    "bench_efficiency",
    "bench_roofline",
    "bench_scalability",
    "bench_batch_precision",
    "bench_kernels",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = []
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:
            traceback.print_exc()
            failures.append((mod_name, str(e)[:200]))
            print(f"{mod_name}/FAILED,0,{e!r}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
