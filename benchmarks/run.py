"""Benchmark harness entrypoint — every module registers scenarios with
:mod:`repro.bench`; one shared runner times, stamps, and sinks them.

    PYTHONPATH=src python -m benchmarks.run [--only <substr>]
                                            [--tags tag1,tag2]
                                            [--tune]
                                            [--json <path> | --no-json]
                                            [--list]

Prints the legacy ``name,us_per_call,derived`` CSV (one line per
measurement) on stdout and writes machine-readable BenchRecord JSONL
(default ``results/bench/latest.jsonl``). Exits non-zero if any module
fails to import or any scenario workload raises.

| module                 | scenario groups   | paper artifact            |
|------------------------|-------------------|---------------------------|
| bench_allocation       | allocation        | Table I / Fig. 6-7        |
| bench_load_balance     | load_balance      | Fig. 8 (LI, Eq. 3/4)      |
| bench_efficiency       | efficiency        | Fig. 9 (TFLOPs vs size)   |
| bench_roofline         | roofline          | Fig. 10 (roofline models) |
| bench_scalability      | scalability       | Table III / Fig. 11       |
| bench_batch_precision  | deploy            | Fig. 12 / Table IV        |
| bench_kernels          | kernels           | kernel microbenchmarks    |
| bench_serving          | serving           | Tier-2 serving latency    |
| bench_tune             | tune              | kernel autotuning sweeps  |

Scenarios tagged ``tune`` (the autotuning sweeps writing
``results/tuned/``) only run with ``--tune``; a bare ``--tune`` runs just
them, combined with ``--only``/``--tags`` it widens the selection.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

DEFAULT_JSONL = REPO / "results" / "bench" / "latest.jsonl"

# module -> scenario groups it registers. Every module is always imported
# (imports are cheap; heavy deps load inside scenario fns) — this map only
# scopes which import *failures* an --only run reports and fails on, and
# resolves module-name --only filters like `bench_kernels`.
MODULES = {
    "bench_allocation": ("allocation",),
    "bench_load_balance": ("load_balance",),
    "bench_efficiency": ("efficiency",),
    "bench_roofline": ("roofline",),
    "bench_scalability": ("scalability",),
    "bench_batch_precision": ("deploy",),
    "bench_kernels": ("kernels",),
    "bench_serving": ("serving",),
    "bench_tune": ("tune",),
}


def import_benchmarks():
    """Import every bench module (side effect: scenario registration).
    Returns (module_names_imported, import_failures); each failure is
    (module, short_error, full_traceback) — the caller decides which
    tracebacks to surface, so `--only` runs stay quiet about unrelated
    breakage."""
    imported, failures = [], []
    for mod_name in MODULES:
        try:
            importlib.import_module(f"benchmarks.{mod_name}")
            imported.append(mod_name)
        except Exception as e:
            failures.append((mod_name, str(e)[:200],
                             traceback.format_exc()))
    return imported, failures


def _module_matches(only: str, mod_name: str) -> bool:
    """Whether an ``--only`` substring targets a module (either the module
    file name or one of its scenario groups, in either direction — so
    `bench_kernels`, `alloc`, and `allocation/hidden` all resolve)."""
    return only in mod_name or \
        any(only in g or g in only for g in MODULES[mod_name])


def main(argv: list[str] | None = None) -> int:
    from repro.bench import BenchRunner, CsvStdoutSink, JsonlSink, select

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--only", default=None,
                    help="substring filter on module/scenario name")
    ap.add_argument("--tags", default=None,
                    help="comma-separated tag filter (any-of)")
    ap.add_argument("--tune", action="store_true",
                    help="run the kernel autotuning sweeps (scenarios "
                         "tagged `tune`, excluded from normal runs); "
                         "winners persist to results/tuned/")
    ap.add_argument("--json", default=str(DEFAULT_JSONL), metavar="PATH",
                    help="BenchRecord JSONL output path "
                         f"(default: {DEFAULT_JSONL})")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the JSONL sink")
    ap.add_argument("--list", action="store_true",
                    help="list matching scenarios and exit")
    args = ap.parse_args(argv)
    tags = [t for t in (args.tags or "").split(",") if t] or None

    imported, import_failures = import_benchmarks()
    # a filtered run only fails on import errors in modules it targets
    if args.only:
        import_failures = [f for f in import_failures
                           if _module_matches(args.only, f[0])]
    for _, _, tb in import_failures:
        print(tb, file=sys.stderr)
    import_failures = [(m, e) for m, e, _ in import_failures]

    # select by scenario name/group substring, falling back to the module
    # file name (`--only bench_kernels` keeps its pre-harness meaning)
    mod_groups = {g for m in MODULES
                  if args.only and args.only in m for g in MODULES[m]}
    selected = [s for s in select(tags=tags)
                if not args.only or args.only in s.name
                or args.only in s.group or s.group in mod_groups]

    # tune sweeps are opt-in: excluded unless --tune; a bare --tune (no
    # other filter) runs only them
    if not args.tune:
        selected = [s for s in selected if "tune" not in s.tags]
    elif not args.only and not tags:
        selected = [s for s in selected if "tune" in s.tags]

    if args.list:
        for scen in selected:
            print(f"{scen.name:32s} tags={','.join(scen.tags):40s} "
                  f"[{scen.paper_ref}]")
        return 0

    if not selected:
        print("no scenarios matched", file=sys.stderr)
        return 1

    sinks = [CsvStdoutSink()]
    if not args.no_json:
        try:
            jsonl = JsonlSink(args.json)
        except OSError as e:
            print(f"cannot write --json {args.json}: {e}", file=sys.stderr)
            return 2
        # filtered run into an existing result set: carry over records
        # from scenarios outside the filter so the JSONL stays the
        # latest-known record per scenario, not just the last invocation
        if args.only or tags:
            from repro.bench import read_jsonl

            sel_names = {s.name for s in selected}
            try:
                prior = read_jsonl(args.json) \
                    if Path(args.json).exists() else []
            except Exception:
                prior = []
            for rec in prior:
                if rec.scenario not in sel_names:
                    jsonl.emit(rec)
        sinks.append(jsonl)
    summary = BenchRunner(sinks=sinks).run(selected)

    for mod_name, err in import_failures:
        print(f"{mod_name}/IMPORT_FAILED,0.0,error={err}", flush=True)
    failures = import_failures + summary.failures
    if failures:
        print(f"\n{len(failures)} failure(s):", file=sys.stderr)
        for name, err in failures:
            print(f"  {name}: {err}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
