"""Benchmark harness entrypoint — every module registers scenarios with
:mod:`repro.bench`; one shared runner times, stamps, and sinks them.

    PYTHONPATH=src python -m benchmarks.run [--only <name-or-substr>[,..]]
                                            [--tags tag1,tag2]
                                            [--tune]
                                            [--compare [--bless]]
                                            [--compare-only]
                                            [--json <path> | --no-json]
                                            [--list]

Prints the legacy ``name,us_per_call,derived`` CSV (one line per
measurement) on stdout and writes machine-readable BenchRecord JSONL
(default ``results/bench/latest.jsonl``). Exits non-zero if any module
fails to import or any scenario workload raises. ``--only`` takes a
comma-separated list; a term that exactly names a registered scenario
selects just that scenario (CI retries rerun one flaky scenario, not its
group), anything else is the historical substring filter.

``--compare`` diffs the resulting records against the blessed baselines
under ``results/baselines/`` (noise-aware: p50 ratio + a sign test over
per-iteration samples, see ``repro.bench.compare``), appends a point to
``results/trajectory.jsonl``, and exits 3 on regression. ``--bless``
accepts the fresh records as the new baselines. ``--compare-only`` skips
running scenarios and compares the existing ``--json`` file as-is.

| module                 | scenario groups   | paper artifact            |
|------------------------|-------------------|---------------------------|
| bench_allocation       | allocation        | Table I / Fig. 6-7        |
| bench_load_balance     | load_balance      | Fig. 8 (LI, Eq. 3/4)      |
| bench_efficiency       | efficiency        | Fig. 9 (TFLOPs vs size)   |
| bench_roofline         | roofline          | Fig. 10 (roofline models) |
| bench_scalability      | scalability       | Table III / Fig. 11       |
| bench_scaling_matrix   | scaling_matrix    | Fig. 11 (measured matrix) |
| bench_trace            | trace_replay      | Sec. V (trace -> predict) |
| bench_batch_precision  | deploy            | Fig. 12 / Table IV        |
| bench_kernels          | kernels           | kernel microbenchmarks    |
| bench_serving          | serving           | Tier-2 serving latency    |
| bench_tune             | tune              | kernel autotuning sweeps  |

Scenarios tagged ``tune`` (the autotuning sweeps writing
``results/tuned/``) only run with ``--tune``; a bare ``--tune`` runs just
them, combined with ``--only``/``--tags`` it widens the selection.
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

DEFAULT_JSONL = REPO / "results" / "bench" / "latest.jsonl"

# module -> scenario groups it registers. Every module is always imported
# (imports are cheap; heavy deps load inside scenario fns) — this map only
# scopes which import *failures* an --only run reports and fails on, and
# resolves module-name --only filters like `bench_kernels`.
MODULES = {
    "bench_allocation": ("allocation",),
    "bench_load_balance": ("load_balance",),
    "bench_efficiency": ("efficiency",),
    "bench_roofline": ("roofline",),
    "bench_scalability": ("scalability",),
    "bench_scaling_matrix": ("scaling_matrix",),
    "bench_trace": ("trace_replay",),
    "bench_batch_precision": ("deploy",),
    "bench_kernels": ("kernels",),
    "bench_serving": ("serving",),
    "bench_tune": ("tune",),
}


def import_benchmarks():
    """Import every bench module (side effect: scenario registration).
    Returns (module_names_imported, import_failures); each failure is
    (module, short_error, full_traceback) — the caller decides which
    tracebacks to surface, so `--only` runs stay quiet about unrelated
    breakage."""
    imported, failures = [], []
    for mod_name in MODULES:
        try:
            importlib.import_module(f"benchmarks.{mod_name}")
            imported.append(mod_name)
        except Exception as e:
            failures.append((mod_name, str(e)[:200],
                             traceback.format_exc()))
    return imported, failures


def _only_terms(only: str | None) -> list[str]:
    return [t for t in (only or "").split(",") if t]


def _module_matches(only: str, mod_name: str) -> bool:
    """Whether an ``--only`` filter targets a module (either the module
    file name or one of its scenario groups, in either direction — so
    `bench_kernels`, `alloc`, and `allocation/hidden` all resolve)."""
    return any(
        t in mod_name or any(t in g or g in t for g in MODULES[mod_name])
        for t in _only_terms(only))


def _git_sha() -> str:
    """Best-effort short commit id for trajectory points."""
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
    except Exception:
        return ""


def run_compare(records, baseline_dir: str, trajectory: str,
                do_bless: bool) -> bool:
    """Diff ``records`` against blessed baselines; append a trajectory
    point; optionally bless. Returns True when the gate passes (no
    regression, or --bless accepted the new numbers). Report on stderr —
    stdout stays the legacy CSV stream."""
    from repro.bench import (append_trajectory, bless, compare_records,
                             load_baselines)
    from repro.bench.baseline import record_backend
    from repro.bench.compare import CompareReport

    # compare each record against the baselines of ITS backend — names
    # repeat across backends, so one flat name-keyed dict would let one
    # backend's baselines shadow (and silently skip) another's
    bdir = Path(baseline_dir)
    by_backend = {}
    for rec in records:
        by_backend.setdefault(record_backend(rec), []).append(rec)
    report = CompareReport()
    any_baselines = False
    for backend in sorted(by_backend):
        baselines = load_baselines(bdir, backend)
        any_baselines = any_baselines or bool(baselines)
        sub = compare_records(by_backend[backend], baselines)
        report.results.extend(sub.results)
    # fingerprint skips are by design (a foreign host's baselines must
    # never fail a run), but a gate that compared NOTHING while baselines
    # exist is a silent no-op — say so loudly
    skipped = len(report.by_status("skipped"))
    if any_baselines and skipped and not report.trajectory_point()["compared"]:
        print("WARNING: 0 comparable record pairs — baselines exist but "
              f"{skipped} pairs were skipped (env fingerprint mismatch?); "
              "the regression gate was a no-op this run", file=sys.stderr)
    print("", file=sys.stderr)
    for line in report.lines():
        print(line, file=sys.stderr)
    append_trajectory(
        report.trajectory_point(
            extra={"blessed": do_bless, "git": _git_sha()}),
        Path(trajectory))
    if do_bless:
        written = bless(records, bdir)
        for backend, path in written.items():
            print(f"blessed baselines [{backend}] -> {path}",
                  file=sys.stderr)
        return True
    if not report.ok:
        names = ", ".join(r.name for r in report.regressions)
        print(f"PERFORMANCE REGRESSION: {names}\n"
              f"(re-bless intended slowdowns with --compare --bless)",
              file=sys.stderr)
    return report.ok


def main(argv: list[str] | None = None) -> int:
    from repro.bench import (BenchRunner, CsvStdoutSink, JsonlSink,
                             only_matches, read_jsonl, select)

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--only", default=None,
                    help="comma-separated scenario filter: exact scenario "
                         "name > substring on module/scenario/group name")
    ap.add_argument("--tags", default=None,
                    help="comma-separated tag filter (any-of)")
    ap.add_argument("--tune", action="store_true",
                    help="run the kernel autotuning sweeps (scenarios "
                         "tagged `tune`, excluded from normal runs); "
                         "winners persist to results/tuned/")
    ap.add_argument("--compare", action="store_true",
                    help="diff resulting records against blessed "
                         "baselines; exit 3 on regression")
    ap.add_argument("--compare-only", action="store_true",
                    help="skip running scenarios; compare the existing "
                         "--json records against baselines")
    ap.add_argument("--bless", action="store_true",
                    help="with --compare/--compare-only: accept the "
                         "fresh records as the new blessed baselines")
    ap.add_argument("--baseline-dir", metavar="DIR",
                    default=os.environ.get(
                        "REPRO_BASELINE_DIR",
                        str(REPO / "results" / "baselines")),
                    help="blessed-baseline directory "
                         "(default: results/baselines; env "
                         "REPRO_BASELINE_DIR overrides)")
    ap.add_argument("--trajectory", metavar="PATH",
                    default=str(REPO / "results" / "trajectory.jsonl"),
                    help="trajectory JSONL appended on every compare "
                         "(default: results/trajectory.jsonl)")
    ap.add_argument("--json", default=str(DEFAULT_JSONL), metavar="PATH",
                    help="BenchRecord JSONL output path "
                         f"(default: {DEFAULT_JSONL})")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the JSONL sink")
    ap.add_argument("--list", action="store_true",
                    help="list matching scenarios and exit")
    args = ap.parse_args(argv)
    tags = [t for t in (args.tags or "").split(",") if t] or None

    if args.compare_only:
        if not Path(args.json).exists():
            print(f"--compare-only: no records at {args.json}",
                  file=sys.stderr)
            return 2
        ok = run_compare(read_jsonl(args.json), args.baseline_dir,
                         args.trajectory, args.bless)
        return 0 if ok else 3

    imported, import_failures = import_benchmarks()
    # a filtered run only fails on import errors in modules it targets
    if args.only:
        import_failures = [f for f in import_failures
                           if _module_matches(args.only, f[0])]
    for _, _, tb in import_failures:
        print(tb, file=sys.stderr)
    import_failures = [(m, e) for m, e, _ in import_failures]

    # select per --only term: exact scenario name > name/group substring
    # (repro.bench.scenario.only_matches), falling back to the module
    # file name (`--only bench_kernels` keeps its pre-harness meaning)
    terms = _only_terms(args.only)
    mod_groups = {g for m in MODULES for t in terms
                  if t in m for g in MODULES[m]}
    selected = [s for s in select(tags=tags)
                if not terms
                or any(only_matches(t, s) for t in terms)
                or s.group in mod_groups]

    # tune sweeps are opt-in: excluded unless --tune; a bare --tune (no
    # other filter) runs only them
    if not args.tune:
        selected = [s for s in selected if "tune" not in s.tags]
    elif not args.only and not tags:
        selected = [s for s in selected if "tune" in s.tags]

    if args.list:
        for scen in selected:
            print(f"{scen.name:32s} tags={','.join(scen.tags):40s} "
                  f"[{scen.paper_ref}]")
        return 0

    if not selected:
        print("no scenarios matched", file=sys.stderr)
        return 1

    sinks = [CsvStdoutSink()]
    if not args.no_json:
        try:
            jsonl = JsonlSink(args.json)
        except OSError as e:
            print(f"cannot write --json {args.json}: {e}", file=sys.stderr)
            return 2
        # filtered run into an existing result set: carry over records
        # from scenarios outside the filter so the JSONL stays the
        # latest-known record per scenario, not just the last invocation
        if args.only or tags:
            from repro.bench import read_jsonl

            sel_names = {s.name for s in selected}
            try:
                prior = read_jsonl(args.json) \
                    if Path(args.json).exists() else []
            except Exception:
                prior = []
            for rec in prior:
                if rec.scenario not in sel_names:
                    jsonl.emit(rec)
        sinks.append(jsonl)
    summary = BenchRunner(sinks=sinks).run(selected)

    for mod_name, err in import_failures:
        print(f"{mod_name}/IMPORT_FAILED,0.0,error={err}", flush=True)
    failures = import_failures + summary.failures
    if failures:
        print(f"\n{len(failures)} failure(s):", file=sys.stderr)
        for name, err in failures:
            print(f"  {name}: {err}", file=sys.stderr)
        return 1
    if args.compare or args.bless:
        # compare the full latest-known record set (the JSONL carries
        # over records outside a filtered run), not just this invocation
        records = read_jsonl(args.json) if not args.no_json \
            else summary.records
        if not run_compare(records, args.baseline_dir, args.trajectory,
                           args.bless):
            return 3
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
