"""Paper Fig. 10: roofline models. Emits arithmetic intensity (Eq. 5) and
the three roofline terms for every dry-run cell; classifies each as
compute-/memory-/collective-bound (the paper's WSE vs RDU/IPU split)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES
from repro.core import metrics

RDIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run():
    rows = []
    for f in sorted(RDIR.glob("*_16x16.json")):
        rec = json.loads(f.read_text())
        rl = rec.get("roofline")
        if not rl:
            continue
        arch = ARCHS.get(rec["arch"])
        shape = SHAPES.get(rec["shape"])
        ai = 0.0
        if arch and shape:
            act = metrics.activation_bytes_estimate(
                arch.num_layers + arch.encoder_layers, shape.global_batch,
                shape.seq_len, arch.d_model)
            ai = metrics.arithmetic_intensity(
                arch.active_param_count(), shape.global_batch,
                shape.seq_len, act)
        rows.append((
            f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
            f"dom={rl['dominant']};c={rl['compute_s']:.3e};"
            f"m={rl['memory_s']:.3e};n={rl['collective_s']:.3e};"
            f"AI={ai:.1f};mfu={rl.get('mfu') or 0:.3f}"))
    if not rows:
        rows.append(("roofline/no_dryrun_artifacts", 0.0,
                     "run launch/dryrun.py first"))
    return rows
