"""Paper Fig. 10: roofline models. Emits arithmetic intensity (Eq. 5) and
the three roofline terms for every dry-run cell; classifies each as
compute-/memory-/collective-bound (the paper's WSE vs RDU/IPU split)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.bench import BenchRecord, Workload, scenario

RDIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


@scenario(
    "roofline/dryrun", tags=("projected", "fig10"),
    paper_ref="Fig. 10",
    workloads=[Workload(label="16x16", knobs={"glob": "*_16x16.json"})])
def roofline_dryrun(wl: Workload):
    """Roofline terms + AI for every compiled dry-run cell on the mesh."""
    from repro.configs import ARCHS, SHAPES
    from repro.core import metrics

    emitted = False
    for f in sorted(RDIR.glob(wl.knobs["glob"])):
        rec = json.loads(f.read_text())
        rl = rec.get("roofline")
        if not rl:
            continue
        arch = ARCHS.get(rec["arch"])
        shape = SHAPES.get(rec["shape"])
        ai = 0.0
        if arch and shape:
            act = metrics.activation_bytes_estimate(
                arch.num_layers + arch.encoder_layers, shape.global_batch,
                shape.seq_len, arch.d_model)
            ai = metrics.arithmetic_intensity(
                arch.active_param_count(), shape.global_batch,
                shape.seq_len, act)
        emitted = True
        yield BenchRecord(
            name=f"roofline/{rec['arch']}/{rec['shape']}",
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            derived={"dom": rl["dominant"],
                     "compute_s": rl["compute_s"],
                     "memory_s": rl["memory_s"],
                     "collective_s": rl["collective_s"],
                     "AI": round(ai, 1),
                     "mfu": round(rl.get("mfu") or 0.0, 3)})
    if not emitted:
        yield BenchRecord(name="roofline/no_dryrun_artifacts",
                          derived={"note": "run launch/dryrun.py first"})
