"""Measured multi-device scaling matrix (Tier-2, paper Fig. 11/Table III).

Drives the real DP/TP (`parallel/sharding` + collectives) and GPipe
(`parallel/pipeline`) paths on subprocess-simulated host meshes — one
child process per device count, spawned with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` via
``repro.launch.mesh.host_device_env`` (the parent must keep seeing one
device; jax locks the count on first init). Each child prints raw
per-iteration step times as JSON; the parent turns them into
:class:`BenchRecord` rows carrying:

* ``efficiency``       — throughput vs the 1-device run of the *same*
  global problem (`core/scalability.scaling_efficiency`; ideal = 1.0 on a
  shared-core simulated mesh, the deficit is partition overhead);
* ``collective_frac``  — upper-bound fraction of the step spent in
  collectives/partitioning (`collective_time_fraction`);
* ``shard_balance``    — Eq. 3 over per-shard work units (batch rows for
  DP, attention heads for TP; a starved shard pins it to 0);
* PP rows additionally check the paper's "throughput = most-loaded
  stage" model: ``model_ratio`` is measured/predicted step time with the
  per-layer time calibrated on the balanced split, plus Eq. 2/3 stage
  metrics (`pipeline_allocation`, `pp_stage_balance`).

Selection: ``python -m benchmarks.run --only scaling_matrix`` (or an
exact scenario name such as ``scaling_matrix/pp``).
"""
from __future__ import annotations

import functools
import json
import statistics
from typing import Dict, List, Tuple

from repro.bench import BenchRecord, Workload, scenario
from repro.bench.runner import TimingStats, run_with_devices

ARCH = "granite-3-8b"
B, S = 8, 64  # global batch x seq, identical across every split
DEVICE_COUNTS = (1, 2, 4, 8)
# device count -> (data, model) splits measured inside that child process
SPLITS: Dict[int, Tuple[Tuple[int, int], ...]] = {
    1: ((1, 1),),
    2: ((2, 1), (1, 2)),
    4: ((4, 1), (1, 4)),
    8: ((8, 1), (1, 8), (4, 2), (2, 4)),
}
PP_STAGE_SPLITS = ((2, 2, 2, 2), (1, 2, 2, 3), (1, 1, 1, 5))
PP_M, PP_MB, PP_SEQ, PP_D, PP_L = 8, 2, 32, 128, 8

_PREAMBLE = r"""
import json, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
from repro.launch.mesh import make_mesh, set_mesh

cfg = reduced(ARCHS["granite-3-8b"], layers=2, d_model=128, d_ff=256,
              vocab=512)
B, S = 8, 64


def timed_samples(fn, args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    out = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        out.append(time.perf_counter() - t0)
    return out
"""

_SPLIT_BODY = r"""
from repro.models.frontends import synth_batch
from repro.parallel import sharding as shd
from repro.runtime.steps import build_train_step


def step_samples(mesh_shape):
    mesh_cfg = MeshConfig(shape=mesh_shape, axes=("data", "model"))
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", "train", S, B),
                     mesh=mesh_cfg, param_dtype="float32",
                     attention_backend="dense", exec_mode="resident")
    mesh = make_mesh(mesh_cfg)
    with set_mesh(mesh):
        step, model, opt = build_train_step(rcfg)
        params = model.init_params(jax.random.PRNGKey(0))
        pspecs = shd.param_pspecs(params, cfg, rcfg)
        params = jax.tree.map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
            params, pspecs, is_leaf=lambda x: not isinstance(x, dict))
        opt_state = opt.init(params)
        batch = synth_batch(cfg, B, S, kind="train")
        return timed_samples(jax.jit(step), (params, opt_state, batch))


for shape in SPLITS:
    name = "x".join(map(str, shape))
    print(json.dumps({"split": name, "samples_s": step_samples(shape)}))
print(json.dumps({"meta": {"heads": cfg.num_heads,
                           "kv_heads": cfg.num_kv_heads,
                           "batch": B, "seq": S}}))
"""

_PP_BODY = r"""
from repro.parallel.pipeline import pipeline_forward, stack_stages

L, D, M, MB, SS = {pp_dims}
mesh = make_mesh(MeshConfig(shape=(4,), axes=("model",)))
params = {{"w1": jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.05,
           "w2": jax.random.normal(jax.random.PRNGKey(1), (L, D, D)) * 0.05}}
x = jax.random.normal(jax.random.PRNGKey(2), (M, MB, SS, D))


def layer_fn(c, p):
    return c + jnp.tanh(c @ p["w1"]) @ p["w2"]


for stage_layers in {pp_splits}:
    staged, mask = stack_stages(params, stage_layers)
    with set_mesh(mesh):
        fn = jax.jit(
            lambda st, m, xx: pipeline_forward(st, m, xx, layer_fn))
        samples = timed_samples(fn, (staged, mask, x))
    print(json.dumps({{"split": "-".join(map(str, stage_layers)),
                       "samples_s": samples}}))
"""


def _parse_json_lines(stdout: str) -> List[dict]:
    out = []
    for line in stdout.splitlines():
        line = line.strip()
        if line.startswith("{"):
            out.append(json.loads(line))
    return out


@functools.lru_cache(maxsize=None)
def _mesh_results(n_devices: int) -> Dict[str, dict]:
    """split-name -> {"samples_s": [...]} measured in one n-device child
    (plus a "meta" entry). Cached so DP/TP/mixed scenarios share the four
    child processes instead of re-spawning per axis."""
    code = (
        _PREAMBLE
        + f"\nSPLITS = {SPLITS[n_devices]!r}\n"
        + _SPLIT_BODY
    )
    results: Dict[str, dict] = {}
    for rec in _parse_json_lines(
        run_with_devices(code, n_devices=n_devices, timeout=900)
    ):
        if "meta" in rec:
            results["meta"] = rec["meta"]
        else:
            results[rec["split"]] = rec
    return results


@functools.lru_cache(maxsize=1)
def _pp_results() -> Dict[str, dict]:
    code = _PREAMBLE + _PP_BODY.format(
        pp_dims=(PP_L, PP_D, PP_M, PP_MB, PP_SEQ),
        pp_splits=tuple(PP_STAGE_SPLITS),
    )
    return {
        rec["split"]: rec
        for rec in _parse_json_lines(
            run_with_devices(code, n_devices=4, timeout=900)
        )
    }


def _median_s(samples_s: List[float]) -> float:
    return float(statistics.median(samples_s))


def _split_record(
    kind: str, shape: Tuple[int, int], n_devices: int
) -> BenchRecord:
    """One DP/TP/mixed record: measured split vs the 1-device baseline."""
    from repro.core.scalability import (
        collective_time_fraction,
        even_shard_sizes,
        scaling_efficiency,
        shard_balance,
    )

    res = _mesh_results(n_devices)
    base = _mesh_results(1)
    split = "x".join(map(str, shape))
    samples_us = [t * 1e6 for t in res[split]["samples_s"]]
    t_n = _median_s(res[split]["samples_s"])
    t_1 = _median_s(base["1x1"]["samples_s"])
    tokens = B * S
    dp, tp = shape
    heads = res.get("meta", {}).get("heads", 4)
    # analytic partition balance: batch rows over DP replicas and
    # attention heads over TP shards (a TP shard beyond the head count
    # sits idle and pins Eq. 3 to 0)
    work = even_shard_sizes(B, dp) if tp == 1 else even_shard_sizes(heads, tp)
    name = f"scaling_matrix/{kind}{n_devices}" if kind != "mix" \
        else f"scaling_matrix/mix_{split}"
    return BenchRecord(
        name=name,
        mesh=split,
        us_per_call=TimingStats(samples_us),
        knobs={"devices": n_devices, "split": split, "kind": kind},
        derived={
            "tok_s": round(tokens / t_n, 1),
            "efficiency": round(
                scaling_efficiency(tokens / t_n, tokens / t_1), 4
            ),
            "collective_frac": round(
                collective_time_fraction(t_n, t_1), 4
            ),
            "shard_balance": round(shard_balance(work), 4),
        },
    )


@scenario(
    "scaling_matrix/dp",
    tags=("tier2", "measured", "fig11", "table3", "scaling_matrix"),
    paper_ref="Fig. 11a / Table III (measured mesh matrix)",
    workloads=[
        Workload(label=f"n{n}", arch=ARCH, knobs={"devices": n})
        for n in DEVICE_COUNTS
    ],
)
def scaling_matrix_dp(wl: Workload):
    """DP replica scaling on simulated 1/2/4/8-device host meshes."""
    n = wl.knobs["devices"]
    yield _split_record("dp", (n, 1), n)


@scenario(
    "scaling_matrix/tp",
    tags=("tier2", "measured", "fig11", "table3", "scaling_matrix"),
    paper_ref="Fig. 11b / Table III (measured mesh matrix)",
    workloads=[
        Workload(label=f"n{n}", arch=ARCH, knobs={"devices": n})
        for n in DEVICE_COUNTS
        if n > 1
    ],
)
def scaling_matrix_tp(wl: Workload):
    """TP width scaling on simulated 2/4/8-device host meshes."""
    n = wl.knobs["devices"]
    yield _split_record("tp", (1, n), n)


@scenario(
    "scaling_matrix/mixed",
    tags=("tier2", "measured", "fig11", "table3", "scaling_matrix"),
    paper_ref="Table III (DP x TP interior splits)",
    workloads=[
        Workload(label="4x2", arch=ARCH, knobs={"devices": 8}),
        Workload(label="2x4", arch=ARCH, knobs={"devices": 8}),
    ],
)
def scaling_matrix_mixed(wl: Workload):
    """Interior DPxTP splits of the 8-device mesh (4x2, 2x4)."""
    shape = tuple(int(x) for x in wl.label.split("x"))
    yield _split_record("mix", shape, wl.knobs["devices"])


@scenario(
    "scaling_matrix/pp",
    tags=("tier2", "measured", "fig11", "scaling_matrix"),
    paper_ref="Fig. 11c (most-loaded-stage model, measured)",
    workloads=[
        Workload(
            label="-".join(map(str, sl)),
            arch=ARCH,
            knobs={"devices": 4, "stage_layers": sl},
        )
        for sl in PP_STAGE_SPLITS
    ],
)
def scaling_matrix_pp(wl: Workload):
    """GPipe layer-allocation splits on a simulated 4-device mesh,
    checked against the most-loaded-stage bottleneck model."""
    from repro.core.scalability import (
        pp_calibrate_per_layer,
        pp_model_check,
        pp_stage_balance,
    )
    from repro.parallel.pipeline import pipeline_allocation

    stage_layers = tuple(wl.knobs["stage_layers"])
    split = wl.label
    res = _pp_results()
    balanced = "-".join(map(str, PP_STAGE_SPLITS[0]))
    per_layer = pp_calibrate_per_layer(
        _median_s(res[balanced]["samples_s"]), PP_STAGE_SPLITS[0], PP_M
    )
    t = _median_s(res[split]["samples_s"])
    check = pp_model_check(t, stage_layers, PP_M, per_layer)
    tokens = PP_M * PP_MB * PP_SEQ
    yield BenchRecord(
        name=f"scaling_matrix/pp_{split}",
        mesh="4",
        us_per_call=TimingStats([s * 1e6 for s in res[split]["samples_s"]]),
        derived={
            "tok_s": round(tokens / t, 1),
            "max_stage": max(stage_layers),
            "stage_balance": round(pp_stage_balance(stage_layers), 4),
            "allocation": round(pipeline_allocation(stage_layers), 4),
            "predicted_us": round(check.predicted_s * 1e6, 1),
            "model_ratio": round(check.ratio, 4),
            "model_ok": check.within(),
        },
    )
