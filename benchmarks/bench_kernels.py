"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp stand-ins vs
dense reference — correctness-weighted timing plus the structural flop
accounting the roofline uses."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.kernels import ops, ref
from repro.models.attention import chunked_attention
from repro.models.ssm import chunked_linear_attention


def run():
    rows = []
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, D = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    dense = jax.jit(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=True))
    chunked = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, chunk=128))
    rows.append(("kernels/attn_dense_ref", timeit_us(dense, q, k, v), ""))
    rows.append(("kernels/attn_chunked_jnp", timeit_us(chunked, q, k, v), ""))
    rows.append(("kernels/attn_pallas_interp",
                 timeit_us(lambda *a: ops.flash_attention(*a, causal=True),
                           q, k, v, iters=2, warmup=1),
                 "interpret-mode (CPU); real kernel on TPU"))

    T, H, K = 256, 2, 64
    q2 = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    k2 = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    v2 = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    ld = jnp.asarray(-np.exp(rng.standard_normal((B, T, H, K))), jnp.float32)
    chunked_w = jax.jit(lambda *a: chunked_linear_attention(*a, chunk=64)[0])
    rows.append(("kernels/wkv6_chunked_jnp",
                 timeit_us(chunked_w, q2, k2, v2, ld), ""))
    rows.append(("kernels/wkv6_pallas_interp",
                 timeit_us(lambda *a: ops.wkv6(*a, chunk=64)[0],
                           q2, k2, v2, ld, iters=2, warmup=1),
                 "interpret-mode (CPU)"))

    x = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    sc = jnp.ones((512,), jnp.float32)
    rows.append(("kernels/rmsnorm_jnp",
                 timeit_us(jax.jit(lambda x, s: ref.rmsnorm_ref(x, s)),
                           x, sc), ""))
    rows.append(("kernels/rmsnorm_pallas_interp",
                 timeit_us(lambda x, s: ops.rmsnorm(x, s), x, sc,
                           iters=2, warmup=1), "interpret-mode (CPU)"))
    return rows
