"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp stand-ins vs
dense reference — correctness-weighted timing plus the structural flop
accounting the roofline uses. Each kernel family is one scenario whose
implementations are declared as :class:`Workload` cells.

Pallas workloads run with "auto" tile sizes: the resolved config (tuned
cache hit from ``--tune``, or the kernel default) is reported in the
record's derived metrics."""
from __future__ import annotations

from repro.bench import BenchRecord, Workload, scenario, timeit_us

_ATTN_IMPLS = ("dense_ref", "chunked_jnp", "pallas_interp")
_WKV_IMPLS = ("chunked_jnp", "pallas_interp")
_NORM_IMPLS = ("jnp", "pallas_interp")

_INTERP_NOTE = "interpret-mode (CPU); real kernel on TPU"


def _attn_inputs():
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, D = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    return q, k, v


@scenario(
    "kernels/attention", tags=("kernel", "micro"),
    paper_ref="kernel-level microbenchmarks",
    workloads=[Workload(label=impl, knobs={"impl": impl})
               for impl in _ATTN_IMPLS])
def kernels_attention(wl: Workload):
    """Causal flash attention: Pallas interpret vs chunked-jnp vs dense."""
    import jax

    from repro.kernels import ops, ref
    from repro.models.attention import chunked_attention

    q, k, v = _attn_inputs()
    impl = wl.knobs["impl"]
    if impl == "dense_ref":
        fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(
            q, k, v, causal=True))
        us = timeit_us(fn, q, k, v)
        derived = {}
    elif impl == "chunked_jnp":
        fn = jax.jit(lambda q, k, v: chunked_attention(
            q, k, v, causal=True, chunk=128))
        us = timeit_us(fn, q, k, v)
        derived = {}
    else:
        from repro.kernels import tuning

        us = timeit_us(lambda *a: ops.flash_attention(*a, causal=True),
                       q, k, v, iters=2, warmup=1)
        sig = tuning.attention_signature(q.shape, k.shape, q.dtype,
                                         causal=True, window=0)
        bq, bk = tuning.resolve_attention_blocks(
            None, None, q_shape=q.shape, k_shape=k.shape, dtype=q.dtype,
            causal=True, window=0)
        derived = {"note": _INTERP_NOTE, "block_q": bq, "block_k": bk,
                   "tuned": bool(tuning.lookup("flash_attention_fwd",
                                               sig))}
    yield BenchRecord(name=f"kernels/attn_{impl}", us_per_call=us,
                      derived=derived)


@scenario(
    "kernels/wkv6", tags=("kernel", "micro", "ssm"),
    paper_ref="kernel-level microbenchmarks",
    workloads=[Workload(label=impl, knobs={"impl": impl})
               for impl in _WKV_IMPLS])
def kernels_wkv6(wl: Workload):
    """RWKV6 wkv recurrence: Pallas interpret vs chunked-jnp."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.models.ssm import chunked_linear_attention

    rng = np.random.default_rng(0)
    B, T, H, K = 1, 256, 2, 64
    q = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    ld = jnp.asarray(-np.exp(rng.standard_normal((B, T, H, K))), jnp.float32)
    if wl.knobs["impl"] == "chunked_jnp":
        fn = jax.jit(lambda *a: chunked_linear_attention(*a, chunk=64)[0])
        us = timeit_us(fn, q, k, v, ld)
        derived = {}
    else:
        from repro.kernels import tuning

        us = timeit_us(lambda *a: ops.wkv6(*a)[0],
                       q, k, v, ld, iters=2, warmup=1)
        sig = tuning.wkv6_signature(q.shape, v.shape[-1], q.dtype,
                                    use_u=False)
        chunk = tuning.resolve_wkv_chunk(None, q_shape=q.shape,
                                         v_head=v.shape[-1],
                                         dtype=q.dtype, use_u=False)
        derived = {"note": _INTERP_NOTE, "chunk": chunk,
                   "tuned": bool(tuning.lookup("wkv6_fwd", sig))}
    yield BenchRecord(name=f"kernels/wkv6_{wl.knobs['impl']}",
                      us_per_call=us, derived=derived)


@scenario(
    "kernels/rmsnorm", tags=("kernel", "micro"),
    paper_ref="kernel-level microbenchmarks",
    workloads=[Workload(label=impl, knobs={"impl": impl})
               for impl in _NORM_IMPLS])
def kernels_rmsnorm(wl: Workload):
    """RMSNorm: Pallas interpret vs jnp reference."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4096, 512)), jnp.float32)
    sc = jnp.ones((512,), jnp.float32)
    if wl.knobs["impl"] == "jnp":
        us = timeit_us(jax.jit(lambda x, s: ref.rmsnorm_ref(x, s)), x, sc)
        derived = {}
    else:
        from repro.kernels import tuning

        us = timeit_us(lambda x, s: ops.rmsnorm(x, s), x, sc,
                       iters=2, warmup=1)
        sig = tuning.rmsnorm_signature(x.shape[0], x.shape[1], x.dtype)
        rows = tuning.resolve_rmsnorm_rows(None, rows=x.shape[0],
                                           d=x.shape[1], dtype=x.dtype)
        derived = {"note": _INTERP_NOTE, "block_rows": rows,
                   "tuned": bool(tuning.lookup("rmsnorm_fwd", sig))}
    yield BenchRecord(name=f"kernels/rmsnorm_{wl.knobs['impl']}",
                      us_per_call=us, derived=derived)
