"""Paper Fig. 12 + Table IV: deployment optimization — throughput vs batch
size and numeric precision, measured on reduced models on this host.

Note: XLA:CPU emulates bf16 in f32, so the *measured* CPU precision delta
understates TPU reality; the full-scale precision effect shows up in the
dry-run roofline terms (bf16 halves the memory term), which we also emit.
"""
from __future__ import annotations

import functools
import json
from pathlib import Path

from repro.bench import BenchRecord, Workload, scenario, timeit_us

RDIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"
SEQ = 128


@functools.lru_cache(maxsize=4)
def _grad_fn(dtype_name: str):
    """Reduced qwen2.5 block + jitted loss-grad, cached across workloads."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.models import Runtime, build

    cfg = reduced(ARCHS["qwen2.5-32b"], layers=4, d_model=256, d_ff=1024,
                  vocab=2048)
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[dtype_name]
    model = build(cfg, Runtime(attention_backend="dense"), dt)
    params = model.init_params(jax.random.PRNGKey(0))
    g = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    return cfg, params, g


@scenario(
    "deploy/batch", tags=("measured", "fig12"),
    paper_ref="Fig. 12",
    workloads=[Workload(label=f"batch{B}", arch="qwen2.5-32b",
                        knobs={"batch": B})
               for B in (1, 2, 4, 8, 16, 32)])
def deploy_batch(wl: Workload):
    """Throughput vs batch size (reduced qwen2.5 block, f32 train step)."""
    from repro.models.frontends import synth_batch

    cfg, params, g = _grad_fn("float32")
    B = wl.knobs["batch"]
    batch = synth_batch(cfg, B, SEQ, kind="train")
    us = timeit_us(g, params, batch, iters=3)
    yield BenchRecord(name=f"deploy/batch{B}", us_per_call=us,
                      derived={"tok_s": round(B * SEQ / (us * 1e-6))})


@scenario(
    "deploy/precision", tags=("measured", "table4"),
    paper_ref="Table IV",
    workloads=[Workload(label=dt, arch="qwen2.5-32b", knobs={"dtype": dt})
               for dt in ("float32", "bfloat16")])
def deploy_precision(wl: Workload):
    """Throughput per param dtype at fixed batch (Table IV knob)."""
    from repro.models.frontends import synth_batch

    cfg, params, g = _grad_fn(wl.knobs["dtype"])
    batch = synth_batch(cfg, 8, SEQ, kind="train")
    us = timeit_us(g, params, batch, iters=3)
    yield BenchRecord(name=f"deploy/precision_{wl.knobs['dtype']}",
                      us_per_call=us,
                      derived={"tok_s": round(8 * SEQ / (us * 1e-6))})


@scenario(
    "deploy/precision_fullscale", tags=("projected", "table4"),
    paper_ref="Table IV (full-scale projection)",
    workloads=[Workload(label="bf16", arch="granite-3-8b")])
def deploy_precision_fullscale(wl: Workload):
    """Full-scale precision effect from the dry-run roofline memory term."""
    f = RDIR / "granite-3-8b_train_4k_16x16.json"
    if not f.exists():
        return
    rl = json.loads(f.read_text())["roofline"]
    yield BenchRecord(
        name="deploy/precision_fullscale_bf16",
        derived={"memory_s": round(rl["memory_s"], 2),
                 "note": "f32_would_be~2x_memory_term"})
