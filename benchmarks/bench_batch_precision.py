"""Paper Fig. 12 + Table IV: deployment optimization — throughput vs batch
size and numeric precision, measured on reduced models on this host.

Note: XLA:CPU emulates bf16 in f32, so the *measured* CPU precision delta
understates TPU reality; the full-scale precision effect shows up in the
dry-run roofline terms (bf16 halves the memory term), which we also emit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.configs import ARCHS, reduced
from repro.models import build, Runtime
from repro.models.frontends import synth_batch


def run():
    rows = []
    cfg = reduced(ARCHS["qwen2.5-32b"], layers=4, d_model=256, d_ff=1024,
                  vocab=2048)

    # --- batch sweep (Fig. 12) ---
    model = build(cfg, Runtime(attention_backend="dense"), jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    g = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    S = 128
    for B in (1, 2, 4, 8, 16, 32):
        batch = synth_batch(cfg, B, S, kind="train")
        us = timeit_us(g, params, batch, iters=3)
        rows.append((f"deploy/batch{B}", us,
                     f"tok_s={B * S / (us * 1e-6):.0f}"))

    # --- precision sweep (Table IV) ---
    for dt_name, dt in (("float32", jnp.float32), ("bfloat16", jnp.bfloat16)):
        m = build(cfg, Runtime(attention_backend="dense"), dt)
        p = m.init_params(jax.random.PRNGKey(0))
        gg = jax.jit(jax.grad(lambda p, b: m.loss(p, b)[0]))
        batch = synth_batch(cfg, 8, S, kind="train")
        us = timeit_us(gg, p, batch, iters=3)
        rows.append((f"deploy/precision_{dt_name}", us,
                     f"tok_s={8 * S / (us * 1e-6):.0f}"))

    # --- full-scale precision effect from the roofline (memory term) ---
    import json
    from pathlib import Path
    rdir = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    f = rdir / "granite-3-8b_train_4k_16x16.json"
    if f.exists():
        rl = json.loads(f.read_text())["roofline"]
        rows.append(("deploy/precision_fullscale_bf16", 0.0,
                     f"memory_s={rl['memory_s']:.2f};"
                     "f32_would_be~2x_memory_term"))
    return rows
