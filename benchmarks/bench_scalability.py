"""Paper Table III / Fig. 11: inter-chip scalability, MEASURED on an
8-fake-device host mesh with reduced models:

* DP replicas (WSE-style intra-chip data parallelism, Fig. 11a)
* TP width sweep (RDU-style tensor parallelism, Fig. 11b)
* PP layer-allocation sweep (IPU-style, Fig. 11c: most-loaded stage governs)
* resident vs streaming (FSDP) weights — the paper's whole-graph vs
  weight-streaming comparison (~20% claimed overhead on WSE-2).
"""
from __future__ import annotations

from benchmarks.common import run_with_devices

_CODE = r"""
import time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
from repro.launch.mesh import make_mesh
from repro.models import build
from repro.models.frontends import synth_batch
from repro.parallel import sharding as shd
from repro.runtime.steps import build_train_step, make_runtime

def measure(fn, args, iters=4):
    out = fn(*args); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters

cfg = reduced(ARCHS["granite-3-8b"], layers=4, d_model=256, d_ff=1024,
              vocab=2048)
B, S = 16, 128
tokens = B * S

def step_time(mesh_shape, axes, exec_mode="resident"):
    mesh_cfg = MeshConfig(shape=mesh_shape, axes=axes)
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", "train", S, B),
                     mesh=mesh_cfg, param_dtype="float32",
                     attention_backend="dense", exec_mode=exec_mode)
    mesh = make_mesh(mesh_cfg)
    with jax.set_mesh(mesh):
        step, model, opt = build_train_step(rcfg)
        params = model.init_params(jax.random.PRNGKey(0))
        pspecs = shd.param_pspecs(params, cfg, rcfg)
        params = jax.tree.map(lambda x, s: jax.device_put(
            x, NamedSharding(mesh, s)), params, pspecs,
            is_leaf=lambda x: not isinstance(x, dict))
        opt_state = opt.init(params)
        batch = synth_batch(cfg, B, S, kind="train")
        fn = jax.jit(step)
        return measure(fn, (params, opt_state, batch))

# DP scaling (Fig 11a): 1 -> 8 data shards
for dp in (1, 2, 4, 8):
    t = step_time((dp, 1), ("data", "model"))
    print(f"scalability/dp{dp},{t*1e6:.0f},tok_s={tokens/t:.0f}")
# TP sweep (Fig 11b)
for tp in (1, 2, 4, 8):
    t = step_time((8 // tp, tp), ("data", "model"))
    print(f"scalability/tp{tp},{t*1e6:.0f},tok_s={tokens/t:.0f}")
# resident vs streaming (weight-streaming overhead, Table III WSE column)
t_res = step_time((4, 2), ("data", "model"), "resident")
t_str = step_time((4, 2), ("data", "model"), "streaming")
print(f"scalability/resident,{t_res*1e6:.0f},tok_s={tokens/t_res:.0f}")
print(f"scalability/streaming,{t_str*1e6:.0f},"
      f"tok_s={tokens/t_str:.0f};overhead={t_str/t_res-1:.2%}")

# PP layer-allocation sweep (Fig 11c) on a 4-stage pipe
from repro.parallel.pipeline import stack_stages, pipeline_forward
mesh = make_mesh(MeshConfig(shape=(4,), axes=("model",)))
L, D, M, MB, SS = 8, 256, 8, 2, 64
params = {"w1": jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.05,
          "w2": jax.random.normal(jax.random.PRNGKey(1), (L, D, D)) * 0.05}
x = jax.random.normal(jax.random.PRNGKey(2), (M, MB, SS, D))
layer_fn = lambda c, p: c + jnp.tanh(c @ p["w1"]) @ p["w2"]
for stage_layers in [(2, 2, 2, 2), (1, 2, 2, 3), (1, 1, 1, 5)]:
    staged, mask = stack_stages(params, stage_layers)
    with jax.set_mesh(mesh):
        fn = jax.jit(lambda s, m, xx: pipeline_forward(s, m, xx, layer_fn))
        t = measure(fn, (staged, mask, x))
    name = "-".join(map(str, stage_layers))
    print(f"scalability/pp_{name},{t*1e6:.0f},"
          f"tok_s={M*MB*SS/t:.0f};max_stage={max(stage_layers)}")
"""


def run():
    rows = []
    out = run_with_devices(_CODE, n_devices=8, timeout=1200)
    for line in out.strip().splitlines():
        if line.count(",") >= 2:
            name, us, derived = line.split(",", 2)
            rows.append((name, float(us), derived))
    return rows
