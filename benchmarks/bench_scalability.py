"""Paper Table III / Fig. 11: inter-chip scalability, MEASURED on an
8-fake-device host mesh with reduced models:

* DP replicas (WSE-style intra-chip data parallelism, Fig. 11a)
* TP width sweep (RDU-style tensor parallelism, Fig. 11b)
* PP layer-allocation sweep (IPU-style, Fig. 11c: most-loaded stage governs)
* resident vs streaming (FSDP) weights — the paper's whole-graph vs
  weight-streaming comparison (~20% claimed overhead on WSE-2).

Each axis is its own scenario so ``--only``/tag filtering and fail-soft
error capture work per axis. The fake-device subprocess prints one JSON
record per measurement; the parent parses JSON, never ``key=value``
strings.
"""
from __future__ import annotations

import json

from repro.bench import BenchRecord, Workload, scenario, run_with_devices

_PREAMBLE = r"""
import json, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
from repro.launch.mesh import make_mesh, set_mesh
from repro.models import build
from repro.models.frontends import synth_batch
from repro.parallel import sharding as shd
from repro.runtime.steps import build_train_step, make_runtime

def emit(name, t, **derived):
    print(json.dumps({"name": name, "us_per_call": t * 1e6,
                      "derived": derived}))

def measure(fn, args, iters=4):
    out = fn(*args); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters

cfg = reduced(ARCHS["granite-3-8b"], layers=4, d_model=256, d_ff=1024,
              vocab=2048)
B, S = 16, 128
tokens = B * S

def step_time(mesh_shape, axes, exec_mode="resident"):
    mesh_cfg = MeshConfig(shape=mesh_shape, axes=axes)
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", "train", S, B),
                     mesh=mesh_cfg, param_dtype="float32",
                     attention_backend="dense", exec_mode=exec_mode)
    mesh = make_mesh(mesh_cfg)
    with set_mesh(mesh):
        step, model, opt = build_train_step(rcfg)
        params = model.init_params(jax.random.PRNGKey(0))
        pspecs = shd.param_pspecs(params, cfg, rcfg)
        params = jax.tree.map(lambda x, s: jax.device_put(
            x, NamedSharding(mesh, s)), params, pspecs,
            is_leaf=lambda x: not isinstance(x, dict))
        opt_state = opt.init(params)
        batch = synth_batch(cfg, B, S, kind="train")
        fn = jax.jit(step)
        return measure(fn, (params, opt_state, batch))
"""

_DP_CODE = _PREAMBLE + r"""
for dp in (1, 2, 4, 8):
    t = step_time((dp, 1), ("data", "model"))
    emit(f"scalability/dp{dp}", t, tok_s=round(tokens / t))
"""

_TP_CODE = _PREAMBLE + r"""
for tp in (1, 2, 4, 8):
    t = step_time((8 // tp, tp), ("data", "model"))
    emit(f"scalability/tp{tp}", t, tok_s=round(tokens / t))
"""

_STREAMING_CODE = _PREAMBLE + r"""
t_res = step_time((4, 2), ("data", "model"), "resident")
t_str = step_time((4, 2), ("data", "model"), "streaming")
emit("scalability/resident", t_res, tok_s=round(tokens / t_res))
emit("scalability/streaming", t_str, tok_s=round(tokens / t_str),
     overhead=round(t_str / t_res - 1, 4))
"""

_PP_CODE = _PREAMBLE + r"""
from repro.parallel.pipeline import stack_stages, pipeline_forward
mesh = make_mesh(MeshConfig(shape=(4,), axes=("model",)))
L, D, M, MB, SS = 8, 256, 8, 2, 64
params = {"w1": jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.05,
          "w2": jax.random.normal(jax.random.PRNGKey(1), (L, D, D)) * 0.05}
x = jax.random.normal(jax.random.PRNGKey(2), (M, MB, SS, D))
layer_fn = lambda c, p: c + jnp.tanh(c @ p["w1"]) @ p["w2"]
for stage_layers in [(2, 2, 2, 2), (1, 2, 2, 3), (1, 1, 1, 5)]:
    staged, mask = stack_stages(params, stage_layers)
    with set_mesh(mesh):
        fn = jax.jit(lambda s, m, xx: pipeline_forward(s, m, xx, layer_fn))
        t = measure(fn, (staged, mask, x))
    name = "-".join(map(str, stage_layers))
    emit(f"scalability/pp_{name}", t, tok_s=round(M * MB * SS / t),
         max_stage=max(stage_layers))
"""


def _run_json(code: str, timeout: int = 1200):
    """Run fake-device code and yield the JSON records it prints."""
    for line in run_with_devices(code, n_devices=8,
                                 timeout=timeout).splitlines():
        line = line.strip()
        if line.startswith("{"):
            yield BenchRecord.from_dict(json.loads(line))


@scenario(
    "scalability/dp", tags=("measured", "fig11", "table3"),
    paper_ref="Fig. 11a / Table III",
    workloads=[Workload(label="dp1-8", arch="granite-3-8b",
                        knobs={"devices": 8})])
def scalability_dp(wl: Workload):
    """DP replica scaling 1 -> 8 data shards (WSE-style)."""
    yield from _run_json(_DP_CODE)


@scenario(
    "scalability/tp", tags=("measured", "fig11", "table3"),
    paper_ref="Fig. 11b / Table III",
    workloads=[Workload(label="tp1-8", arch="granite-3-8b",
                        knobs={"devices": 8})])
def scalability_tp(wl: Workload):
    """TP width sweep at fixed 8 devices (RDU-style)."""
    yield from _run_json(_TP_CODE)


@scenario(
    "scalability/streaming", tags=("measured", "fig11", "table3"),
    paper_ref="Table III (weight streaming)",
    workloads=[Workload(label="4x2", arch="granite-3-8b",
                        knobs={"devices": 8})])
def scalability_streaming(wl: Workload):
    """Resident vs streaming (FSDP) weights on a 4x2 mesh."""
    yield from _run_json(_STREAMING_CODE)


@scenario(
    "scalability/pp", tags=("measured", "fig11"),
    paper_ref="Fig. 11c",
    workloads=[Workload(label="4stage", knobs={"devices": 4})])
def scalability_pp(wl: Workload):
    """PP layer-allocation sweep: most-loaded stage governs throughput."""
    yield from _run_json(_PP_CODE)
