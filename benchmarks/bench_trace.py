"""Tier-2 trace-replay scenarios: capture → DAG replay → prediction.

The measure→compare→gate loop applied to *prediction* (DESIGN.md §3):
every scenario first CAPTURES a trace of a real run, then REPLAYS its
event DAG and compares the replayed prediction against the measurement
it was decomposed from.

* ``trace_replay/matrix`` — one trace per cell of the measured DP/TP
  scaling-matrix grid (same splits, same reduced model, same
  subprocess-simulated meshes as ``bench_scaling_matrix``). Each record
  carries ``predicted_us`` (identity replay of the cell's DAG) next to
  ``measured_us`` and the ``rel_err`` between them —
  ``tools/ci_checks.py trace-replay-error`` gates rel_err ≤ 25% per
  cell. Traces land in ``results/traces/`` (CI artifacts).
* ``trace_replay/whatif`` — cross-split predictions from the 1x1 trace
  alone (``trace.whatif.predict_split``): for every other measured
  cell, the record reports the what-if prediction, the measured time,
  and their ratio. REPORTED, not gated — simulated-host cells include
  shared-core contention no per-device model represents (DESIGN.md §4).
* ``trace_replay/advise`` — the trace-driven ``mesh_advisor`` mode:
  split rankings at 8 devices from analytic peaks vs from the 1x1
  trace's measured calibration.
* ``trace_replay/serve`` — a paged-engine burst under
  ``TracingClock(SimClock)``: the dispatch-chain trace's identity
  replay must equal the engine's busy time exactly (deterministic, so
  ``rel_err`` here is 0 by construction or the seam is broken).
* ``trace_replay/serve_roles`` — the same chunked-prefill-heavy
  staggered stream through the interleaved paged loop and the
  P/D-disaggregated engine, each under ``TracingClock(SimClock)``: the
  records carry the trace's per-role lane decomposition
  (``Trace.lane_seconds(by="role")``) next to the decode-step stall
  distribution — decode interference before/after disaggregation.
  REPORTED, not gated (the strict stall ordering is gated by
  ``tools/ci_checks.py pd-parity``).

Selection: ``python -m benchmarks.run --only trace_replay``.
"""

from __future__ import annotations

import functools
from pathlib import Path
from typing import Dict, Tuple

from benchmarks.bench_scaling_matrix import ARCH, B, DEVICE_COUNTS, S, SPLITS
from repro.bench import BenchRecord, Workload, scenario
from repro.bench.runner import TimingStats

TRACE_DIR = Path(__file__).resolve().parent.parent / "results" / "traces"
GATE_REL_ERR = 0.25  # the trace-replay-error CI bound, per matrix cell


@functools.lru_cache(maxsize=None)
def _cell_traces(n_devices: int) -> Dict[str, "object"]:
    """split-name -> captured Trace, one child process per device count
    (cached so the dp/tp/mixed/whatif scenarios share the children)."""
    from repro.trace import capture_matrix_cell

    traces = capture_matrix_cell(n_devices, SPLITS[n_devices],
                                 arch=ARCH, batch=B, seq=S)
    out = {}
    for tr in traces:
        split = tr.mesh
        out[split] = tr
        tr.save(TRACE_DIR / f"{ARCH}-{split}.json")
    return out


def _cell_record(kind: str, shape: Tuple[int, int],
                 n_devices: int) -> BenchRecord:
    """One matrix cell: identity replay vs the measurement it came from."""
    from repro.trace import replay

    split = "x".join(map(str, shape))
    tr = _cell_traces(n_devices)[split]
    base = _cell_traces(1)["1x1"]
    res = replay(tr)
    measured_us = tr.measured_step_s * 1e6
    predicted_us = res.predicted_s * 1e6
    rel_err = abs(predicted_us - measured_us) / measured_us
    tokens = B * S
    name = (f"trace_replay/{kind}{n_devices}" if kind != "mix"
            else f"trace_replay/mix_{split}")
    return BenchRecord(
        name=name,
        mesh=split,
        us_per_call=TimingStats([t * 1e6 for t in tr.samples_s]),
        knobs={"devices": n_devices, "split": split, "kind": kind},
        derived={
            "measured_us": round(measured_us, 1),
            "predicted_us": round(predicted_us, 1),
            "rel_err": round(rel_err, 6),
            "gate_rel_err": GATE_REL_ERR,
            "efficiency": round(
                (tokens / tr.measured_step_s)
                / (tokens / base.measured_step_s), 4),
            "dominant": res.dominant_lane,
            "n_events": len(tr.events),
            "critical_path_len": len(res.critical_path),
            "calibration_ratio": round(
                float(tr.meta.get("calibration_ratio", 0.0)), 4),
        },
    )


@scenario(
    "trace_replay/matrix",
    tags=("tier2", "measured", "trace_replay"),
    paper_ref="Sec. V guidance loop (trace capture -> replay prediction)",
    workloads=[
        Workload(label=f"n{n}", arch=ARCH, knobs={"devices": n})
        for n in DEVICE_COUNTS
    ],
)
def trace_replay_matrix(wl: Workload):
    """Identity replay of every captured scaling-matrix cell at this
    device count; rel_err per cell is the trace-replay-error gate."""
    n = wl.knobs["devices"]
    for dp, tp in SPLITS[n]:
        kind = "dp" if tp == 1 else ("tp" if dp == 1 else "mix")
        yield _cell_record(kind, (dp, tp), n)


@scenario(
    "trace_replay/whatif",
    tags=("tier2", "measured", "trace_replay"),
    paper_ref="Sec. V guidance loop (what-if split prediction)",
    workloads=[Workload(label="from-1x1", arch=ARCH, knobs={})],
)
def trace_replay_whatif(wl: Workload):
    """Cross-split what-if predictions from the 1x1 trace vs every
    measured cell (reported, not gated — DESIGN.md §4)."""
    from repro.trace import predict_split

    base = _cell_traces(1)["1x1"]
    for n in DEVICE_COUNTS:
        cells = _cell_traces(n)
        for dp, tp in SPLITS[n]:
            split = f"{dp}x{tp}"
            pred = predict_split(base, (dp, tp))
            measured_s = cells[split].measured_step_s
            predicted_us = pred.predicted_s * 1e6
            measured_us = measured_s * 1e6
            yield BenchRecord(
                name=f"trace_replay/whatif_{split}",
                mesh=split,
                us_per_call=measured_us,
                knobs={"devices": n, "split": split},
                derived={
                    "predicted_us": round(predicted_us, 1),
                    "measured_us": round(measured_us, 1),
                    "ratio": round(predicted_us / measured_us, 4),
                    "rel_err": round(
                        abs(predicted_us - measured_us) / measured_us, 4),
                    "dominant": pred.dominant_lane,
                    "gated": False,
                },
            )


@scenario(
    "trace_replay/advise",
    tags=("tier2", "trace_replay"),
    paper_ref="Sec. V guidance loop (trace-calibrated mesh advisor)",
    workloads=[Workload(label="n8", arch=ARCH, knobs={"devices": 8})],
)
def trace_replay_advise(wl: Workload):
    """Split ranking at 8 devices: analytic peaks vs the 1x1 trace's
    measured calibration through the same advisor."""
    from repro.configs import ARCHS, ShapeConfig, reduced
    from repro.core.mesh_advisor import advise
    from repro.trace import advise_from_trace
    from repro.trace.capture import MATRIX_REDUCE_KW

    n = wl.knobs["devices"]
    base = _cell_traces(1)["1x1"]
    cfg = reduced(ARCHS[ARCH], **MATRIX_REDUCE_KW)
    shape = ShapeConfig("trace", "train", S, B)
    candidates = [1, 2, 4, 8]
    analytic = advise(cfg, shape, n, candidates=candidates)
    traced = advise_from_trace(base, n, candidates=candidates)
    cal = base.calibration()
    yield BenchRecord(
        name=f"trace_replay/advise{n}",
        mesh="x".join(map(str, traced[0].mesh.shape)),
        knobs={"devices": n},
        derived={
            "analytic_best": "x".join(map(str, analytic[0].mesh.shape)),
            "traced_best": "x".join(map(str, traced[0].mesh.shape)),
            "analytic_step_us": round(analytic[0].step_s * 1e6, 1),
            "traced_step_us": round(traced[0].step_s * 1e6, 1),
            "traced_dominant": traced[0].dominant,
            "flops_per_s": round(cal["flops_per_s"], 1),
            "hbm_bytes_per_s": round(cal["hbm_bytes_per_s"], 1),
            "ici_bytes_per_s": round(cal["ici_bytes_per_s"], 1),
            "calibration_ratio": round(cal["calibration_ratio"], 4),
        },
    )


@scenario(
    "trace_replay/serve",
    tags=("tier2", "serving", "trace_replay"),
    paper_ref="Sec. V guidance loop (serving dispatch trace)",
    workloads=[Workload(label="paged-burst", arch=ARCH, knobs={})],
)
def trace_replay_serve(wl: Workload):
    """Paged-engine burst under TracingClock(SimClock): the recorded
    dispatch chain replays to exactly the engine's busy time."""
    from repro.data.pipeline import synth_requests
    from repro.launch.serve import build_engine
    from repro.serving.request import SimClock
    from repro.trace import TracingClock, replay

    clk = TracingClock(SimClock(prefill_cost_s=0.5, decode_cost_s=0.1))
    eng, cfg = build_engine(
        ARCH, batch=4, prompt_len=8, max_new_tokens=8, scheduler="paged",
        page_size=4, num_pages=64, clock=clk,
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128))
    reqs = synth_requests(cfg, 6, 8, max_new_tokens=(8,), seed=0)
    report = eng.run(reqs)
    tr = clk.trace(f"serve/{ARCH}/paged", arch=ARCH)
    tr.save(TRACE_DIR / f"{ARCH}-serve-paged.json")
    res = replay(tr)
    busy_us = tr.measured_step_s * 1e6
    predicted_us = res.predicted_s * 1e6
    yield BenchRecord(
        name="trace_replay/serve_paged",
        us_per_call=TimingStats(
            [ev.cost_s * 1e6 for ev in tr.events if ev.cost_s > 0]
        ),
        knobs={"scheduler": "paged", "requests": len(reqs)},
        derived={
            "completed": report.completed,
            "busy_us": round(busy_us, 1),
            "predicted_us": round(predicted_us, 1),
            "rel_err": round(
                abs(predicted_us - busy_us) / busy_us, 6) if busy_us else 0.0,
            "n_events": len(tr.events),
            "prefill_dispatches": tr.meta["dispatches"].get("prefill", 0),
            "decode_dispatches": tr.meta["dispatches"].get("decode", 0),
        },
    )


@scenario(
    "trace_replay/serve_roles",
    tags=("tier2", "serving", "trace_replay", "disagg"),
    paper_ref="Sec. V guidance loop (per-role serving dispatch lanes)",
    workloads=[Workload(label="interleaved", arch=ARCH,
                        knobs={"scheduler": "paged"}),
               Workload(label="disaggregated", arch=ARCH,
                        knobs={"scheduler": "disaggregated"})],
)
def trace_replay_serve_roles(wl: Workload):
    """A chunked-prefill-heavy staggered stream under
    ``TracingClock(SimClock)``, both loop compositions: the trace's
    role-lane decomposition (``lane_seconds(by="role")``) rides next to
    the engine's decode-step stall distribution — the decode
    interference picture before/after P/D disaggregation."""
    import numpy as np

    from repro.launch.serve import build_engine
    from repro.serving import Request
    from repro.serving.request import SimClock
    from repro.trace import TracingClock, replay

    sched = wl.knobs["scheduler"]
    clk = TracingClock(SimClock())
    kw = (dict(prefill_workers=1, decode_workers=2)
          if sched == "disaggregated" else {})
    eng, cfg = build_engine(
        ARCH, batch=2, prompt_len=16, max_new_tokens=12,
        scheduler=sched, page_size=4, prefill_chunk_tokens=4, clock=clk,
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128), **kw)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 16
                                        ).astype(np.int32),
                    max_new_tokens=12, arrival_s=45.0 * i)
            for i in range(8)]
    report = eng.run(reqs)
    s = report.summary()
    tr = clk.trace(f"serve/{ARCH}/{sched}-roles", arch=ARCH)
    tr.save(TRACE_DIR / f"{ARCH}-serve-{wl.label}-roles.json")
    res = replay(tr)
    lanes = tr.lane_seconds(by="role")
    yield BenchRecord(
        name=f"trace_replay/serve_{wl.label}_roles",
        us_per_call=TimingStats(
            [ev.cost_s * 1e6 for ev in tr.events if ev.cost_s > 0]),
        knobs={"scheduler": sched, "requests": len(reqs)},
        derived={
            "completed": report.completed,
            "busy_us": round(tr.measured_step_s * 1e6, 1),
            "predicted_us": round(res.predicted_s * 1e6, 1),
            "role_prefill_us": round(lanes.get("prefill", 0.0) * 1e6, 1),
            "role_decode_us": round(lanes.get("decode", 0.0) * 1e6, 1),
            "role_handoff_us": round(lanes.get("handoff", 0.0) * 1e6, 1),
            "decode_stall_p50_s": round(
                s.get("decode_stall_p50_s", 0.0), 4),
            "decode_stall_p95_s": round(
                s.get("decode_stall_p95_s", 0.0), 4),
            "handoffs": s.get("handoffs", 0),
            "gated": False,
        },
    )
