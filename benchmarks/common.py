"""Shared benchmark utilities: timing + subprocess-with-N-devices runner."""
from __future__ import annotations

import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def timeit_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    import jax
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-4000:]}")
    return proc.stdout
