"""Back-compat shim: the timing loop and fake-device subprocess runner
moved into the shared harness (:mod:`repro.bench.runner`). Import from
``repro.bench`` in new code."""
from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

from repro.bench.runner import run_with_devices, timeit_us  # noqa: E402,F401
