"""Tier-2 serving scenarios: request-level latency + deployment behavior
measured on this host (reduced models, CPU) through ``repro.serving``.

Five sweeps, the LLM-Inference-Bench (arXiv 2411.00136) metric set
applied to the paper's Tier-2 deployment axis:

* ``serving/goodput_vs_load``       — goodput + TTFT + per-token latency
  vs Poisson offered load (continuous scheduler);
* ``serving/static_vs_continuous``  — the schedulers head-to-head on the
  same burst workload with mixed decode budgets (the cell where
  continuous batching's slot backfill shows up as strictly higher
  goodput);
* ``serving/slot_balance``          — slot-occupancy load balance
  (Eq. 3 over KV slots) for uniform vs skewed budget mixes;
* ``serving/paged_vs_monolithic``   — the paged-KV engine against the
  monolithic continuous engine at *equal KV memory budget*
  (``SLOTS x span`` tokens) on the mixed-budget burst: paged admits
  strictly more concurrent requests (``peak_concurrency``) because
  admission reserves pages for actual request lengths, not whole spans;
* ``serving/paged_page_size``       — page size x offered load sweep
  recording page occupancy / internal fragmentation / goodput;
* ``serving/prefix_shared_burst``   — shared-system-prompt burst at
  equal KV budget, radix prefix cache off vs on: sharing must admit
  strictly more concurrently and save prefill tokens (token parity is
  gated by ``tools/ci_checks.py prefix-parity``);
* ``serving/multi_turn_replay``     — multi-turn session replay
  (``data/pipeline.synth_sessions``) off vs on: warm turns re-prefill
  only the newest turn, so warm TTFT < cold TTFT on the same schedule;
* ``serving/chaos_soak``            — a deadline/priority burst through
  the paged engine fault-free vs under the default seeded FaultPlan:
  goodput under faults, outcome taxonomy, preemption/requeue counters,
  and fault-recovery latency, with zero leaked pages asserted on both
  records (token parity under chaos is gated by ``tools/ci_checks.py
  chaos-parity``);
* ``serving/pd_disaggregation``     — a chunked-prefill-heavy staggered
  stream through the interleaved paged engine vs the P/D-disaggregated
  engine (separate prefill/decode worker pools, one shared page pool):
  per-role utilization, handoff latency p50/p95, and the decode-step
  stall distribution — the prefill-interference number disaggregation
  exists to shrink (token parity and the strict stall ordering are
  gated by ``tools/ci_checks.py pd-parity``).

Every record carries ``ttft_us`` (median time-to-first-token) and
per-token ``p50_us``/``p95_us`` stamped from the decode-step samples;
paged records add the page-pool fields from ``ServeReport.summary``.
The two prefix scenarios run under ``SimClock`` so their latency
orderings are schedule-determined (CI-stable), not host-noise-determined.
"""
from __future__ import annotations

import functools

from repro.bench import BenchRecord, Workload, scenario
from repro.bench.runner import TimingStats

ARCH = "granite-3-8b"
PROMPT = 8
SLOTS = 4
MAX_BUDGET = 24
N_REQ = 8
SPAN = PROMPT + MAX_BUDGET
# the monolithic engines' KV budget in tokens — the paged engines below
# get a pool of exactly this many token slots (incl. the null page)
BUDGET_TOKENS = SLOTS * SPAN
PAGED_LANES = 8                    # decode lanes; admission is page-bound

_PAGE_KEYS = ("page_size", "num_pages", "page_occupancy_mean",
              "page_occupancy_peak", "fragmentation_mean",
              "fragmentation_peak", "pages_high_water", "failed_allocs",
              "admission_blocked_steps",
              # prefix-sharing radix cache (cache-enabled records only)
              "prefix_hit_rate", "prefix_hits", "prefix_lookups",
              "prefill_tokens_saved", "pages_shared_peak",
              "prefix_evictions", "ttft_warm_p50_s", "ttft_cold_p50_s")


@functools.lru_cache(maxsize=2)
def _engine(scheduler: str):
    """One warmed engine per scheduler, built through the launcher's own
    ``build_engine`` plumbing (same RunConfig the CLI serves, smaller
    reduction cell); jit caches persist across workloads, so the measured
    runs never pay a compile. Returns (engine, cfg)."""
    from repro.launch.serve import build_engine

    eng, cfg = build_engine(
        ARCH, batch=SLOTS, prompt_len=PROMPT, max_new_tokens=MAX_BUDGET,
        scheduler=scheduler,
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128))
    eng.warmup(PROMPT)
    return eng, cfg


@functools.lru_cache(maxsize=4)
def _paged_engine(page_size: int):
    """Paged engine at the monolithic engines' exact KV memory budget:
    ``BUDGET_TOKENS // page_size`` pages total (one of which is the
    reserved null page). More decode lanes than the monolithic SLOTS —
    concurrency is bounded by free pages, which is the point."""
    from repro.launch.serve import build_engine

    eng, cfg = build_engine(
        ARCH, batch=PAGED_LANES, prompt_len=PROMPT,
        max_new_tokens=MAX_BUDGET, scheduler="paged",
        page_size=page_size, num_pages=BUDGET_TOKENS // page_size,
        prefill_chunk_tokens=PROMPT // 2,
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128))
    eng.warmup(PROMPT)
    return eng, cfg


def _requests(budgets, rate_per_s=0.0, n=N_REQ, seed=0):
    from repro.data.pipeline import synth_requests

    cfg = _engine("continuous")[1]
    return synth_requests(cfg, n, PROMPT, max_new_tokens=budgets,
                          rate_per_s=rate_per_s, seed=seed)


def _record(name, report) -> BenchRecord:
    s = report.summary()
    tok_us = [t * 1e6 for t in report.token_latency_samples_s()]
    derived = {
        "scheduler": s["scheduler"],
        "goodput_rps": round(s["goodput_rps"], 3),
        "goodput_tps": round(s["goodput_tps"], 1),
        "completed": s["completed"],
        "decode_steps": s["decode_steps"],
        "prefills": s["prefills"],
        "occupancy": round(s["occupancy"], 4),
        "peak_concurrency": s["peak_concurrency"],
        "slot_balance": round(s["slot_balance"], 4),
        "makespan_s": round(s["makespan_s"], 5),
    }
    for key in _PAGE_KEYS:          # present on paged reports only
        if key in s:
            v = s[key]
            derived[key] = round(v, 4) if isinstance(v, float) else v
    return BenchRecord(
        name=name,
        us_per_call=TimingStats(tok_us) if tok_us else 0.0,
        ttft_us=s["ttft_p50_s"] * 1e6,
        derived=derived)


@scenario(
    "serving/goodput_vs_load", tags=("tier2", "serving", "measured"),
    paper_ref="Tier-2 deployment (goodput vs offered load)",
    workloads=[Workload(label=f"load{int(r)}", arch=ARCH,
                        knobs={"offered_rps": r})
               for r in (0.0, 16.0, 64.0)])
def goodput_vs_load(wl: Workload):
    """Continuous scheduler under Poisson offered load (0 = burst)."""
    rate = wl.knobs["offered_rps"]
    reqs = _requests(budgets=(4, 12), rate_per_s=rate)
    report = _engine("continuous")[0].run(reqs)
    yield _record(f"serving/goodput_load{int(rate)}", report)


@scenario(
    "serving/static_vs_continuous", tags=("tier2", "serving", "measured"),
    paper_ref="Tier-2 deployment (scheduler comparison)",
    workloads=[Workload(label=sched, arch=ARCH, knobs={"scheduler": sched})
               for sched in ("static", "continuous")])
def static_vs_continuous(wl: Workload):
    """Both schedulers on one burst workload with mixed (2, 24) decode
    budgets: the static scheduler runs every batch to its longest member
    while the continuous scheduler backfills freed slots mid-stream."""
    sched = wl.knobs["scheduler"]
    reqs = _requests(budgets=(2, MAX_BUDGET))
    report = _engine(sched)[0].run(reqs)
    yield _record(f"serving/sched_{sched}", report)


@scenario(
    "serving/paged_vs_monolithic",
    tags=("tier2", "serving", "paged", "measured"),
    paper_ref="Tier-2 deployment (KV memory management)",
    workloads=[Workload(label="continuous", arch=ARCH,
                        knobs={"scheduler": "continuous"}),
               Workload(label="paged", arch=ARCH,
                        knobs={"scheduler": "paged", "page_size": 8})])
def paged_vs_monolithic(wl: Workload):
    """Mixed-budget burst at equal KV memory budget (SLOTS x span
    tokens): the monolithic engine reserves a whole span per slot and
    caps concurrency at SLOTS; the paged engine reserves pages for
    actual request lengths and admits strictly more requests at once
    (``peak_concurrency``), with page occupancy / fragmentation on the
    record. Greedy token parity between the two engines is gated
    separately by ``tools/ci_checks.py paged-parity``."""
    sched = wl.knobs["scheduler"]
    reqs = _requests(budgets=(2, MAX_BUDGET))
    if sched == "paged":
        eng = _paged_engine(wl.knobs["page_size"])[0]
    else:
        eng = _engine(sched)[0]
    yield _record(f"serving/paged_vs_mono_{sched}", eng.run(reqs))


@scenario(
    "serving/paged_page_size",
    tags=("tier2", "serving", "paged", "measured"),
    paper_ref="Tier-2 deployment (page size x offered load)",
    workloads=[Workload(label=f"ps{ps}_load{int(r)}", arch=ARCH,
                        knobs={"page_size": ps, "offered_rps": r})
               for ps in (4, 16) for r in (0.0, 64.0)])
def paged_page_size(wl: Workload):
    """Page size x offered load over the paged engine at a fixed pool
    budget: small pages cut internal fragmentation but grow the block
    table; the records carry occupancy/fragmentation/goodput so the
    trade-off is measured, not asserted."""
    ps, rate = wl.knobs["page_size"], wl.knobs["offered_rps"]
    reqs = _requests(budgets=(4, 12), rate_per_s=rate)
    report = _paged_engine(ps)[0].run(reqs)
    yield _record(f"serving/paged_ps{ps}_load{int(rate)}", report)


@functools.lru_cache(maxsize=4)
def _prefix_engine(prefix_cache: bool, span: int, num_pages: int,
                   page_size: int = 8, chunk: int = 16):
    """Paged engine pair for the prefix scenarios: identical pool budget
    and lanes, only the radix cache toggled. SimClock, so TTFT and
    admission orderings depend on the schedule alone."""
    from repro.launch.serve import build_engine
    from repro.serving import SimClock

    eng, cfg = build_engine(
        ARCH, batch=PAGED_LANES, prompt_len=span - MAX_BUDGET,
        max_new_tokens=MAX_BUDGET, scheduler="paged",
        page_size=page_size, num_pages=num_pages,
        prefill_chunk_tokens=chunk, prefix_cache=prefix_cache,
        clock=SimClock(),
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128))
    return eng, cfg


def _shared_burst(cfg, n=N_REQ, system_len=16, suffix_len=8, budget=8):
    """Burst of ``n`` requests sharing one system prompt with distinct
    user suffixes — the many-users-one-assistant admission pattern."""
    import numpy as np

    rng = np.random.default_rng(7)
    system = rng.integers(1, cfg.vocab_size, system_len).astype(np.int32)
    reqs = []
    from repro.serving import Request
    for i in range(n):
        suffix = rng.integers(1, cfg.vocab_size, suffix_len
                              ).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([system, suffix]),
                            max_new_tokens=budget, arrival_s=0.0))
    return reqs


@scenario(
    "serving/prefix_shared_burst",
    tags=("tier2", "serving", "paged", "prefix", "measured"),
    paper_ref="Tier-2 deployment (prefix sharing at equal KV budget)",
    workloads=[Workload(label="cache_off", arch=ARCH,
                        knobs={"prefix_cache": False}),
               Workload(label="cache_on", arch=ARCH,
                        knobs={"prefix_cache": True})])
def prefix_shared_burst(wl: Workload):
    """Shared-system-prompt burst at one fixed page budget: without
    sharing every request pays full pages for the common prefix and the
    pool caps concurrency early; with the radix cache the prefix is one
    physical page set under N block tables, so the same pool admits
    strictly more at once and skips the redundant prefill compute. The
    cross-engine assertions (strictly-more + token parity) are gated by
    ``tools/ci_checks.py prefix-parity``; the records carry the raw
    numbers."""
    pc = wl.knobs["prefix_cache"]
    span = 24 + 8                     # 16 system + 8 suffix + 8 budget
    eng, cfg = _prefix_engine(pc, span, num_pages=16)
    report = eng.run(_shared_burst(cfg))
    assert report.completed == N_REQ
    if pc:
        assert report.prefill_tokens_saved > 0, "cache on but nothing saved"
    yield _record(f"serving/prefix_burst_{'on' if pc else 'off'}", report)


@scenario(
    "serving/multi_turn_replay",
    tags=("tier2", "serving", "paged", "prefix", "measured"),
    paper_ref="Tier-2 deployment (multi-turn session replay)",
    workloads=[Workload(label="cache_off", arch=ARCH,
                        knobs={"prefix_cache": False}),
               Workload(label="cache_on", arch=ARCH,
                        knobs={"prefix_cache": True})])
def multi_turn_replay(wl: Workload):
    """Chat sessions replaying their accumulated history every turn
    (``synth_sessions``): with the cache on, turn t matches turn t-1's
    prompt pages and re-prefills only the newest turn, so warm-turn TTFT
    must beat cold-turn TTFT on the cache-enabled record (asserted here
    — SimClock makes the ordering structural). Hit rate and tokens saved
    ride on every record."""
    from repro.data.pipeline import synth_sessions

    pc = wl.knobs["prefix_cache"]
    turns, budget = 3, 8
    span = 32 + turns * 16 + budget   # longest final-turn prompt + budget
    eng, cfg = _prefix_engine(pc, span, num_pages=45)
    reqs = synth_sessions(cfg, 2, turns, max_new_tokens=budget,
                          think_s=200.0, stagger_s=60.0, seed=3)
    report = eng.run(reqs)
    assert report.completed == len(reqs)
    if pc:
        warm, cold = (report.ttft_warm_samples_s(),
                      report.ttft_cold_samples_s())
        assert warm and cold, "replay produced no warm/cold split"
        assert max(warm) < min(cold), (
            f"warm TTFT {warm} not strictly below cold TTFT {cold}")
        assert report.prefix_hit_rate > 0
    yield _record(f"serving/replay_{'on' if pc else 'off'}", report)


# robustness counters stamped onto chaos_soak records only (the keys are
# on every serving summary now, but the established scenarios keep their
# blessed derived-key sets stable)
_ROBUST_KEYS = ("n_timed_out", "n_preempted", "n_rejected", "n_failed",
                "preemption_events", "requeues", "retries",
                "faults_injected", "fault_recoveries",
                "recovery_steps_mean", "recovery_steps_max", "pages_leaked")


@functools.lru_cache(maxsize=1)
def _chaos_engine():
    """Paged engine under SimClock for the chaos soak: a deliberately
    tight pool (12 usable pages ~= 3 concurrent requests across 2 lanes)
    so injected pressure, refusals, and priority preemption actually
    bite, and a deterministic schedule so the faulted/fault-free goodput
    gap is structural, not host noise."""
    from repro.launch.serve import build_engine
    from repro.serving import SimClock

    eng, cfg = build_engine(
        ARCH, batch=2, prompt_len=18, max_new_tokens=6,
        scheduler="paged", page_size=4, num_pages=13,
        prefill_chunk_tokens=4, clock=SimClock(),
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128))
    return eng, cfg


def _slo_burst(cfg, n=8):
    """Staggered burst with deadlines and a half/half priority mix —
    the workload every robustness knob (reaper, preemption, requeue,
    fault containment) acts on."""
    import numpy as np

    from repro.serving import Request

    rng = np.random.default_rng(13)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 6 + 2 * (i % 3)
                                        ).astype(np.int32),
                    max_new_tokens=5 + (i % 2), arrival_s=0.5 * i,
                    deadline_s=600.0, priority=2 * (i % 2))
            for i in range(n)]


@scenario(
    "serving/chaos_soak",
    tags=("tier2", "serving", "paged", "faults", "measured"),
    paper_ref="Tier-2 deployment (goodput under injected faults)",
    workloads=[Workload(label="baseline", arch=ARCH,
                        knobs={"faults": False}),
               Workload(label="chaos", arch=ARCH, knobs={"faults": True})])
def chaos_soak(wl: Workload):
    """The same deadline/priority burst fault-free vs under the default
    seeded FaultPlan (alloc refusals, pool pressure, a slow step, a
    prefill error, pool poisoning): the pair measures goodput under
    faults and recovery latency. Both runs must drain the pool clean —
    a leaked page here is a real engine bug, not chaos."""
    from repro.serving import FaultPlan

    faulted = wl.knobs["faults"]
    eng, cfg = _chaos_engine()
    eng.fault_plan = FaultPlan.default(seed=0) if faulted else None
    try:
        report = eng.run(_slo_burst(cfg))
    finally:
        eng.fault_plan = None
    assert report.pages_leaked == 0, (
        f"{report.pages_leaked} pages leaked (faults={faulted})")
    s = report.summary()
    if faulted:
        assert s["faults_injected"] > 0, "fault plan injected nothing"
        assert s["fault_recoveries"] == s["faults_injected"], (
            f"unrecovered: {s['fault_recoveries']}/{s['faults_injected']}")
    rec = _record(
        f"serving/chaos_{'on' if faulted else 'off'}", report)
    for key in _ROBUST_KEYS:            # faults_* absent on the baseline
        if key in s:
            v = s[key]
            rec.derived[key] = round(v, 4) if isinstance(v, float) else v
    yield rec


# role/handoff/stall keys stamped onto pd_disaggregation records only
# (established scenarios keep their blessed derived-key sets stable)
_PD_KEYS = ("prefill_workers", "decode_workers", "prefill_util",
            "decode_util", "handoffs", "handoff_p50_s", "handoff_p95_s",
            "queue_depth_peak", "queue_depth_mean",
            "decode_stall_p50_s", "decode_stall_p95_s")


@functools.lru_cache(maxsize=2)
def _pd_engine(scheduler: str):
    """Interleaved/disaggregated engine pair for the P/D scenario:
    identical tiny model, page pool, lane count (2 lanes total on both
    sides), and chunked prefill — only the loop composition differs.
    SimClock, so the stall distribution is schedule-determined."""
    from repro.launch.serve import build_engine
    from repro.serving import SimClock

    kw = (dict(prefill_workers=1, decode_workers=2)
          if scheduler == "disaggregated" else {})
    eng, cfg = build_engine(
        ARCH, batch=2, prompt_len=16, max_new_tokens=12,
        scheduler=scheduler, page_size=4, prefill_chunk_tokens=4,
        clock=SimClock(),
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128), **kw)
    return eng, cfg


def _pd_stream(cfg, n=8, prompt_len=16, budget=12, stagger_s=45.0):
    """Chunked-prefill-heavy staggered stream: each arrival lands while
    earlier requests are mid-decode, so the interleaved loop must stall
    its live decode lanes for every multi-chunk prefill dispatch."""
    import numpy as np

    from repro.serving import Request

    rng = np.random.default_rng(5)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, prompt_len
                                        ).astype(np.int32),
                    max_new_tokens=budget, arrival_s=stagger_s * i)
            for i in range(n)]


@scenario(
    "serving/pd_disaggregation",
    tags=("tier2", "serving", "paged", "disagg", "measured"),
    paper_ref="Tier-2 deployment (P/D disaggregation)",
    workloads=[Workload(label="interleaved", arch=ARCH,
                        knobs={"scheduler": "paged"}),
               Workload(label="disaggregated", arch=ARCH,
                        knobs={"scheduler": "disaggregated"})])
def pd_disaggregation(wl: Workload):
    """The same staggered stream through both loop compositions: the
    interleaved engine prefills and decodes on one timeline (every
    multi-chunk prefill stalls the live decode lanes), the
    disaggregated engine runs separate prefill/decode worker pools over
    one shared page pool and hands pages off between roles. Records
    carry per-role utilization, handoff latency percentiles, ITL, and
    the decode-step stall distribution; the cross-record orderings are
    gated by ``tools/ci_checks.py pd-parity``."""
    sched = wl.knobs["scheduler"]
    eng, cfg = _pd_engine(sched)
    report = eng.run(_pd_stream(cfg))
    assert report.completed == len(report.metrics), (
        f"{sched}: {report.completed}/{len(report.metrics)} completed")
    s = report.summary()
    rec = _record(f"serving/pd_{wl.label}", report)
    for key in _PD_KEYS:        # role keys absent on the interleaved run
        if key in s:
            v = s[key]
            rec.derived[key] = round(v, 4) if isinstance(v, float) else v
    yield rec


@scenario(
    "serving/slot_balance", tags=("tier2", "serving", "measured"),
    paper_ref="Eq. 3 (load balance over KV slots)",
    workloads=[Workload(label="uniform", arch=ARCH,
                        knobs={"budgets": (8, 8)}),
               Workload(label="skewed", arch=ARCH,
                        knobs={"budgets": (2, 2, 2, MAX_BUDGET)})])
def slot_balance(wl: Workload):
    """Slot-occupancy load balance under uniform vs skewed budget mixes
    (continuous scheduler, burst arrivals)."""
    reqs = _requests(budgets=wl.knobs["budgets"])
    report = _engine("continuous")[0].run(reqs)
    yield _record(f"serving/slots_{wl.label}", report)
