"""Paper Table I / Fig. 6-7: resource-allocation ratio vs layer count and
hidden size, per compile mode (O0/O1/O3), from the Tier-1 section engine.

The paper varies GPT-2-style decoder blocks; we sweep the same knobs on a
granite-family reduced block over the 16x16 production mesh config. The
sweeps are declared as :class:`Workload` data; the shared runner times,
stamps, and sinks the records."""
from __future__ import annotations

import dataclasses

from repro.bench import (BENCH_MESH, BENCH_SHAPE, BenchRecord, Workload,
                         scenario, timeit_us)
from repro.configs import ARCHS, SHAPES

COMPILE_MODES = ("O0", "O1", "O3")


@scenario(
    "allocation/layers", tags=("tier1", "structural", "table1", "fig6"),
    paper_ref="Table I / Fig. 6",
    workloads=[Workload(label=f"layers{L}", arch="granite-3-8b",
                        shape=BENCH_SHAPE, mesh=BENCH_MESH,
                        knobs={"num_layers": L})
               for L in (6, 12, 24, 48)])
def allocation_layers(wl: Workload):
    """Allocation ratio (Eq. 2) vs layer count, all three compile modes."""
    from repro.core import sections

    cfg = dataclasses.replace(ARCHS[wl.arch],
                              num_layers=wl.knobs["num_layers"])
    # per-mode timeit (not one shared single-shot split three ways): the
    # per-iter samples let the compare gate's sign test veto jitter
    for m in COMPILE_MODES:
        rep = sections.analyze(cfg, wl.shape, wl.mesh, m)  # doubles as warmup
        us = timeit_us(sections.analyze, cfg, wl.shape, wl.mesh, m,
                       iters=5, warmup=0)
        yield BenchRecord(
            name=f"allocation/{wl.label}/{m}",
            us_per_call=us,
            knobs={"mode": m},
            derived={"alloc": round(rep.allocation, 4),
                     "n_sections": rep.n_sections})


@scenario(
    "allocation/hidden", tags=("tier1", "structural", "fig7"),
    paper_ref="Fig. 7b",
    workloads=[Workload(label=f"hs{hs}", arch="granite-3-8b",
                        shape=BENCH_SHAPE, mesh=BENCH_MESH,
                        knobs={"d_model": hs})
               for hs in (512, 1024, 2048, 4096)])
def allocation_hidden(wl: Workload):
    """Allocation ratio vs hidden size at fixed depth, O3 partitioning."""
    from repro.core import sections

    hs = wl.knobs["d_model"]
    nq = max(4, hs // 128)
    cfg = dataclasses.replace(ARCHS[wl.arch], d_model=hs, d_ff=4 * hs,
                              num_heads=nq, num_kv_heads=max(1, nq // 4),
                              head_dim=128, num_layers=12)
    rep = sections.analyze(cfg, wl.shape, wl.mesh, "O3")  # doubles as warmup
    us = timeit_us(sections.analyze, cfg, wl.shape, wl.mesh, "O3",
                   iters=5, warmup=0)
    yield BenchRecord(name=f"allocation/{wl.label}/O3", us_per_call=us,
                      knobs={"mode": "O3"},
                      derived={"alloc": round(rep.allocation, 4)})


@scenario(
    "allocation/archs", tags=("tier1", "structural", "table1"),
    paper_ref="Table I",
    workloads=[Workload(label=name, arch=name, shape=SHAPES["train_4k"],
                        mesh=BENCH_MESH)
               for name in sorted(ARCHS)])
def allocation_archs(wl: Workload):
    """Structural allocation at train_4k for every assigned architecture."""
    from repro.core import sections

    rep = sections.analyze(ARCHS[wl.arch], wl.shape, wl.mesh, "O3")
    yield BenchRecord(name=f"allocation/{wl.arch}/O3",
                      knobs={"mode": "O3"},
                      derived={"alloc": round(rep.allocation, 4)})
