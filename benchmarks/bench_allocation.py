"""Paper Table I / Fig. 6-7: resource-allocation ratio vs layer count and
hidden size, per compile mode (O0/O1/O3), from the Tier-1 section engine.

The paper varies GPT-2-style decoder blocks; we sweep the same knobs on a
granite-family reduced block over the 16x16 production mesh config."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import timeit_us
from repro.configs import ARCHS, MeshConfig, ShapeConfig, reduced
from repro.core import sections


def run():
    rows = []
    mesh = MeshConfig()          # 16x16
    base = ARCHS["granite-3-8b"]
    shape = ShapeConfig("bench", "train", 1024, 64)
    # --- layers sweep (paper Table I) ---
    for L in (6, 12, 24, 48):
        cfg = dataclasses.replace(base, num_layers=L)
        t0 = time.perf_counter()
        reps = {m: sections.analyze(cfg, shape, mesh, m) for m in
                ("O0", "O1", "O3")}
        us = (time.perf_counter() - t0) * 1e6
        for m, rep in reps.items():
            rows.append((f"allocation/layers{L}/{m}", us / 3,
                         f"alloc={rep.allocation:.4f}"))
    # --- hidden-size sweep (paper Fig. 7b) ---
    for hs in (512, 1024, 2048, 4096):
        nq = max(4, hs // 128)
        cfg = dataclasses.replace(base, d_model=hs, d_ff=4 * hs,
                                  num_heads=nq, num_kv_heads=max(1, nq // 4),
                                  head_dim=128, num_layers=12)
        t0 = time.perf_counter()
        rep = sections.analyze(cfg, shape, mesh, "O3")
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"allocation/hs{hs}/O3", us,
                     f"alloc={rep.allocation:.4f}"))
    # --- per assigned arch: structural allocation at train_4k ---
    from repro.configs import SHAPES
    for name, cfg in ARCHS.items():
        rep = sections.analyze(cfg, SHAPES["train_4k"], mesh, "O3")
        rows.append((f"allocation/{name}/O3", 0.0,
                     f"alloc={rep.allocation:.4f}"))
    return rows
