"""Planted RS101 violation: a bare assert guarding a runtime invariant."""


def reserve(slots: int, want: int) -> int:
    assert want <= slots, "pool overcommitted"  # dies under python -O
    return slots - want
