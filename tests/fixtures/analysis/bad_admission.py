"""Planted RS103 violations: an engine whose run() skips _validate and
whose admission_error override drops the base checks."""


class _EngineBase:
    def admission_error(self, r):
        return None

    def _validate(self, requests):
        return requests


class RogueEngine(_EngineBase):
    def admission_error(self, r):
        # override forgets super().admission_error(r): base checks lost
        return None if r else "empty"

    def run(self, requests):
        # never calls self._validate(requests): admission is bypassed
        return [self.admission_error(r) for r in requests]
