"""Planted RS102 violation: a direct page free outside _release_pages."""


class Reaper:
    def reap(self, alloc, rid: int) -> None:
        alloc.free(rid)  # bypasses the PagedEngine._release_pages seam
