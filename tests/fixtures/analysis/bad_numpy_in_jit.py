"""Planted RS105 violation: a numpy host op inside a jitted function."""

import numpy as np

import jax


def pool_step(state):
    live = np.asarray(state["active"])  # host round-trip inside jit
    return state, live.sum()


pool_step_jit = jax.jit(pool_step)
