"""Planted RS104 violation: wall-clock reads in a Sim-clock code path.

The ``repro.serving`` import marks this module as serving-scoped, which
is what puts it under the Sim-clock discipline.
"""

import time

from repro.serving.request import SimClock  # noqa: F401


def step_duration(engine) -> float:
    t0 = time.perf_counter()  # wall clock in a SimClock-driven loop
    engine.step()
    return time.time() - t0  # and again
