"""Use hypothesis when installed; otherwise a tiny deterministic fallback.

The tier-1 suite must collect and run on a clean environment where
``hypothesis`` isn't installed. Property tests import ``given``,
``settings``, and ``st`` from this module instead of from hypothesis:
with hypothesis present they get the real thing; without it they get a
deterministic sampler that draws a fixed number of pseudo-random examples
per test (seeded, so failures reproduce).

Only the strategy combinators the suite actually uses are implemented:
``sampled_from``, ``booleans``, ``floats``, ``integers``, ``lists``,
``tuples``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elements))

    st = _Strategies()

    class settings:  # noqa: N801 — mirrors hypothesis.settings
        _profiles = {}
        max_examples = 20

        def __init__(self, **kwargs):
            pass

        def __call__(self, fn):
            # real hypothesis settings instances decorate test functions;
            # the shim applies its module-wide example count instead
            return fn

        @classmethod
        def register_profile(cls, name, **kwargs):
            cls._profiles[name] = kwargs

        @classmethod
        def load_profile(cls, name):
            cls.max_examples = cls._profiles.get(name, {}).get(
                "max_examples", cls.max_examples)

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(settings.max_examples):
                    drawn_args = tuple(s.example(rng)
                                       for s in arg_strategies)
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)

            # pytest must not see the wrapped signature, or it would try
            # to inject the strategy parameters as fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco
