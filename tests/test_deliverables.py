"""Deliverable-integrity checks: the dry-run artifact matrix and the
Tier-2 scalability helpers. Skipped gracefully on a fresh clone (run
`python -m repro.launch.dryrun --all [--multi-pod]` to produce artifacts)."""
import json
from pathlib import Path

import pytest

from repro.configs import ARCHS, SHAPES
from repro.core.scalability import pp_bottleneck_model, pp_throughput_ratio
from repro.launch.cells import all_cells

RDIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _have_matrix(mesh):
    return RDIR.exists() and len(list(RDIR.glob(f"*_{mesh}.json"))) >= 32


@pytest.mark.parametrize("mesh", ["16x16", "2x16x16"])
def test_dryrun_matrix_complete(mesh):
    if not _have_matrix(mesh):
        pytest.skip(f"no {mesh} dry-run artifacts; run launch/dryrun.py")
    cells = {(a, s) for a, s in all_cells()}
    found = set()
    for f in RDIR.glob(f"*_{mesh}.json"):
        if "_opt" in f.name or "_nolicm" in f.name:
            continue
        rec = json.loads(f.read_text())
        found.add((rec["arch"], rec["shape"]))
        rl = rec["roofline"]
        assert rl["compute_s"] > 0 and rl["memory_s"] > 0
        assert rl["dominant"] in ("compute", "memory", "collective")
        assert rec["hlo"]["flops_per_device"] > 0
        # every train/prefill cell must move bytes over the interconnect
        if rec["shape"] != "long_500k":
            assert rec["hlo"]["collective_ici_bytes"] > 0
    assert found == cells, (cells - found, found - cells)


def test_40_cell_accounting():
    assert len(ARCHS) * len(SHAPES) == 40
    assert len(list(all_cells())) == 32   # + 8 noted long_500k skips


def test_pp_models():
    # balanced 4 stages beat a (1,1,1,5) split by ~max-stage ratio
    t_bal = pp_bottleneck_model([2, 2, 2, 2], per_layer_time=1.0,
                                n_microbatches=8)
    t_skew = pp_bottleneck_model([1, 1, 1, 5], per_layer_time=1.0,
                                 n_microbatches=8)
    assert t_skew / t_bal == pytest.approx(5 / 2)
    r = pp_throughput_ratio([2, 2, 2, 2], n_microbatches=8)
    assert 0 < r <= 1
    assert pp_throughput_ratio([1, 1, 1, 5], 8) < r
