"""Composable serving roles (repro.serving.roles) and the P/D
disaggregated engine: PageHandoff ownership invariants (never dual-held,
refcount-conserving, PoolInvariantError on protocol violations), the
extracted Scheduler's reaping/preemption/deadline-truncation policy, and
disaggregated-vs-interleaved greedy token parity on stub engines."""
from _hypothesis_compat import given, settings, st

import numpy as np
import pytest

from repro.serving import (DisaggregatedEngine, PageAllocator, PageHandoff,
                           PoolInvariantError, Request, Scheduler, SimClock,
                           prefill_owner)
from test_paged import (_paged_stub_engine, stub_chunk_prefill,
                        stub_paged_cache_init, stub_paged_decode)


def _release(alloc, key):
    """Stands in for the engine's bound ``_release_pages`` seam."""
    alloc.free(key)


def _handoff(num_pages=17, page_size=4):
    alloc = PageAllocator(num_pages=num_pages, page_size=page_size)
    return alloc, PageHandoff(alloc, _release, page_size)


def _disagg_stub_engine(**kw):
    kw.setdefault("clock", SimClock())
    return DisaggregatedEngine(stub_chunk_prefill, stub_paged_decode, None,
                               stub_paged_cache_init, **kw)


# ------------------------------------------------------------- handoff
def test_transfer_moves_ownership_and_conserves_refcounts():
    alloc, h = _handoff()
    pages = alloc.allocate(prefill_owner(5), 10)      # 3 pages
    assert h.roles_of(5) == (True, False)
    used_before = alloc.num_used
    got = h.transfer(5)
    assert got == pages
    assert h.roles_of(5) == (False, True)
    assert alloc.owned(5) == pages
    assert alloc.num_used == used_before              # net-zero refcounts
    assert h.handoffs == 1
    alloc.check()


def test_double_handoff_raises():
    alloc, h = _handoff()
    alloc.allocate(prefill_owner(5), 6)
    h.transfer(5)
    alloc.allocate(prefill_owner(5), 6)   # prefill re-reserves the rid
    with pytest.raises(PoolInvariantError, match="double handoff"):
        h.transfer(5)


def test_transfer_without_reservation_raises():
    _, h = _handoff()
    with pytest.raises(PoolInvariantError,
                       match="handoff without reservation"):
        h.transfer(9)


def test_abort_releases_prefill_hold():
    alloc, h = _handoff()
    alloc.allocate(prefill_owner(3), 8)
    h.abort(3)
    assert h.roles_of(3) == (False, False)
    assert alloc.num_owners == 0
    with pytest.raises(PoolInvariantError, match="holds no pages"):
        h.abort(3)
    alloc.check()


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 20)),
                    min_size=1, max_size=50))
def test_handoff_roles_never_overlap(ops):
    """Random grant/transfer/retire/abort sequences preserve the handoff
    invariants: a request's pages are never held by both roles at once,
    every op leaves the pool check()-clean, and draining both roles
    returns the pool to empty (refcounts conserved end to end)."""
    alloc, h = _handoff(num_pages=33)
    prefill_held, decode_held = set(), set()
    rid = 0
    for op, tokens in ops:
        if op == 0:                      # prefill reserves a new request
            if alloc.pages_needed(tokens) <= alloc.num_free:
                alloc.allocate(prefill_owner(rid), tokens)
                prefill_held.add(rid)
                rid += 1
        elif op == 1 and prefill_held:   # handoff to decode
            r = min(prefill_held)
            assert h.transfer(r)
            prefill_held.discard(r)
            decode_held.add(r)
        elif op == 2 and decode_held:    # decode retires
            r = min(decode_held)
            _release(alloc, r)
            decode_held.discard(r)
        elif op == 3 and prefill_held:   # prefill aborts
            r = max(prefill_held)
            h.abort(r)
            prefill_held.discard(r)
        for r in prefill_held | decode_held:
            pheld, dheld = h.roles_of(r)
            assert pheld == (r in prefill_held)
            assert dheld == (r in decode_held)
            assert not (pheld and dheld)
        assert alloc.num_owners == len(prefill_held) + len(decode_held)
        alloc.check()
    for r in sorted(prefill_held):
        h.abort(r)
    for r in sorted(decode_held):
        _release(alloc, r)
    assert alloc.num_owners == 0 and alloc.num_used == 0
    alloc.check()


# ----------------------------------------------------------- scheduler
def _req(rid, budget=2, **kw):
    return Request(rid, np.full(4, 2, np.int32), budget, **kw)


def test_scheduler_validate_seeds_queue_and_reaps_expired():
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=16)
    sched = Scheduler(eng)
    ok, rejected = sched.validate([_req(0, deadline_s=5.0),
                                   _req(1, arrival_s=1.0)])
    assert [r.rid for r in ok] == [0, 1] and not rejected
    assert sched.queue_depth() == 2 and sched.has_deadlines
    assert sched.reap_queued(3.0) == []          # not expired yet
    reaped = sched.reap_queued(20.0)
    assert [r.rid for r in reaped] == [0]
    assert sched.queue_depth() == 1


def test_pick_victim_lowest_priority_newest_strictly_below():
    eng = _paged_stub_engine(slots=3, cache_span=16, page_size=4,
                             num_pages=16)
    sched = Scheduler(eng)
    sched.validate([_req(0, priority=0), _req(1, priority=0),
                    _req(2, priority=5)])
    slot_rid = [0, 1, 2]
    active = np.array([True, True, True])
    admit_seq = [1, 2, 3]
    high = _req(9, priority=3)
    # both prio-0 lanes qualify; the later-admitted one (least sunk
    # prefill) is the victim
    assert sched.pick_victim(high, slot_rid, active, admit_seq) == 1
    equal = _req(10, priority=0)
    assert sched.pick_victim(equal, slot_rid, active, admit_seq) is None
    assert sched.pick_victim(high, slot_rid,
                             np.zeros(3, bool), admit_seq) is None


def test_deadline_truncate_no_deadline_counts_everything():
    n, t, out = Scheduler.deadline_truncate(10.0, [1.0] * 7, None)
    assert (n, t, out) == (8, 17.0, False)


def test_deadline_truncate_credits_only_pre_deadline_tokens():
    """The static-engine over-count case: first token at t=10, seven
    1s decode steps, deadline 12 — only tokens landing by the deadline
    (prefill + 2 decode) are credited, and the request times out."""
    n, t, out = Scheduler.deadline_truncate(10.0, [1.0] * 7, 12.0)
    assert (n, t, out) == (3, 12.0, True)


def test_deadline_truncate_late_first_token_keeps_one():
    n, t, out = Scheduler.deadline_truncate(10.0, [1.0] * 4, 5.0)
    assert (n, t, out) == (1, 10.0, True)


def test_deadline_truncate_exact_boundary_counts():
    # landing exactly on the deadline is a make (reapers use strict >)
    n, t, out = Scheduler.deadline_truncate(1.0, [1.0, 1.0], 3.0)
    assert (n, t, out) == (3, 3.0, False)


# -------------------------------------------------- disaggregated engine
def test_disagg_worker_count_validation():
    with pytest.raises(ValueError, match="divide evenly"):
        _disagg_stub_engine(slots=4, cache_span=16, page_size=4,
                            num_pages=16, decode_workers=3)
    with pytest.raises(ValueError, match=">= 1 worker"):
        _disagg_stub_engine(slots=4, cache_span=16, page_size=4,
                            num_pages=16, prefill_workers=0)


def test_disagg_token_parity_with_interleaved_stub():
    """Greedy tokens per request are identical between the interleaved
    paged loop and the disaggregated worker pools on a staggered
    stream, with exactly one handoff per request and no leaked pages."""
    span, n = 16, 6

    def reqs():
        return [_req(i, budget=3, arrival_s=0.5 * i) for i in range(n)]

    paged = _paged_stub_engine(slots=4, cache_span=span, page_size=4,
                               num_pages=16)
    disagg = _disagg_stub_engine(slots=4, cache_span=span, page_size=4,
                                 num_pages=16, prefill_workers=2,
                                 decode_workers=2)
    rp, rd = paged.run(reqs()), disagg.run(reqs())
    assert rp.completed == rd.completed == n
    toks_p = {m.rid: list(m.tokens) for m in rp.metrics}
    toks_d = {m.rid: list(m.tokens) for m in rd.metrics}
    assert toks_d == toks_p
    assert rd.handoffs == n
    assert rd.pages_leaked == 0
    assert rd.prefill_workers == 2 and rd.decode_workers == 2


def test_disagg_metrics_carry_role_assignments():
    disagg = _disagg_stub_engine(slots=2, cache_span=16, page_size=4,
                                 num_pages=16, decode_workers=2)
    rep = disagg.run([_req(i, budget=3) for i in range(4)])
    assert rep.completed == 4
    for m in rep.metrics:
        assert m.prefill_worker == 0          # single prefill worker
        assert m.decode_worker in (0, 1)
        assert m.handoff_latency_s >= 0.0
    assert len(rep.handoff_latencies_s) == rep.handoffs == 4
    s = rep.summary()
    assert 0.0 < s["prefill_util"] <= 1.0
    assert 0.0 < s["decode_util"] <= 1.0
    assert s["queue_depth_peak"] >= 1


def test_disagg_reaps_deadlines_per_role():
    """A queued request whose deadline passes before any prefill worker
    reaches it is reaped (timed_out) without ever holding pages."""
    disagg = _disagg_stub_engine(slots=1, cache_span=32, page_size=4,
                                 num_pages=16)
    rep = disagg.run([_req(0, budget=8, deadline_s=500.0),
                      _req(1, budget=8, deadline_s=15.0)])
    by_rid = {m.rid: m for m in rep.metrics}
    assert by_rid[0].outcome == "completed"
    # r1's deadline (15s) expires during r0's prefill+decode (SimClock:
    # 10s prefill + 8x1s decode), before the lone lane frees up
    assert by_rid[1].outcome == "timed_out"
    assert rep.pages_leaked == 0
