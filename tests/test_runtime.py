"""Fault-tolerance + runtime substrate: checkpoint atomicity/roundtrip,
resume, retry-on-failure, straggler watchdog, elastic re-mesh, data
determinism, optimizer behaviour."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_with_devices
from repro.checkpoint import checkpoint as ckpt
from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamW, warmup_cosine
from repro.runtime import train_loop
from repro.runtime.steps import build_train_step


def _tiny_rcfg():
    cfg = reduced(ARCHS["granite-3-8b"], layers=2, d_model=64, vocab=256,
                  d_ff=128)
    return RunConfig(model=cfg, shape=ShapeConfig("t", "train", 32, 2),
                     mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
                     param_dtype="float32", attention_backend="dense",
                     learning_rate=1e-3, warmup_steps=2)


# ------------------------------------------------------------ checkpoints
def test_checkpoint_roundtrip(tmp_ckpt_dir):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt.save(tmp_ckpt_dir, 7, tree)
    assert ckpt.available_steps(tmp_ckpt_dir) == [7]
    step, restored = ckpt.restore_latest(tmp_ckpt_dir, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_uncommitted_invisible(tmp_ckpt_dir):
    tree = {"a": jnp.zeros(3)}
    ckpt.save(tmp_ckpt_dir, 1, tree)
    # simulate crash-mid-save: step_2 exists but no _COMMITTED marker
    os.makedirs(os.path.join(tmp_ckpt_dir, "step_2"))
    assert ckpt.available_steps(tmp_ckpt_dir) == [1]
    step, _ = ckpt.restore_latest(tmp_ckpt_dir, tree)
    assert step == 1


def test_checkpoint_async(tmp_ckpt_dir):
    tree = {"a": jnp.ones((100, 100))}
    t = ckpt.save(tmp_ckpt_dir, 3, tree, blocking=False)
    t.join()
    assert ckpt.available_steps(tmp_ckpt_dir) == [3]


# ------------------------------------------------------------ train loop
def _loop_pieces(rcfg, total_steps=12):
    step_fn, model, opt = build_train_step(rcfg, total_steps=total_steps)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    data = SyntheticLM(rcfg.model, rcfg.shape.global_batch,
                       rcfg.shape.seq_len)
    return jax.jit(step_fn, donate_argnums=(0, 1)), params, opt_state, data


def test_train_loop_loss_decreases(tmp_ckpt_dir):
    rcfg = _tiny_rcfg()
    step_fn, params, opt_state, data = _loop_pieces(rcfg, 30)
    res = train_loop.run(step_fn, params, opt_state, data.batch_at,
                         total_steps=30, ckpt_dir=tmp_ckpt_dir,
                         ckpt_every=10)
    assert res.final_step == 30
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])
    assert res.checkpoints  # saved something


def test_train_loop_resume(tmp_ckpt_dir):
    rcfg = _tiny_rcfg()
    step_fn, params, opt_state, data = _loop_pieces(rcfg)
    r1 = train_loop.run(step_fn, params, opt_state, data.batch_at,
                        total_steps=8, ckpt_dir=tmp_ckpt_dir, ckpt_every=4)
    # fresh state; loop must resume from the checkpoint, not step 0
    step_fn2, params2, opt2, data2 = _loop_pieces(rcfg)
    r2 = train_loop.run(step_fn2, params2, opt2, data2.batch_at,
                        total_steps=12, ckpt_dir=tmp_ckpt_dir, ckpt_every=4)
    assert r2.resumed_from == r1.checkpoints[-1]
    assert r2.final_step == 12
    assert len(r2.losses) == 12 - (r2.resumed_from + 1)


def test_train_loop_retries_transient_failure(tmp_ckpt_dir):
    rcfg = _tiny_rcfg()
    step_fn, params, opt_state, data = _loop_pieces(rcfg)
    boom = {"left": 2}

    def injector(step):
        if step == 3 and boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("simulated node failure")

    res = train_loop.run(step_fn, params, opt_state, data.batch_at,
                         total_steps=6, max_retries=3,
                         fail_injector=injector)
    assert res.retries == 2
    assert res.final_step == 6


def test_train_loop_gives_up_after_max_retries():
    rcfg = _tiny_rcfg()
    step_fn, params, opt_state, data = _loop_pieces(rcfg)

    def injector(step):
        raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError):
        train_loop.run(step_fn, params, opt_state, data.batch_at,
                       total_steps=4, max_retries=2, fail_injector=injector)


def test_straggler_watchdog():
    wd = train_loop.StragglerWatchdog(k=2.0)
    for i in range(20):
        wd.observe(i, 0.1)
    assert wd.observe(20, 5.0)       # 50x slower step flagged
    assert wd.flagged and wd.flagged[-1][0] == 20


# ---------------------------------------------------------- elastic remesh
def test_elastic_remesh():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.checkpoint import checkpoint as ckpt
from repro.runtime.elastic import choose_mesh, remesh
from repro.launch.mesh import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

d = tempfile.mkdtemp()
# save from an 8-device (4,2) mesh
m8 = make_mesh(choose_mesh(8, prefer_model=2))
tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                            NamedSharding(m8, P("data", "model")))}
ckpt.save(d, 5, tree)
# restore onto a 4-device (2,2) mesh (simulating node loss)
cfg4 = choose_mesh(4, prefer_model=2)
mesh4, out = remesh(d, tree, cfg4, {"w": P("data", "model")})
assert out["step"] == 5
got = np.asarray(out["tree"]["w"])
np.testing.assert_array_equal(got, np.arange(64.0).reshape(8, 8))
n_shards = len(out["tree"]["w"].addressable_shards)
assert n_shards == 4, n_shards
print("OK")
""", n_devices=8)


def test_choose_mesh_splits():
    """`choose_mesh` on awkward device counts: model stays a divisor of
    n (halved until it divides), non-power-of-two counts fall back to
    wide data parallelism, and a 1-device fleet is always (1, 1)."""
    from repro.runtime.elastic import choose_mesh

    def shape(n, **kw):
        cfg = choose_mesh(n, **kw)
        assert cfg.axes == ("data", "model")
        d, m = cfg.shape
        assert d * m == n, f"{cfg.shape} does not cover {n} devices"
        return cfg.shape

    assert shape(1) == (1, 1)
    assert shape(8) == (4, 2)
    assert shape(16) == (4, 4)
    # non-power-of-two: model halves until it divides the count
    assert shape(6) == (3, 2)
    assert shape(12) == (6, 2)
    # prime count: model collapses to 1, pure data parallelism
    assert shape(7) == (7, 1)
    # prefer_model larger than the fleet clamps down to a divisor
    assert shape(4, prefer_model=16) == (1, 4)
    # a non-power-of-two preference is honored when it divides ...
    assert shape(6, prefer_model=3) == (2, 3)
    # ... and collapses via integer halving when it does not
    assert shape(8, prefer_model=3) == (8, 1)


def test_elastic_remesh_round_trip():
    """8 -> 4 -> 8 device round trip: each hop restores the full logical
    array bit-identically and lays it out across the hop's device count
    (shrink on node loss, re-expand when capacity returns)."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.checkpoint import checkpoint as ckpt
from repro.runtime.elastic import choose_mesh, remesh
from repro.launch.mesh import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

want = np.arange(64.0).reshape(8, 8)
pspecs = {"w": P("data", "model")}
d8 = tempfile.mkdtemp()
m8 = make_mesh(choose_mesh(8, prefer_model=2))
tree = {"w": jax.device_put(jnp.asarray(want),
                            NamedSharding(m8, P("data", "model")))}
ckpt.save(d8, 5, tree)

# shrink: restore the 8-device checkpoint onto 4 devices
mesh4, out4 = remesh(d8, tree, choose_mesh(4, prefer_model=2), pspecs)
assert out4["step"] == 5
np.testing.assert_array_equal(np.asarray(out4["tree"]["w"]), want)
assert len(out4["tree"]["w"].addressable_shards) == 4

# re-expand: checkpoint the resharded tree and restore back onto 8
d4 = tempfile.mkdtemp()
ckpt.save(d4, 6, out4["tree"])
mesh8, out8 = remesh(d4, out4["tree"], choose_mesh(8, prefer_model=2),
                     pspecs)
assert out8["step"] == 6
np.testing.assert_array_equal(np.asarray(out8["tree"]["w"]), want)
assert len(out8["tree"]["w"].addressable_shards) == 8
print("OK")
""", n_devices=8)


# ------------------------------------------------------------------- data
def test_data_determinism_and_sharding():
    cfg = reduced(ARCHS["granite-3-8b"])
    d = SyntheticLM(cfg, batch=8, seq=64, seed=1)
    b1 = d.batch_at(step=3)
    b2 = d.batch_at(step=3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d.batch_at(step=4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # shard 1 of 4 == rows 2:4 of the full batch
    sh = d.batch_at(step=3, shard=1, num_shards=4)
    np.testing.assert_array_equal(np.asarray(sh["tokens"]),
                                  np.asarray(b1["tokens"])[2:4])
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"])[:, 1:],
                                  np.asarray(b1["labels"])[:, :-1])


def test_data_prefetch_iterator():
    cfg = reduced(ARCHS["granite-3-8b"])
    d = SyntheticLM(cfg, batch=2, seq=32)
    it = d.iterate(start_step=0)
    b0 = next(it)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(d.batch_at(0)["tokens"]))
    it.close()


def test_iterate_respects_dp_sharding():
    """The prefetch producer must thread shard/num_shards through to
    batch_at — it used to always build the FULL global batch on every
    data-parallel host."""
    cfg = reduced(ARCHS["granite-3-8b"])
    d = SyntheticLM(cfg, batch=8, seq=32, seed=2)
    it = d.iterate(start_step=3, shard=1, num_shards=4)
    try:
        got = next(it)
    finally:
        it.close()
    want = d.batch_at(3, shard=1, num_shards=4)
    assert got["tokens"].shape[0] == 2              # 8 rows / 4 shards
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(want["tokens"]))


def test_iterate_builds_each_step_once_and_joins():
    """Against a full queue the producer must BLOCK on put, not recompute
    the same step's batch every timeout; and closing the generator must
    join the producer thread instead of leaving it running."""
    import threading
    import time

    cfg = reduced(ARCHS["granite-3-8b"])
    calls = []

    class Counting(SyntheticLM):
        def batch_at(self, step, shard=0, num_shards=1):
            calls.append(step)
            return super().batch_at(step, shard=shard,
                                    num_shards=num_shards)

    d = Counting(cfg, batch=2, seq=16)
    d.batch_at(999)        # warm lazy jnp/XLA pools off the thread delta
    calls.clear()
    before = set(threading.enumerate())
    it = d.iterate(start_step=0, prefetch=1)
    next(it)
    spawned = [t for t in threading.enumerate() if t not in before]
    assert spawned, "no producer thread spawned"
    # queue stays full from here: the producer sits blocked on put (it
    # used to re-call batch_at every 0.5 s while spinning on queue.Full)
    time.sleep(1.2)
    assert len(calls) == len(set(calls)), \
        f"steps recomputed while the queue was full: {sorted(calls)}"
    it.close()
    for t in spawned:
        assert not t.is_alive(), "producer thread not joined on close"


# -------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    opt = AdamW(lr_fn=lambda s: 0.05, weight_decay=0.0, grad_clip=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(300):
        g = {"x": 2 * params["x"]}
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_state_dtypes(state_dtype):
    opt = AdamW(lr_fn=lambda s: 0.05, weight_decay=0.0, grad_clip=1.0,
                state_dtype=state_dtype, use_master=state_dtype == "float32")
    params = {"w": jnp.ones((4, 32)) * 2.0}
    state = opt.init(params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, m = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.15
    assert np.isfinite(float(m["grad_norm"]))


def test_q8_roundtrip_error_bound():
    from repro.optim.adamw import _q8_decode, _q8_encode
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    dec = _q8_decode(_q8_encode(x))
    blockmax = np.abs(np.asarray(x)).reshape(64, -1, 16).max(-1)
    bound = (blockmax / 127.0).max() * 0.51
    assert float(jnp.abs(dec - x).max()) <= bound + 1e-6


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(1e-4, rel=0.05)
    assert float(lr(jnp.int32(5))) < float(lr(jnp.int32(10)))
