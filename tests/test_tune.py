"""Kernel autotuning subsystem: tuned-config cache round-trips,
deterministic winner selection with a fake timer, VMEM-budget rejection,
and an interpret-mode end-to-end tune of rmsnorm_fwd."""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.bench import tune
from repro.kernels import tuning


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    """Point the tuned-config cache at a scratch dir for every test."""
    monkeypatch.setenv(tuning.ENV_VAR, str(tmp_path / "tuned"))
    tuning.clear_cache()
    yield tmp_path / "tuned"
    tuning.clear_cache()


# ------------------------------------------------------------- cache I/O
def test_cache_round_trip_hit_and_miss(isolated_cache):
    sig = tuning.rmsnorm_signature(4096, 512, np.float32)
    key = tuning.entry_key("rmsnorm_fwd", sig)
    path = tuning.save_entries({key: {"config": {"block_rows": 1024},
                                      "us": 1.0, "default_us": 2.0}})
    assert path.parent == isolated_cache
    # reload from disk (save cleared the in-memory cache)
    assert tuning.lookup("rmsnorm_fwd", sig) == {"block_rows": 1024}
    # miss on a different shape-signature falls back to the defaults
    other = tuning.rmsnorm_signature(128, 128, np.float32)
    assert tuning.lookup("rmsnorm_fwd", other) is None
    assert tuning.resolve("rmsnorm_fwd", other)["block_rows"] == \
        tuning.DEFAULTS["rmsnorm_fwd"]["block_rows"]
    # tuned value resolves; an explicit caller override beats the cache
    assert tuning.resolve("rmsnorm_fwd", sig)["block_rows"] == 1024
    assert tuning.resolve("rmsnorm_fwd", sig,
                          block_rows=64)["block_rows"] == 64


def test_cache_env_fingerprint_invalidation(isolated_cache):
    sig = tuning.rmsnorm_signature(64, 64, np.float32)
    key = tuning.entry_key("rmsnorm_fwd", sig)
    tuning.save_entries({key: {"config": {"block_rows": 8}}})
    assert tuning.lookup("rmsnorm_fwd", sig) == {"block_rows": 8}
    # rewrite the file as if tuned on another machine/toolchain
    path = tuning.cache_path()
    data = json.loads(path.read_text())
    data["env"]["jax"] = "0.0.0-elsewhere"
    path.write_text(json.dumps(data))
    tuning.clear_cache()
    assert tuning.lookup("rmsnorm_fwd", sig) is None


def test_save_merges_entries(isolated_cache):
    s1 = tuning.rmsnorm_signature(64, 64, np.float32)
    s2 = tuning.rmsnorm_signature(128, 64, np.float32)
    tuning.save_entries({tuning.entry_key("rmsnorm_fwd", s1):
                         {"config": {"block_rows": 8}}})
    tuning.save_entries({tuning.entry_key("rmsnorm_fwd", s2):
                         {"config": {"block_rows": 16}}})
    assert tuning.lookup("rmsnorm_fwd", s1) == {"block_rows": 8}
    assert tuning.lookup("rmsnorm_fwd", s2) == {"block_rows": 16}


# --------------------------------------------- winner selection (faked)
def _fake_timer(times_by_rows):
    """Timer keyed on the candidate config carried in fn.keywords."""
    def timer(fn, *args, iters=1, warmup=0):
        return times_by_rows[fn.keywords["block_rows"]]
    return timer


def test_deterministic_winner_with_fake_timer():
    import jax.numpy as jnp

    x = jnp.zeros((1024, 128), jnp.float32)
    sc = jnp.ones((128,), jnp.float32)
    res = tune.tune_rmsnorm(
        x, sc, timer=_fake_timer({64: 30.0, 128: 20.0, 256: 50.0,
                                  512: 10.0, 1024: 40.0}))
    assert res.config == {"block_rows": 512}
    assert res.us == 10.0
    assert res.default_us == 50.0          # default (256) is candidate 0
    assert res.speedup == pytest.approx(5.0)
    assert res.n_candidates == 5


def test_tie_resolves_to_default():
    """Equal timings must keep the default config (candidate 0)."""
    import jax.numpy as jnp

    x = jnp.zeros((1024, 128), jnp.float32)
    sc = jnp.ones((128,), jnp.float32)
    res = tune.tune_rmsnorm(
        x, sc, timer=_fake_timer(dict.fromkeys(
            (64, 128, 256, 512, 1024), 7.0)))
    assert res.config == {"block_rows": 256}
    assert res.us == res.default_us == 7.0


# ------------------------------------------------------------ VMEM model
def test_vmem_budget_rejects_oversized_candidates():
    # full budget keeps every row count; a starved one drops the big ones
    full, rej_full, dflt = tune.rmsnorm_candidates(4096, 512, 4)
    assert [c["block_rows"] for c in full] == [256, 64, 128, 512, 1024]
    assert rej_full == 0 and dflt == {"block_rows": 256}
    small_budget = tune.rmsnorm_vmem_bytes(128, 512, 4)
    small, rej, dflt = tune.rmsnorm_candidates(4096, 512, 4,
                                               vmem_budget=small_budget)
    assert [c["block_rows"] for c in small] == [64, 128]
    assert rej == 3   # 256 (default), 512, 1024 rejected
    assert dflt is None   # the rejected default is not a baseline

    # attention: (512, 512) blocks blow a starved budget, default survives
    budget = tune.attention_vmem_bytes(256, 256, 64, 4)
    cands, rejected, dflt = tune.attention_candidates(512, 512, 64, 4,
                                                      vmem_budget=budget)
    assert cands[0] == dflt == {"block_q": 128, "block_k": 128}
    assert all(tune.attention_vmem_bytes(c["block_q"], c["block_k"], 64, 4)
               <= budget for c in cands)
    assert rejected > 0


def test_rejected_default_yields_neutral_speedup():
    """When the VMEM budget kills the default config, default_us must not
    be mislabeled from another candidate: speedup reports 1.0."""
    import jax.numpy as jnp

    x = jnp.zeros((4096, 512), jnp.float32)
    sc = jnp.ones((512,), jnp.float32)
    res = tune.tune_rmsnorm(
        x, sc, vmem_budget=tune.rmsnorm_vmem_bytes(128, 512, 4),
        timer=_fake_timer({64: 5.0, 128: 3.0}))
    assert res.config == {"block_rows": 128}
    assert not res.default_timed
    assert res.default_us == res.us == 3.0
    assert res.speedup == 1.0


def test_non_tiling_default_is_skipped_not_crashed():
    """Shapes the 128/64 defaults don't divide must sweep without hitting
    the kernels' divisibility asserts (default excluded, not timed)."""
    import jax.numpy as jnp

    # wkv6: T=96 -> default chunk 64 does not divide T
    cands, _, dflt = tune.wkv6_candidates(96, 16, 16, 4)
    assert dflt is None and [c["chunk"] for c in cands] == [16, 32]
    shape = (1, 96, 1, 16)
    z = jnp.zeros(shape, jnp.float32)
    ld = jnp.full(shape, -0.1, jnp.float32)

    def timer(fn, *a, iters=1, warmup=0):
        return {16: 2.0, 32: 1.0}[fn.keywords["chunk"]]

    res = tune.tune_wkv6(z, z, z, ld, timer=timer)
    assert res.config == {"chunk": 32} and not res.default_timed

    # attention: Sq=192 -> default 128 blocks don't tile the sequence
    cands, _, dflt = tune.attention_candidates(192, 192, 64, 4)
    assert dflt is None
    assert cands == [{"block_q": 192, "block_k": 192}]


def test_no_valid_candidates_raises():
    import jax.numpy as jnp

    x = jnp.zeros((64, 128), jnp.float32)
    sc = jnp.ones((128,), jnp.float32)
    with pytest.raises(ValueError, match="no valid tile candidates"):
        tune.tune_rmsnorm(x, sc, vmem_budget=1)


# ------------------------------------------------- end-to-end (interpret)
def test_rmsnorm_tune_end_to_end(isolated_cache):
    """Real interpret-mode sweep -> cache write -> auto resolution."""
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)
    sc = jnp.ones((128,), jnp.float32)
    res = tune.tune_rmsnorm(x, sc, iters=1, warmup=1)
    assert res.us <= res.default_us          # default is in the sweep
    path = tune.save([res])
    assert path.exists() and path.parent == isolated_cache

    # the ops wrapper's "auto" now resolves to the persisted winner...
    got = tuning.resolve_rmsnorm_rows(None, rows=512, d=128,
                                      dtype=np.float32)
    assert got == res.config["block_rows"]
    # ...and the kernel still computes the right thing with it
    out = ops.rmsnorm(x, sc)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.rmsnorm_ref(x, sc)),
                               atol=1e-5, rtol=1e-5)


def test_tune_result_record_fields():
    """TuneResult carries everything bench_tune folds into a record."""
    import jax.numpy as jnp

    x = jnp.zeros((256, 128), jnp.float32)
    sc = jnp.ones((128,), jnp.float32)
    res = tune.tune_rmsnorm(
        x, sc, timer=_fake_timer({64: 3.0, 128: 2.0, 256: 4.0}))
    key, entry = res.entry()
    assert key == tuning.entry_key("rmsnorm_fwd", res.signature)
    assert entry["config"] == {"block_rows": 128}
    assert entry["us"] == 2.0 and entry["default_us"] == 4.0
    assert set(res.timings) == {"block_rows=64", "block_rows=128",
                                "block_rows=256"}
