"""repro.trace: schema round-trip + validation, DAG replay correctness
(toposort, earliest-start, critical path), what-if edit monotonicity,
the serving TracingClock seam, cross-split prediction plumbing, and one
real 2-device matrix cell whose identity replay must sit inside the CI
gate's 25% bound (DESIGN.md §3)."""
import json

import pytest

from conftest import run_with_devices
from repro.serving import ContinuousEngine, SimClock
from repro.trace import (
    Trace,
    TraceError,
    TraceEvent,
    TracingClock,
    advise_from_trace,
    capture_matrix_cell,
    dag_from_cost_summary,
    load_trace,
    predict_split,
    replay,
    scale_kind,
    scale_op,
    set_cost,
    toposort,
)

from test_serving import (SPAN, _stub_requests, stub_cache_init,
                          stub_decode, stub_prefill)


def _diamond(costs=(0.0, 3.0, 5.0, 1.0)):
    """root -> {left, right} -> sink; right is the critical branch."""
    a, b, c, d = costs
    return Trace(name="diamond", events=[
        TraceEvent("root", "host", "dispatch", a),
        TraceEvent("left", "compute", "dot", b, deps=("root",)),
        TraceEvent("right", "memory", "copy", c, deps=("root",)),
        TraceEvent("sink", "host", "sync", d, deps=("left", "right")),
    ])


def _train_like(compute=4.0, memory=2.0):
    """A minimal capture_train_trace-shaped trace predict_split accepts."""
    tr = _diamond((0.0, compute, memory, 0.0))
    tr.meta.update({
        "split": [1, 1], "param_count": 1e5, "d_model": 128, "layers": 2,
        "tokens": 512, "bytes": 1.2e6, "flops": 3e8, "calibration_ratio": 50.0,
    })
    tr.measured_step_s = max(compute, memory)
    tr.samples_s = [tr.measured_step_s]
    return tr


# ------------------------------------------------------------------ schema
def test_trace_json_round_trip_is_byte_stable(tmp_path):
    tr = _diamond()
    tr.meta["calibration_ratio"] = 2.5
    back = Trace.from_json(tr.to_json())
    assert back.to_json() == tr.to_json()
    assert [e.eid for e in back.events] == ["root", "left", "right", "sink"]
    assert back.events[3].deps == ("left", "right")
    p = tr.save(tmp_path / "traces" / "diamond.json")
    loaded = load_trace(p)
    assert loaded.to_json() == tr.to_json()
    # the env fingerprint rides along like BenchRecord's
    assert "python" in loaded.env


def test_validate_rejects_structural_damage():
    dup = _diamond()
    dup.events.append(TraceEvent("left", "compute", "dot", 1.0))
    with pytest.raises(TraceError, match="duplicate"):
        dup.validate()
    neg = _diamond((0.0, -1.0, 5.0, 0.0))
    with pytest.raises(TraceError, match="negative"):
        neg.validate()
    dangling = _diamond()
    dangling.events[1] = TraceEvent("left", "compute", "dot", 3.0,
                                    deps=("ghost",))
    with pytest.raises(TraceError, match="unknown"):
        dangling.validate()


def test_newer_schema_version_is_refused():
    d = _diamond().to_dict()
    d["version"] = 999
    with pytest.raises(TraceError, match="newer"):
        Trace.from_dict(d)


# ------------------------------------------------------------------ replay
def test_toposort_respects_deps_in_any_input_order():
    events = list(reversed(_diamond().events))
    order = [ev.eid for ev in toposort(events)]
    assert order.index("root") < order.index("left") < order.index("sink")
    assert order.index("root") < order.index("right") < order.index("sink")


def test_toposort_names_cycle_members():
    cyc = [TraceEvent("a", "compute", deps=("b",)),
           TraceEvent("b", "compute", deps=("a",))]
    with pytest.raises(TraceError, match="cycle.*'a', 'b'"):
        toposort(cyc)


def test_identity_replay_is_earliest_start_over_the_dag():
    res = replay(_diamond())
    # parallel branches: sink starts when the slower branch finishes
    assert res.predicted_s == pytest.approx(6.0)
    assert res.finish_s["left"] == pytest.approx(3.0)
    assert res.finish_s["right"] == pytest.approx(5.0)
    assert res.critical_path == ["root", "right", "sink"]
    assert res.dominant_lane == "memory"


def test_replay_matches_recorded_step_on_decomposed_dag():
    """The capture-layer invariant the CI gate relies on: a DAG built by
    dag_from_cost_summary replays to the measured step exactly."""
    summary = {
        "flops_by_op": {"dot": 8e9, "add": 1e9, "exp": 5e8},
        "bytes_by_op": {"copy": 2e9, "fusion": 1e9},
        "collective_ici_by_op": {"all-reduce": 3e8},
    }
    measured = 0.125
    events, extras = dag_from_cost_summary(summary, measured, ops_per_lane=2)
    tr = Trace(name="cell", events=events, measured_step_s=measured,
               meta=extras)
    assert replay(tr).predicted_s == pytest.approx(measured, rel=1e-9)
    # the tail "other" event keeps lane totals exact despite ops_per_lane
    assert any(ev.op == "other" for ev in events)
    assert extras["calibration_ratio"] > 0


def test_empty_summary_falls_back_to_opaque_step():
    events, extras = dag_from_cost_summary({}, 0.5)
    tr = Trace(name="opaque", events=events, measured_step_s=0.5)
    assert replay(tr).predicted_s == pytest.approx(0.5)
    assert extras["calibration_ratio"] == 1.0


# ----------------------------------------------------------------- what-if
def test_edit_monotonicity_halving_never_increases_prediction():
    base = replay(_diamond()).predicted_s
    for edit in (scale_op("copy", 0.5), scale_kind("memory", 0.5),
                 scale_op("dot", 0.5), set_cost("right", 0.0)):
        assert replay(_diamond(), edits=[edit]).predicted_s <= base


def test_whatif_edit_can_shift_the_critical_path():
    # halving the memory branch (5.0 -> 2.5) hands the critical path to
    # the 3.0s compute branch; the 1.0s sink still runs after it
    res = replay(_diamond(), edits=[scale_kind("memory", 0.5)])
    assert res.predicted_s == pytest.approx(4.0)
    assert res.critical_path == ["root", "left", "sink"]
    assert res.dominant_lane == "compute"


def test_negative_edit_is_refused():
    with pytest.raises(TraceError, match="negative"):
        replay(_diamond(), edits=[scale_op("copy", -1.0)])


def test_predict_split_requires_train_capture_meta():
    with pytest.raises(TraceError, match="meta lacks"):
        predict_split(_diamond(), (2, 1))


def test_predict_split_scales_lanes_by_first_principles():
    tr = _train_like(compute=4.0, memory=2.0)
    same = predict_split(tr, (1, 1))
    # identity split: no collectives, lanes unchanged -> compute-bound
    assert same.predicted_s == pytest.approx(4.0)
    dp2 = predict_split(tr, (2, 1))
    # compute halves; DP adds a gradient all-reduce, so the prediction
    # can never undercut the pure-compute floor
    assert dp2.finish_s["compute"] == pytest.approx(2.0)
    assert dp2.predicted_s >= 2.0
    assert dp2.finish_s["collective"] > 0.0
    tp2 = predict_split(tr, (1, 2))
    assert tp2.finish_s["compute"] == pytest.approx(2.0)
    assert tp2.finish_s["collective"] > 0.0
    with pytest.raises(TraceError, match="bad split"):
        predict_split(tr, (0, 2))


# ---------------------------------------------------------- serving capture
def test_tracing_clock_records_busy_time_only():
    clk = TracingClock(SimClock(prefill_cost_s=10.0, decode_cost_s=1.0))
    clk.charge("prefill")
    clk.wait_until(clk.now() + 100.0)  # idle gap must not become an event
    clk.charge("decode", n=3)
    tr = clk.trace("serve/unit", n_devices=1)
    assert [ev.kind for ev in tr.events] == ["prefill", "decode"]
    assert tr.events[1].deps == (tr.events[0].eid,)
    assert tr.measured_step_s == pytest.approx(13.0)
    assert tr.meta["dispatches"] == {"prefill": 1, "decode": 1}
    # the dispatch chain replays to the engine's busy time exactly
    assert replay(tr).predicted_s == pytest.approx(13.0)


def test_tracing_clock_traces_a_real_engine_run():
    """Dropping TracingClock into ContinuousEngine at the clock seam must
    capture every dispatch without touching engine code."""
    clk = TracingClock(SimClock(prefill_cost_s=10.0, decode_cost_s=1.0))
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=2, cache_span=SPAN, clock=clk)
    report = eng.run(_stub_requests(3, budgets=(4,)))
    tr = clk.trace("serve/continuous")
    assert sum(tr.meta["dispatches"].values()) == len(tr.events)
    assert tr.meta["dispatches"]["prefill"] == report.prefills
    assert replay(tr).predicted_s == pytest.approx(tr.measured_step_s)


# ------------------------------------------------- real capture (2 devices)
def test_matrix_cell_capture_replays_within_the_ci_bound():
    """One real 2-device scaling-matrix cell end to end: subprocess
    capture -> JSON transport -> identity replay within the 25% gate."""
    traces = capture_matrix_cell(
        2, [(1, 2)], batch=4, seq=16,
        reduce_kw=dict(layers=2, d_model=64, d_ff=128, vocab=128),
        iters=3, warmup=1)
    assert len(traces) == 1
    tr = traces[0]
    assert (tr.mesh, tr.n_devices) == ("1x2", 2)
    assert tr.meta["split"] == [1, 2]
    res = replay(tr)
    rel = abs(res.predicted_s - tr.measured_step_s) / tr.measured_step_s
    assert rel <= 0.25, f"identity replay drifted {rel:.3f} from measured"
    # a TP cell must have a populated collective lane (the Megatron
    # activation psums are in the compiled module's per-device HLO)
    assert tr.lane_seconds().get("collective", 0.0) > 0.0
    # and the trace-calibrated advisor must run off this trace alone
    ranked = advise_from_trace(tr, 2)
    assert ranked and ranked[0].mesh.shape in [(2, 1), (1, 2)]
    assert ranked[0].step_s > 0.0


def test_capture_train_trace_requires_enough_devices():
    code = """
from repro.trace.capture import capture_train_trace
try:
    capture_train_trace(split=(8, 8), iters=1, warmup=0)
except RuntimeError as e:
    assert "needs 64 devices" in str(e), e
    print("REFUSED-OK")
"""
    assert "REFUSED-OK" in run_with_devices(code, n_devices=1)


def test_trace_json_survives_line_transport():
    """The subprocess transport contract: one trace per stdout line."""
    tr = _train_like()
    line = tr.to_json()
    assert "\n" not in line
    assert json.loads(line)["name"] == tr.name
