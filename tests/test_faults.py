"""Fault-tolerant serving: deadlines, priorities, preempt-and-requeue,
and the deterministic fault-injection harness (repro.serving.faults).

The load-bearing property is *chaos parity*: under every fault kind in
the default FaultPlan the paged engine must (a) contain each fault to
one request (retry/requeue or fail it alone), (b) keep the page pool
invariant-clean after every fault, (c) leak zero pages at drain, and
(d) emit byte-identical tokens for surviving requests vs a fault-free
run — faults may perturb scheduling, never numerics."""
import numpy as np
import pytest

from test_paged import _paged_stub_engine, _tiny_serve
from test_serving import stub_cache_init, stub_decode, stub_prefill

from repro.serving import (Fault, FaultInjector, FaultPlan, InjectedFault,
                           ContinuousEngine, PageAllocator, PagedEngine,
                           PoolInvariantError, Request, RequestQueue,
                           SimClock, StaticEngine, resolve_fault_plan)


def _req(rid, plen=8, budget=4, arrival=0.0, **kw):
    return Request(rid=rid, prompt=np.arange(1, plen + 1, dtype=np.int32),
                   max_new_tokens=budget, arrival_s=arrival, **kw)


def _outcomes(report):
    return {m.rid: m.outcome for m in report.metrics}


# ------------------------------------------------------------ FaultPlan
def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan.default(seed=3)
    path = plan.to_json(tmp_path / "plan.json")
    back = FaultPlan.from_json(path)
    assert back == plan
    assert resolve_fault_plan(None) is None
    assert resolve_fault_plan("none") is None
    assert resolve_fault_plan("default", 3) == plan
    assert resolve_fault_plan(str(path)) == plan


def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(step=0, kind="cosmic_ray")


def test_default_plan_covers_every_kind():
    kinds = {f.kind for f in FaultPlan.default().faults}
    assert kinds == {"alloc_refusal", "pool_pressure", "slow_step",
                     "prefill_error", "poison_pool"}


# --------------------------------------------------------- RequestQueue
def test_request_queue_priority_and_fifo_ties():
    q = RequestQueue([_req(0, arrival=0.0), _req(1, arrival=1.0),
                      _req(2, arrival=2.0, priority=5)])
    assert q.peek_best(0.5).rid == 0          # only arrival 0 is ready
    assert q.peek_best(2.5).rid == 2          # highest priority wins
    q.remove(q.peek_best(2.5))
    assert q.peek_best(2.5).rid == 0          # ties: earliest arrival
    assert q.next_arrival() == 0.0


def test_request_queue_pop_expired():
    q = RequestQueue([_req(0, deadline_s=5.0), _req(1, deadline_s=50.0),
                      _req(2)])
    dead = q.pop_expired(10.0)
    assert [r.rid for r in dead] == [0]
    assert len(q) == 2
    assert q.pop_expired(3.0) == []


# ------------------------------------------------------------ deadlines
def test_continuous_deadline_times_out_mid_decode():
    """SimClock: prefill 10s + 1s/token. A 12s deadline lets ~2 tokens
    out before the reaper retires the lane; a lax deadline completes."""
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=2, cache_span=32, clock=SimClock())
    rep = eng.run([_req(0, budget=8, deadline_s=12.0),
                   _req(1, budget=8, deadline_s=100.0)])
    out = _outcomes(rep)
    assert out[0] == "timed_out" and out[1] == "completed"
    m0 = next(m for m in rep.metrics if m.rid == 0)
    assert not m0.finished and 0 < m0.new_tokens < 8
    assert m0.tokens is not None and len(m0.tokens) == m0.new_tokens
    assert rep.summary()["n_timed_out"] == 1


def test_paged_deadline_reap_frees_pages_for_waiting_request():
    """The pool only fits one request; when the head misses its deadline
    its pages are reaped and the queued request admits and completes."""
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=5)
    rep = eng.run([_req(0, plen=8, budget=8, deadline_s=13.0),
                   _req(1, plen=8, budget=8, arrival=1.0)])
    out = _outcomes(rep)
    assert out[0] == "timed_out" and out[1] == "completed"
    assert rep.pages_leaked == 0
    assert rep.completed == 1


def test_paged_deadline_expires_in_queue():
    """A queued request whose deadline passes before any pages free is
    reaped without ever being admitted (no prefill burned on it)."""
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=5)
    rep = eng.run([_req(0, plen=8, budget=8),
                   _req(1, plen=8, budget=8, arrival=1.0, deadline_s=3.0)])
    m1 = next(m for m in rep.metrics if m.rid == 1)
    assert m1.outcome == "timed_out"
    assert m1.admitted_s == 0.0 and m1.new_tokens == 0
    assert rep.pages_leaked == 0


def test_static_deadline_marked_post_hoc():
    """Lockstep batches cannot evict mid-flight: a missed deadline is
    detected after the batch drains (via Scheduler.deadline_truncate)
    and excluded from goodput."""
    eng = StaticEngine(stub_prefill, stub_decode, None, stub_cache_init,
                       slots=2, cache_span=32, clock=SimClock())
    rep = eng.run([_req(0, budget=8, deadline_s=5.0),
                   _req(1, budget=8)])
    out = _outcomes(rep)
    assert out[0] == "timed_out" and out[1] == "completed"
    assert rep.completed == 1


def test_static_deadline_truncates_token_count():
    """Regression: the static engine used to credit every generated
    token to an expired request post hoc (new_tokens=8, finish at batch
    drain), over-counting work past the deadline. The extracted
    Scheduler.deadline_truncate rule credits only tokens that landed by
    the deadline, matching the per-step engines' reapers: SimClock puts
    the first token at t=10 and each decode step at +1s, so a 12s
    deadline covers exactly 3 tokens and the request finishes AT its
    deadline, not at batch drain (t=17)."""
    eng = StaticEngine(stub_prefill, stub_decode, None, stub_cache_init,
                       slots=2, cache_span=32, clock=SimClock())
    rep = eng.run([_req(0, budget=8, deadline_s=12.0),
                   _req(1, budget=8)])
    m0, m1 = (next(m for m in rep.metrics if m.rid == r) for r in (0, 1))
    assert m0.outcome == "timed_out"
    assert m0.new_tokens == 3                 # pre-fix: 8
    assert m0.finish_s == 12.0                # pre-fix: 17.0 (batch drain)
    assert len(m0.tokens) == 3
    assert len(m0.token_latencies_s) == 2
    assert m1.outcome == "completed" and m1.new_tokens == 8
    assert rep.completed == 1


# ----------------------------------------------------------- priorities
def test_paged_preempts_lower_priority_for_pages():
    """Pool fits one request. A higher-priority arrival evicts the
    running low-priority request, which requeues with its progress as a
    prompt extension and finishes after the VIP drains."""
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=5)
    rep = eng.run([_req(0, plen=8, budget=8),
                   _req(1, plen=8, budget=8, arrival=1.0, priority=5)])
    out = _outcomes(rep)
    assert out == {0: "completed", 1: "completed"}
    assert rep.preemption_events == 1 and rep.requeues == 1
    m0, m1 = (next(m for m in rep.metrics if m.rid == r) for r in (0, 1))
    assert m0.preemptions == 1 and m0.retries == 1
    assert m1.preemptions == 0
    # the VIP's first token beats the victim's finish
    assert m1.first_token_s < m0.finish_s
    # the victim still delivered its full budget across both stints
    assert m0.new_tokens == 8 and len(m0.tokens) == 8
    assert rep.pages_leaked == 0
    s = rep.summary()
    assert s["preemption_events"] == 1 and s["retries"] == 1


def test_paged_no_preemption_between_equal_priorities():
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=5)
    rep = eng.run([_req(0, plen=8, budget=8),
                   _req(1, plen=8, budget=8, arrival=1.0)])
    assert rep.preemption_events == 0
    assert _outcomes(rep) == {0: "completed", 1: "completed"}


def test_paged_preemption_retries_bounded():
    """max_retries=0: the first preemption is terminal — outcome
    `preempted`, partial tokens kept, pages returned."""
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=5)
    rep = eng.run([_req(0, plen=8, budget=8, max_retries=0),
                   _req(1, plen=8, budget=8, arrival=1.0, priority=5)])
    out = _outcomes(rep)
    assert out[0] == "preempted" and out[1] == "completed"
    m0 = next(m for m in rep.metrics if m.rid == 0)
    assert not m0.finished and m0.new_tokens >= 1
    assert rep.pages_leaked == 0
    assert rep.summary()["n_preempted"] == 1


# ------------------------------------------------------------ rejection
def test_reject_invalid_outcome_instead_of_raise():
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=2, cache_span=16, clock=SimClock(),
                           reject_invalid=True)
    rep = eng.run([_req(0, plen=8, budget=4),
                   _req(1, plen=8, budget=400)])       # cannot ever fit
    out = _outcomes(rep)
    assert out[0] == "completed" and out[1] == "rejected"
    assert rep.summary()["n_rejected"] == 1
    # strict default still raises
    strict = ContinuousEngine(stub_prefill, stub_decode, None,
                              stub_cache_init, slots=2, cache_span=16,
                              clock=SimClock())
    with pytest.raises(ValueError, match="exceeds"):
        strict.run([_req(1, plen=8, budget=400)])


# ------------------------------------------------------- fault injection
def _chaos_engine(plan, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("cache_span", 16)
    kw.setdefault("page_size", 4)
    return _paged_stub_engine(fault_plan=plan, **kw)


def test_alloc_refusal_blocks_then_recovers():
    plan = FaultPlan(faults=[Fault(step=0, kind="alloc_refusal", count=2)])
    eng = _chaos_engine(plan)
    rep = eng.run([_req(0), _req(1)])
    assert _outcomes(rep) == {0: "completed", 1: "completed"}
    assert rep.faults_injected == 1 and rep.fault_recoveries == 1
    assert rep.admission_blocked_steps >= 2       # the two refusals
    assert rep.pages_leaked == 0


def test_pool_pressure_window_blocks_admission():
    plan = FaultPlan(faults=[
        Fault(step=0, kind="pool_pressure", pages=100, duration=2)])
    eng = _chaos_engine(plan)
    rep = eng.run([_req(0, budget=4)])
    assert _outcomes(rep) == {0: "completed"}
    assert rep.faults_injected == 1 and rep.fault_recoveries == 1
    assert rep.admission_blocked_steps == 2
    assert rep.fault_recovery_steps == [2]        # lifted at step 2
    assert rep.pages_leaked == 0


def test_slow_step_stalls_clock_deterministically():
    base = _chaos_engine(None).run([_req(0, budget=4)])
    plan = FaultPlan(faults=[Fault(step=1, kind="slow_step", stall_s=7.0)])
    rep = _chaos_engine(plan).run([_req(0, budget=4)])
    assert rep.makespan_s == pytest.approx(base.makespan_s + 7.0)
    assert rep.faults_injected == 1 and rep.fault_recoveries == 1
    np.testing.assert_array_equal(rep.metrics[0].tokens,
                                  base.metrics[0].tokens)


def test_prefill_error_requeues_that_request_only():
    plan = FaultPlan(faults=[
        Fault(step=0, kind="prefill_error", req_index=0)])
    eng = _chaos_engine(plan)
    rep = eng.run([_req(0), _req(1, arrival=1.0)])
    assert _outcomes(rep) == {0: "completed", 1: "completed"}
    m0 = next(m for m in rep.metrics if m.rid == 0)
    assert m0.retries == 1
    assert rep.requeues == 1
    assert rep.faults_injected == 1 and rep.fault_recoveries == 1
    assert rep.pages_leaked == 0


def test_prefill_error_exhausted_retries_fails_alone():
    plan = FaultPlan(faults=[
        Fault(step=0, kind="prefill_error", req_index=0)])
    eng = _chaos_engine(plan)
    rep = eng.run([_req(0, max_retries=0), _req(1, arrival=1.0)])
    out = _outcomes(rep)
    assert out[0] == "failed" and out[1] == "completed"
    assert rep.summary()["n_failed"] == 1
    assert rep.pages_leaked == 0


def test_poison_pool_detected_and_healed():
    plan = FaultPlan(faults=[Fault(step=2, kind="poison_pool")])
    eng = _chaos_engine(plan)
    rep = eng.run([_req(0, budget=6)])
    assert _outcomes(rep) == {0: "completed"}
    assert rep.faults_injected == 1 and rep.fault_recoveries == 1
    assert rep.pages_leaked == 0


def test_real_corruption_still_escapes():
    """heal() only undoes the injector's own poison — corruption the
    injector did not cause must raise out of the engine."""
    alloc = PageAllocator(num_pages=5, page_size=4)
    alloc.allocate(0, 8)
    inj = FaultInjector(FaultPlan())
    alloc._free.append(1)                     # corruption with no poison
    with pytest.raises(PoolInvariantError):
        try:
            alloc.check()
        except PoolInvariantError:
            if not inj.heal(alloc):
                raise


def test_injected_fault_is_distinct_exception():
    assert issubclass(InjectedFault, RuntimeError)
    inj = FaultInjector(FaultPlan(faults=[
        Fault(step=0, kind="prefill_error", req_index=0)]))
    inj.begin_step(0, PageAllocator(5, 4), SimClock())
    with pytest.raises(InjectedFault):
        inj.check_prefill()
    inj.check_prefill()                       # consumed: second is clean


def test_default_plan_full_chaos_drains_clean():
    """The standard chaos mix over a contended workload: every request
    reaches a terminal outcome, the pool drains with zero leaks, and
    every fault recovers."""
    eng = _chaos_engine(FaultPlan.default(seed=0), num_pages=9)
    reqs = [_req(i, plen=8, budget=6, arrival=0.5 * i,
                 priority=i % 2) for i in range(5)]
    rep = eng.run(reqs)
    assert rep.faults_injected == 5
    terminal = {"completed", "timed_out", "preempted", "rejected", "failed"}
    assert all(m.outcome in terminal for m in rep.metrics)
    assert rep.pages_leaked == 0
    assert rep.fault_recoveries == rep.faults_injected
    s = rep.summary()
    assert s["recovery_steps_max"] >= 0 and s["pages_leaked"] == 0


# ----------------------------------------------------------- chaos parity
def test_chaos_parity_on_real_model():
    """Acceptance criterion: under the default FaultPlan, every request
    that completes does so with tokens byte-identical to the fault-free
    run — preemption resumes and fault retries re-derive the exact
    greedy continuation through re-prefill."""
    span, ps = 24, 4
    cfg, _, _, model, params = _tiny_serve(span=span, slots=2)
    eng = PagedEngine(model.prefill_chunk, model.decode_step_paged,
                      params, model.paged_cache_init, slots=2,
                      cache_span=span, page_size=ps, num_pages=13,
                      clock=SimClock())
    rng = np.random.default_rng(7)
    def mk():
        return [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            size=6 + 2 * (i % 3)
                                            ).astype(np.int32),
                        max_new_tokens=5 + (i % 2), arrival_s=0.5 * i,
                        priority=(2 if i == 3 else 0))
                for i in range(5)]
    rng = np.random.default_rng(7)
    base = eng.run(mk())
    rng = np.random.default_rng(7)
    eng.fault_plan = FaultPlan.default(seed=0)
    chaos = eng.run(mk())
    base_tok = {m.rid: m.tokens for m in base.metrics if m.finished}
    chaos_tok = {m.rid: m.tokens for m in chaos.metrics if m.finished}
    assert chaos_tok, "chaos run completed nothing"
    for rid, toks in chaos_tok.items():
        np.testing.assert_array_equal(
            toks, base_tok[rid],
            err_msg=f"survivor {rid} diverged under faults")
    assert chaos.pages_leaked == 0 and base.pages_leaked == 0
    assert chaos.faults_injected == 5


# ----------------------------------------- REPRO_DEBUG_POOL audit (S1)
def test_debug_pool_audit_raises_at_faulting_call_site(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_POOL", "1")
    a = PageAllocator(num_pages=6, page_size=4)
    assert a._audit
    a.allocate(0, 8)
    a.allocate(1, 4)
    a._free.append(a.owned(0)[0])             # corrupt: issued page freed
    with pytest.raises(PoolInvariantError):
        a.free(1)                             # raises HERE, not later


def test_debug_pool_audit_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_DEBUG_POOL", raising=False)
    a = PageAllocator(num_pages=6, page_size=4)
    assert not a._audit
    a.allocate(0, 8)
    a.allocate(1, 4)
    a._free.append(a.owned(0)[0])
    a.free(1)                                 # silent without the env
    with pytest.raises(PoolInvariantError):
        a.check()                             # only the explicit check sees it


# -------------------------------------------- CI gate (tools/ci_checks)
def test_chaos_parity_gate_passes_with_leak_self_test():
    """The committed chaos-parity CI gate runs end to end on the tiny
    real model: survivors token-identical, zero leaks, and its built-in
    self-test (no-op the page-release seam, require the leak detector
    to trip) — the exit-code contract the workflow step relies on."""
    import tools.ci_checks as ci_checks

    assert ci_checks.main(["chaos-parity"]) == 0
