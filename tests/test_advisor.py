"""Mesh advisor: analytic rankings must reproduce the measured §Perf
findings (EXPERIMENTS.md) and respect basic invariants."""
from repro.configs import ARCHS, SHAPES
from repro.core.mesh_advisor import advise, best_mesh


def _rank(archname):
    return [a.mesh.shape for a in advise(ARCHS[archname], SHAPES["train_4k"])]


def test_qwen110_prefers_narrow_model():
    """Measured: (64,4) beat (16,16) 2.1x. Advisor must agree on order."""
    ranks = _rank("qwen1.5-110b")
    assert ranks.index((64, 4)) < ranks.index((16, 16))
    assert ranks.index((32, 8)) < ranks.index((16, 16))


def test_rwkv_prefers_pure_dp():
    """Measured: (256,1) best. Advisor must rank DP-heavy splits first."""
    ranks = _rank("rwkv6-3b")
    assert ranks[0][1] <= 2              # model width 1 or 2 on top
    assert ranks.index((256, 1)) < ranks.index((16, 16))


def test_moe_prefers_wide_model():
    """ZeRO-3 MoE gathers scale with P/model: wider model wins."""
    ranks = _rank("arctic-480b")
    assert ranks.index((4, 64)) < ranks.index((32, 8))


def test_advice_invariants():
    for name in ("granite-3-8b", "qwen2.5-32b", "arctic-480b"):
        for a in advise(ARCHS[name], SHAPES["train_4k"]):
            assert a.compute_s > 0 and a.memory_s > 0
            assert a.hbm_gb > 0
            assert a.mesh.num_devices == 256
            assert SHAPES["train_4k"].global_batch % a.microbatches == 0
    # compute term is split-invariant (same flops / chips)
    adv = advise(ARCHS["granite-3-8b"], SHAPES["train_4k"])
    cs = {round(a.compute_s, 6) for a in adv}
    assert len(cs) == 1


def test_best_mesh_fits():
    a = best_mesh(ARCHS["qwen1.5-110b"], SHAPES["train_4k"])
    assert a.fits
