"""repro.bench.compare / repro.bench.baseline: blessed-baseline
round-trips, fingerprint gating, the noise-aware regression verdict
(p50 ratio + sign test), trajectory points, and the benchmarks.run
--compare/--bless CLI plumbing (in-process, no jax)."""
from __future__ import annotations

import random

import pytest

from repro.bench import (BenchRecord, BenchRunner, Scenario, TimingStats,
                         Workload, bless, compare_record, compare_records,
                         load_baselines, read_jsonl, read_trajectory,
                         write_jsonl)
from repro.bench.baseline import (baseline_path, blessable, fingerprint,
                                  fingerprint_compatible)
from repro.bench.compare import (FASTER, NEW, NOISY, OK, REGRESSION,
                                 SKIPPED, sign_test_p)

ENV = {"python": "3.10.16", "platform": "linux", "machine": "x86_64",
       "jax": "0.4.37", "backend": "cpu", "device_count": 1}


def rec(name="g/s", us=1000.0, samples=None, env=ENV, status="ok",
        p50=0.0):
    samples = list(samples or [])
    return BenchRecord(name=name, group="g", us_per_call=us, p50_us=p50,
                       samples_us=samples, status=status, env=dict(env))


# ------------------------------------------------------- baseline store
def test_baseline_bless_and_load_round_trip(tmp_path):
    records = [rec("g/a", us=100.0, samples=[90, 100, 110]),
               rec("g/b", us=200.0)]
    written = bless(records, tmp_path)
    assert set(written) == {"cpu"}
    assert written["cpu"] == baseline_path(tmp_path, "cpu")
    back = load_baselines(tmp_path, "cpu")
    assert set(back) == {"g/a", "g/b"}
    assert back["g/a"].samples_us == [90, 100, 110]
    assert load_baselines(tmp_path, "tpu") == {}  # never blessed


def test_bless_overwrites_by_name_and_keeps_others(tmp_path):
    bless([rec("g/a", us=100.0), rec("g/keep", us=50.0)], tmp_path)
    bless([rec("g/a", us=300.0)], tmp_path)  # re-bless one name
    back = load_baselines(tmp_path, "cpu")
    assert back["g/a"].us_per_call == 300.0   # overwritten
    assert back["g/keep"].us_per_call == 50.0  # untouched


def test_blessable_excludes_error_and_untimed_records():
    keep = rec("g/a", us=100.0)
    out = blessable([keep, rec("g/err", us=100.0, status="error"),
                     rec("g/analytic", us=0.0)])
    assert out == [keep]


def test_fingerprint_compatibility_rules():
    assert fingerprint_compatible(fingerprint(ENV), fingerprint(ENV))
    other = dict(ENV, jax="0.5.0")
    assert not fingerprint_compatible(fingerprint(ENV), fingerprint(other))
    # a key missing on one side never counts as a mismatch
    sparse = {"backend": "cpu"}
    assert fingerprint_compatible(sparse, fingerprint(ENV))


# ------------------------------------------------------------ verdicts
def test_compare_statuses_ok_new_faster():
    base = rec(us=1000.0)
    assert compare_record(rec(us=1000.0), base).status == OK
    assert compare_record(rec(us=1100.0), base).status == OK  # within tol
    assert compare_record(rec(us=100.0), base).status == FASTER
    assert compare_record(rec(us=1000.0), None).status == NEW


def test_fingerprint_mismatch_skips_not_fails():
    base = rec(us=1000.0, env=dict(ENV, jax="0.5.0"))
    res = compare_record(rec(us=5000.0), base)  # 5x slower, wrong env
    assert res.status == SKIPPED
    assert "fingerprint" in res.detail
    report = compare_records([rec(us=5000.0)], {"g/s": base})
    assert report.ok  # skips never fail the gate


def test_sub_min_us_baseline_is_skipped():
    res = compare_record(rec(us=90.0), rec(us=30.0))  # 3x but noise-sized
    assert res.status == SKIPPED


def test_regression_needs_ratio_and_sign_test():
    base = rec(us=1000.0, samples=[950, 1000, 1050, 1000, 990])
    # 2x slower, every sample above the old median: regression
    slow = rec(us=2000.0, samples=[1900, 2000, 2100, 2050, 1950])
    res = compare_record(slow, base)
    assert res.status == REGRESSION
    assert res.ratio == pytest.approx(2.0)
    # mean inflated by one spike, but samples straddle the old median:
    # the sign test vetoes the ratio -> noisy, not a failure
    spiky = rec(us=1400.0, samples=[900, 950, 1000, 980, 3170])
    res = compare_record(spiky, base)
    assert res.status == NOISY
    assert compare_records([spiky], {"g/s": base}).ok


def test_unanimous_samples_regress_even_below_significance():
    """4 samples can never reach alpha=0.05 (best p = 1/16), but when
    every sample sits above the old median there is no contrary evidence
    — the unanimity clause must still fail the gate."""
    base = rec(us=1000.0, samples=[950, 1000, 1050, 1000])
    slow = rec(us=2000.0, samples=[1900, 2000, 2100, 2050])
    res = compare_record(slow, base)
    assert res.status == REGRESSION
    # one straddling sample restores the noise veto
    spiky = rec(us=1600.0, samples=[900, 2000, 2100, 3400])
    assert compare_record(spiky, base).status == NOISY


def test_regression_without_samples_needs_a_larger_ratio_breach():
    """Sample-less records have no sign-test veto, so ordinary one-shot
    jitter (25-60%) must read as noisy; only a big breach regresses."""
    res = compare_record(rec(us=2000.0), rec(us=1000.0))  # 2x
    assert res.status == REGRESSION
    assert "ratio-only" in res.detail
    res = compare_record(rec(us=1400.0), rec(us=1000.0))  # 1.4x jitter
    assert res.status == NOISY
    assert "without samples" in res.detail


def test_sign_test_p_values():
    assert sign_test_p(5, 5) == pytest.approx(1 / 32)
    assert sign_test_p(4, 5) == pytest.approx(6 / 32)
    assert sign_test_p(0, 5) == pytest.approx(1.0)
    assert sign_test_p(0, 0) == 1.0


def test_threshold_verdict_is_deterministic_under_seeded_fake_timer():
    """Same seeded fake-timer samples -> byte-identical verdicts, and a
    borderline +34% drift whose samples straddle the old median stays
    `noisy` (never flaps to regression) run after run."""
    rng = random.Random(42)
    base_samples = sorted(1000.0 + rng.gauss(0, 30) for _ in range(5))
    base = rec(us=sum(base_samples) / 5, samples=base_samples,
               p50=base_samples[2])
    drift = [s * 1.5 if i != 0 else s * 0.7
             for i, s in enumerate(base_samples)]
    fresh = rec(us=sum(drift) / 5, samples=drift)
    verdicts = [compare_record(fresh, base) for _ in range(3)]
    assert all(v.status == verdicts[0].status for v in verdicts)
    assert verdicts[0].status == NOISY
    # a genuine seeded 2x slowdown is still caught every time
    slow = rec(us=2000.0, samples=[s * 2 for s in base_samples])
    assert all(compare_record(slow, base).status == REGRESSION
               for _ in range(3))


def test_compare_uses_p50_over_mean_when_available():
    base = rec(us=5000.0, p50=1000.0)
    fresh = rec(us=1000.0, p50=1000.0)
    res = compare_record(fresh, base)
    assert res.status == OK and res.ratio == pytest.approx(1.0)


# ------------------------------------------------- report + trajectory
def test_report_counts_geomean_and_trajectory(tmp_path):
    from repro.bench import append_trajectory

    base = {"g/a": rec("g/a", us=1000.0), "g/b": rec("g/b", us=1000.0)}
    report = compare_records(
        [rec("g/a", us=2000.0), rec("g/b", us=500.0), rec("g/new")],
        base)
    assert [r.name for r in report.regressions] == ["g/a"]
    c = report.counts()
    assert c[REGRESSION] == 1 and c[FASTER] == 1 and c[NEW] == 1
    assert report.geomean_ratio() == pytest.approx(1.0)  # 2.0 * 0.5
    traj = tmp_path / "trajectory.jsonl"
    append_trajectory(report.trajectory_point(extra={"git": "abc123"}),
                      traj)
    append_trajectory(report.trajectory_point(), traj)
    points = read_trajectory(traj)
    assert len(points) == 2
    assert points[0]["git"] == "abc123"
    assert points[0]["regressions"] == ["g/a"]
    assert points[0]["compared"] == 2


def test_runner_stamps_samples_us_from_timing_stats():
    scen = Scenario(
        name="_test/samples",
        fn=lambda wl: [BenchRecord(
            name="_test/samples/r",
            us_per_call=TimingStats([1.0, 2.0, 9.0]))],
        group="_test", workloads=(Workload(),))
    out = BenchRunner().run([scen]).records[0]
    assert out.samples_us == [1.0, 2.0, 9.0]
    back = BenchRecord.from_json_line(out.to_json_line())
    assert back.samples_us == [1.0, 2.0, 9.0]


# --------------------------------------------------------- CLI plumbing
def _cli(tmp_path, jsonl, *extra):
    import benchmarks.run as bench_run

    return bench_run.main([
        "--compare-only", "--json", str(jsonl),
        "--baseline-dir", str(tmp_path / "baselines"),
        "--trajectory", str(tmp_path / "trajectory.jsonl"), *extra])


def test_cli_bless_then_compare_then_injected_slowdown(tmp_path, capsys):
    jsonl = tmp_path / "latest.jsonl"
    records = [rec("g/a", us=1000.0, samples=[950, 1000, 1050, 990, 1010]),
               rec("g/b", us=400.0)]
    write_jsonl(records, jsonl)

    assert _cli(tmp_path, jsonl, "--bless") == 0
    assert load_baselines(tmp_path / "baselines", "cpu").keys() \
        == {"g/a", "g/b"}
    assert _cli(tmp_path, jsonl) == 0          # clean re-run passes

    import tools.ci_checks as ci_checks

    assert ci_checks.main(["inject-slowdown", "--factor", "2",
                           "--jsonl", str(jsonl)]) == 0
    assert read_jsonl(jsonl)[0].us_per_call == pytest.approx(2000.0)
    assert _cli(tmp_path, jsonl) == 3          # the gate trips
    err = capsys.readouterr().err
    assert "PERFORMANCE REGRESSION" in err
    # blessing the slowdown accepts it as the new baseline
    assert _cli(tmp_path, jsonl, "--bless") == 0
    assert _cli(tmp_path, jsonl) == 0
    points = read_trajectory(tmp_path / "trajectory.jsonl")
    assert len(points) == 5
    assert points[2]["regressions"] == ["g/a", "g/b"]


def test_cli_compare_only_without_records_errors(tmp_path):
    assert _cli(tmp_path, tmp_path / "missing.jsonl") == 2


def test_cli_compares_per_backend_without_shadowing(tmp_path):
    """Names repeat across backends; a cpu record must be diffed against
    the cpu baseline even when a tpu baseline of the same name exists
    (a flattened name-keyed dict would shadow it into a fingerprint
    skip and silently pass a real regression)."""
    jsonl = tmp_path / "latest.jsonl"
    tpu = rec("g/a", us=2000.0, env=dict(ENV, backend="tpu"))
    write_jsonl([rec("g/a", us=1000.0), tpu], jsonl)
    assert _cli(tmp_path, jsonl, "--bless") == 0
    assert set(load_baselines(tmp_path / "baselines", "tpu")) == {"g/a"}
    # cpu regresses 3x, tpu unchanged
    write_jsonl([rec("g/a", us=3000.0), tpu], jsonl)
    assert _cli(tmp_path, jsonl) == 3


def test_runner_sample_cap_strides_over_the_whole_run():
    """The 64-sample cap must subsample the full chronological sequence,
    not keep a head slice — a late-run degradation tail has to stay
    visible to the compare sign test."""
    samples = [100.0] * 60 + [500.0] * 60
    scen = Scenario(
        name="_test/cap",
        fn=lambda wl: [BenchRecord(name="_test/cap/r",
                                   us_per_call=TimingStats(samples))],
        group="_test", workloads=(Workload(),))
    out = BenchRunner().run([scen]).records[0]
    assert len(out.samples_us) == 64
    assert out.samples_us[0] == 100.0
    assert out.samples_us[-1] == 500.0
    assert sum(1 for s in out.samples_us if s == 500.0) >= 30
