"""Prefix-sharing subsystem: refcounted allocate/share/release/free
invariants (hypothesis), invariant checks that survive ``python -O``,
the radix cache's longest-prefix/insert/evict properties, the
multi-turn session workload, and end-to-end engine behavior — CoW
parity (shared-prefix decode greedy-token-identical to cold prefill),
byte-identical disabled-cache output, and warm-vs-cold TTFT."""
import os
import subprocess
import sys
from pathlib import Path

from _hypothesis_compat import given, settings, st

import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.pipeline import synth_sessions
from repro.serving import (PageAllocator, PagedEngine, PoolInvariantError,
                           RadixCache, Request, SimClock)

from test_paged import (_paged_stub_engine, _tiny_serve)


# ------------------------------------------------- refcounted allocator
def test_allocate_with_shared_pages():
    a = PageAllocator(num_pages=9, page_size=4)
    p1 = a.allocate(1, 16)                      # 4 pages, refcount 1
    a.share(p1[:2])                             # cache holds the prefix
    p2 = a.allocate(2, 16, shared=p1[:2])       # 2 shared + 2 fresh
    assert p2[:2] == p1[:2] and len(p2) == 4
    assert a.refcount(p1[0]) == 3               # owner 1, owner 2, cache
    assert a.num_free == 9 - 1 - 6              # 6 distinct pages in use
    a.check()
    # owner 1 retires: shared pages stay resident, its tail pages free
    freed = a.free(1)
    assert set(freed) == set(p1[2:])
    assert a.refcount(p1[0]) == 2
    # owner 2 retires: prefix survives on the cache's reference alone
    freed = a.free(2)
    assert set(freed) == set(p2[2:])
    assert a.refcount(p1[0]) == 1
    a.check()
    # the cache lets go: now the prefix pages actually free
    assert set(a.release(p1[:2])) == set(p1[:2])
    assert a.num_free == a.usable_pages
    a.check()


def test_allocate_shared_validation():
    a = PageAllocator(num_pages=9, page_size=4)
    with pytest.raises(ValueError, match="not issued"):
        a.allocate(1, 8, shared=[3])
    p1 = a.allocate(1, 8)
    with pytest.raises(ValueError, match="exceed"):
        a.allocate(2, 4, shared=p1)             # 2 shared > 1 page needed
    with pytest.raises(ValueError, match="not issued"):
        a.share([8])
    with pytest.raises(ValueError, match="not issued"):
        a.release([8])


def test_can_fit_counts_shared_pages():
    a = PageAllocator(num_pages=5, page_size=4)
    p1 = a.allocate(1, 16)                      # whole pool
    assert not a.can_fit(16)
    assert a.can_fit(16, shared_pages=4)        # fully cached: 0 fresh
    a.free(1)
    assert a.can_fit(16)
    assert p1


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 40)),
                    min_size=1, max_size=60),
       page_size=st.sampled_from([1, 4, 16]))
def test_refcount_random_share_release(ops, page_size):
    """Random allocate-with-sharing / share / release / free sequences
    preserve the refcount invariants: a page is never freed while its
    refcount is positive, free+used always partition the pool (counting
    distinct pages), and when every owner retires and the cache drops
    its holds, nothing leaks."""
    a = PageAllocator(num_pages=17, page_size=page_size)
    live = []                  # owners
    cache_held = []            # ownerless references, LIFO
    next_rid = 0
    for op, tokens in ops:
        if op == 0 or not live:               # allocate, maybe sharing
            donor = a.owned(live[-1]) if live else []
            need = a.pages_needed(tokens)
            shared = donor[:min(len(donor), need)]
            if need - len(shared) <= a.num_free:
                got = a.allocate(next_rid, tokens, shared=shared)
                assert got[:len(shared)] == shared
                for p in shared:
                    assert a.refcount(p) >= 2
                live.append(next_rid)
            else:
                with pytest.raises(MemoryError):
                    a.allocate(next_rid, tokens, shared=shared)
            next_rid += 1
        elif op == 1:                          # cache takes a reference
            pages = a.owned(live[0])
            a.share(pages)
            cache_held.append(pages)
        elif op == 2 and cache_held:           # cache drops a reference
            a.release(cache_held.pop())
        else:                                  # an owner retires
            rid = live.pop(0)
            held = a.owned(rid)
            before = {p: a.refcount(p) for p in held}
            freed = a.free(rid)
            for p in held:
                if before[p] > 1:              # still referenced: kept
                    assert p not in freed
                    assert a.refcount(p) == before[p] - 1
                else:
                    assert p in freed and a.refcount(p) == 0
        a.check()
    for rid in live:
        a.free(rid)
    for pages in cache_held:
        a.release(pages)
    assert a.num_free == a.usable_pages and a.num_used == 0
    a.check()


# ---------------------------------------------- check() under python -O
def test_pool_invariant_error_is_assertion_error():
    assert issubclass(PoolInvariantError, AssertionError)


def test_check_raises_on_corruption():
    a = PageAllocator(num_pages=5, page_size=4)
    a.allocate(1, 8)
    a._free.append(a._owned[1][0])             # corrupt: issued AND free
    with pytest.raises(PoolInvariantError, match="issued and free"):
        a.check()


def test_check_raises_under_disabled_asserts():
    """The invariant checks must stay live under ``python -O`` — a bare
    ``assert`` would be compiled away and corruption would pass
    silently. Run a corrupted pool through check() in a -O subprocess
    and require the explicit PoolInvariantError."""
    src = Path(__file__).resolve().parents[1] / "src"
    prog = (
        "import sys; assert not __debug__, 'run me with -O'\n"
        "from repro.serving import PageAllocator, PoolInvariantError\n"
        "a = PageAllocator(num_pages=5, page_size=4)\n"
        "a.allocate(1, 8)\n"
        "a._free.append(a._owned[1][0])\n"
        "try:\n"
        "    a.check()\n"
        "except PoolInvariantError:\n"
        "    sys.exit(0)\n"
        "sys.exit(1)\n"
    )
    res = subprocess.run([sys.executable, "-O", "-c", prog],
                         capture_output=True, text=True,
                         env={**os.environ, "PYTHONPATH": str(src)})
    assert res.returncode == 0, (res.stdout, res.stderr)


# ------------------------------------------------------------ radix cache
def _cache(num_pages=33, page_size=4):
    a = PageAllocator(num_pages=num_pages, page_size=page_size)
    return RadixCache(a), a


def test_radix_lookup_empty():
    c, _ = _cache()
    assert c.lookup(np.arange(1, 9)) == ([], 0)


def test_radix_insert_then_longest_prefix():
    c, a = _cache(page_size=4)
    seq = np.asarray([1, 2, 3, 4, 5, 6, 7, 8, 9, 10], np.int32)
    pages = a.allocate(0, len(seq))
    added = c.insert(seq, pages)
    assert added == 2                          # only the 2 full pages
    assert a.refcount(pages[0]) == 2           # owner + cache
    assert a.refcount(pages[2]) == 1           # partial page: not indexed
    # full match of both indexed pages
    got, n = c.lookup(np.asarray([1, 2, 3, 4, 5, 6, 7, 8, 42]))
    assert got == pages[:2] and n == 8
    # diverges inside page 2: only page 1 matches
    got, n = c.lookup(np.asarray([1, 2, 3, 4, 5, 0, 0, 0]))
    assert got == pages[:1] and n == 4
    # shorter than one page: no match
    assert c.lookup(np.asarray([1, 2, 3])) == ([], 0)


def test_radix_insert_existing_keeps_first_writer():
    c, a = _cache(page_size=4)
    s = np.asarray([1, 2, 3, 4], np.int32)
    p1 = a.allocate(0, 4)
    p2 = a.allocate(1, 4)
    assert c.insert(s, p1) == 1
    assert c.insert(s, p2) == 0                # duplicate content: kept
    assert c.lookup(s)[0] == p1
    assert a.refcount(p2[0]) == 1              # no extra cache reference


@settings(max_examples=25, deadline=None)
@given(lengths=st.lists(st.integers(1, 24), min_size=1, max_size=6),
       probe_len=st.integers(0, 30),
       page_size=st.sampled_from([2, 4]))
def test_radix_longest_prefix_property(lengths, probe_len, page_size):
    """Against a brute-force reference: after inserting arbitrary
    sequences drawn from a tiny alphabet (maximizing shared prefixes),
    lookup(probe) matches exactly the longest inserted page-aligned
    prefix of the probe."""
    rng = np.random.default_rng(sum(lengths) * 31 + probe_len)
    c, a = _cache(num_pages=257, page_size=page_size)
    inserted = set()                           # indexed chunk paths
    for i, n in enumerate(lengths):
        seq = rng.integers(1, 3, n).astype(np.int32)
        pages = a.allocate(i, max(n, 1))
        c.insert(seq, pages)
        full = (n // page_size) * page_size
        for k in range(page_size, full + 1, page_size):
            inserted.add(tuple(seq[:k]))
    probe = rng.integers(1, 3, probe_len).astype(np.int32)
    want = 0
    full = (probe_len // page_size) * page_size
    for k in range(page_size, full + 1, page_size):
        if tuple(probe[:k]) in inserted:
            want = k
        else:
            break
    pages, n = c.lookup(probe)
    assert n == want
    assert len(pages) == want // page_size


def test_radix_evicts_lru_refcount_one_only():
    c, a = _cache(num_pages=5, page_size=4)
    p1 = a.allocate(0, 8)
    c.insert(np.asarray([1, 2, 3, 4, 5, 6, 7, 8]), p1)
    a.free(0)                                  # cache is now sole holder
    p2 = a.allocate(1, 8)
    c.insert(np.asarray([9, 9, 9, 9, 8, 8, 8, 8]), p2)  # owner 1 lives
    assert a.num_free == 0
    # only p1's leaf is refcount-1; deeper p1 node frees on a second pass
    freed = c.evict(2)
    assert freed == 2 and a.num_free == 2 and c.evictions == 2
    assert c.lookup(np.asarray([1, 2, 3, 4]))[1] == 0
    # p2's nodes are pinned by owner 1's references
    assert c.evict(1) == 0
    assert c.lookup(np.asarray([9, 9, 9, 9]))[1] == 4
    a.check()


def test_radix_evict_respects_protect():
    c, a = _cache(num_pages=5, page_size=4)
    p1 = a.allocate(0, 8)
    c.insert(np.asarray([1, 2, 3, 4, 5, 6, 7, 8]), p1)
    a.free(0)
    assert c.evict(2, protect=frozenset(p1)) == 0
    assert c.lookup(np.asarray([1, 2, 3, 4, 5, 6, 7, 8]))[1] == 8
    assert c.evict(2) == 2                     # unprotected: both go


# -------------------------------------------------- session workload
def test_synth_sessions_replay_structure():
    cfg = get_arch("granite-3-8b")
    reqs = synth_sessions(cfg, 3, 4, system_len=8, turn_len=4,
                          think_s=5.0, stagger_s=20.0, seed=11)
    assert len(reqs) == 12
    assert [r.arrival_s for r in reqs] == sorted(r.arrival_s for r in reqs)
    by_session = {}
    for r in reqs:
        by_session.setdefault(r.rid // 100, []).append(r)
    system = reqs[0].prompt[:8]
    for sid, turns in by_session.items():
        turns.sort(key=lambda r: r.rid)
        for t, r in enumerate(turns):
            assert r.rid == sid * 100 + t
            assert r.prompt_len == 8 + 4 * (t + 1)
            np.testing.assert_array_equal(r.prompt[:8], system)
            if t:    # each turn extends the previous turn's prompt
                prev = turns[t - 1].prompt
                np.testing.assert_array_equal(r.prompt[:len(prev)], prev)
                assert r.arrival_s == turns[t - 1].arrival_s + 5.0
    # deterministic in the seed
    again = synth_sessions(cfg, 3, 4, system_len=8, turn_len=4,
                           think_s=5.0, stagger_s=20.0, seed=11)
    for r, s in zip(reqs, again):
        np.testing.assert_array_equal(r.prompt, s.prompt)
        assert (r.rid, r.arrival_s) == (s.rid, s.arrival_s)


# ------------------------------------------------------ engine behavior
def _shared_burst_reqs(n=6, budget=4):
    rng = np.random.default_rng(0)
    system = rng.integers(1, 100, 16).astype(np.int32)
    out = []
    for i in range(n):
        sfx = rng.integers(1, 100, 4).astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([system, sfx]),
                           max_new_tokens=budget))
    return out


def test_disabled_cache_is_byte_identical():
    """--no-prefix-cache must leave the paged engine's report exactly as
    the pre-cache scheduler produced it (satellite guarantee: enabling
    the feature flag off changes nothing)."""
    kw = dict(slots=4, cache_span=24, page_size=4, num_pages=25,
              prefill_chunk_tokens=8)
    base = _paged_stub_engine(**kw, clock=SimClock())
    off = _paged_stub_engine(**kw, prefix_cache=False, clock=SimClock())
    reqs = _shared_burst_reqs
    ra, rb = base.run(reqs()), off.run(reqs())
    assert not ra.prefix_enabled and not rb.prefix_enabled
    assert ra.summary() == rb.summary()
    for ma, mb in zip(ra.metrics, rb.metrics):
        np.testing.assert_array_equal(ma.tokens, mb.tokens)
        assert (ma.ttft_s, ma.finish_s, ma.slot) == (
            mb.ttft_s, mb.finish_s, mb.slot)


def test_prefix_cache_stub_shares_and_saves():
    kw = dict(slots=4, cache_span=24, page_size=4, num_pages=25,
              prefill_chunk_tokens=8)
    off = _paged_stub_engine(**kw, clock=SimClock())
    on = _paged_stub_engine(**kw, prefix_cache=True, clock=SimClock())
    ra, rb = off.run(_shared_burst_reqs()), on.run(_shared_burst_reqs())
    assert rb.prefix_hits > 0 and rb.prefill_tokens_saved > 0
    assert rb.pages_shared_peak > 0
    assert rb.prefix_hit_rate == rb.prefix_hits / rb.prefix_lookups
    for ma, mb in zip(ra.metrics, rb.metrics):
        np.testing.assert_array_equal(ma.tokens, mb.tokens)


def test_cow_parity_real_model():
    """Greedy decode from a shared cached prefix — including the
    copy-on-write path when the whole prompt is cached — emits exactly
    the tokens a cold prefill emits."""
    span = 24
    cfg, _, _, model, params = _tiny_serve(span=span)
    rng = np.random.default_rng(1)
    shared = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    branch = np.concatenate(
        [shared, rng.integers(1, cfg.vocab_size, 3).astype(np.int32)])
    # rid 1 re-sends rid 0's exact prompt (whole-prompt CoW), rid 2
    # extends the shared prefix (page-aligned warm suffix), rid 3 hits
    # with a 1-token budget (finishes at admission)
    reqs = lambda: [Request(0, shared.copy(), 5, 0.0),
                    Request(1, shared.copy(), 5, 30.0),
                    Request(2, branch.copy(), 5, 60.0),
                    Request(3, shared.copy(), 1, 90.0)]
    runs = {}
    for pc in (False, True):
        eng = PagedEngine(model.prefill_chunk, model.decode_step_paged,
                          params, model.paged_cache_init, slots=2,
                          cache_span=span, page_size=4,
                          prefill_chunk_tokens=4, clock=SimClock(),
                          prefix_cache=pc)
        runs[pc] = eng.run(reqs())
    toks = {pc: [list(m.tokens) for m in r.metrics]
            for pc, r in runs.items()}
    assert toks[True] == toks[False]
    on = runs[True]
    cached = {m.rid: m.cached_prompt_tokens for m in on.metrics}
    assert cached[0] == 0                      # cold: nothing indexed yet
    assert cached[1] == 7                      # whole prompt cached, CoW
    assert cached[2] == 8                      # aligned warm suffix
    assert on.prefill_tokens_saved == sum(cached.values())
    assert on.ttft_warm_samples_s() and on.ttft_cold_samples_s()


def test_multi_turn_replay_warm_beats_cold():
    """Session replay through the stub engine: every turn after the
    first is warm, and on a SimClock warm TTFT is strictly below cold
    TTFT (fewer prefill chunks)."""
    cfg = get_arch("granite-3-8b")
    reqs = synth_sessions(cfg, 2, 3, system_len=8, turn_len=4,
                          max_new_tokens=2, think_s=100.0,
                          stagger_s=40.0, seed=5)
    eng = _paged_stub_engine(slots=4, cache_span=24, page_size=4,
                             num_pages=40, prefill_chunk_tokens=4,
                             prefix_cache=True, clock=SimClock())
    rep = eng.run(reqs)
    assert rep.completed == len(reqs)
    warm, cold = rep.ttft_warm_samples_s(), rep.ttft_cold_samples_s()
    assert warm and cold
    assert max(warm) < min(cold)
    assert rep.prefix_hit_rate > 0.5
