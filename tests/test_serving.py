"""Request-level serving: the repro.serving schedulers, the decode-loop
bugfix regressions (first-token sampling, cache_span, per-token host
sync, warmup blocking), per-slot position correctness, EOS/budget
termination, slot reuse, and static-vs-continuous goodput ordering."""
import numpy

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
from repro.core import scalability
from repro.data.pipeline import poisson_arrivals, synth_requests
from repro.runtime import serve_loop
from repro.runtime.serve_loop import generate
from repro.runtime.steps import build_serve_steps
from repro.serving import (ContinuousEngine, Request, SimClock,
                           StaticEngine, engine as engine_mod, make_engine)

VOCAB = 17
SPAN = 16


# ------------------------------------------------------- stub model pieces
def stub_prefill(params, batch, cache_span):
    """Flat logits except a spike at token 1; caches with batch axis 1."""
    B = batch["tokens"].shape[0]
    logits = jnp.zeros((B, 1, VOCAB)).at[:, :, 1].set(100.0)
    return logits, {"k": jnp.zeros((1, B, cache_span, 2))}


def stub_decode(params, caches, tok, pos):
    """Deterministic next token = pos + 1 (clipped into the vocab).
    Handles both a scalar pos (lockstep) and a (B,) vector (continuous)."""
    pos_v = jnp.broadcast_to(jnp.atleast_1d(pos), (tok.shape[0],))
    lg = jax.nn.one_hot(jnp.minimum(pos_v + 1, VOCAB - 1), VOCAB) * 100.0
    return lg[:, None, :], caches


def stub_cache_init(batch, max_len, dtype=jnp.float32):
    return {"k": jnp.zeros((1, batch, max_len, 2), dtype)}


def _flat_prefill(params, batch, cache_span):
    """All-zero logits: argmax is 0, sampling is seed-dependent."""
    B = batch["tokens"].shape[0]
    return jnp.zeros((B, 1, VOCAB)), {"k": jnp.zeros((1, B, cache_span, 2))}


def _flat_decode(params, caches, tok, pos):
    B = tok.shape[0]
    return jnp.zeros((B, 1, VOCAB)), caches


def _stub_requests(n, prompt_len=4, budgets=(6,)):
    return [Request(rid=i, prompt=np.full(prompt_len, 2, np.int32),
                    max_new_tokens=budgets[i % len(budgets)])
            for i in range(n)]


# ------------------------------------------- bugfix regressions: generate
def test_generate_first_token_sampled():
    """greedy=False must sample the FIRST token too (it used to be argmax
    from the prefill logits regardless of the seed)."""
    seed = 123
    batch = {"tokens": jnp.zeros((4, 4), jnp.int32)}
    # legacy 2-arg prefill so this test runs (and fails) on pre-fix code
    res = generate(lambda p, b: _flat_prefill(p, b, SPAN), _flat_decode,
                   None, batch,
                   prompt_len=4, max_new_tokens=3, greedy=False, seed=seed)
    # mirror the documented key schedule: first split samples token 0
    key = jax.random.PRNGKey(seed)
    _, sub = jax.random.split(key)
    expect = np.asarray(
        jax.random.categorical(sub, jnp.zeros((4, 1, VOCAB))))[:, 0]
    np.testing.assert_array_equal(res.tokens[:, 0], expect)
    # flat logits: argmax would be identically 0; sampling must not be
    assert res.tokens[:, 0].any(), "first token still argmax'd"


def test_generate_greedy_unchanged():
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    res = generate(stub_prefill, stub_decode, None, batch,
                   prompt_len=4, max_new_tokens=4, greedy=True)
    np.testing.assert_array_equal(res.tokens[:, 0], [1, 1])
    # stub decode emits pos+1: positions 4,5,6 -> tokens 5,6,7
    np.testing.assert_array_equal(res.tokens[0], [1, 5, 6, 7])


def test_generate_honors_cache_span():
    """The cache_span argument must reach prefill (the old loop computed
    `span` and dropped it on the floor)."""
    seen = {}

    def recording_prefill(params, batch, cache_span):
        seen["span"] = cache_span
        return stub_prefill(params, batch, cache_span)

    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    generate(recording_prefill, stub_decode, None, batch,
             prompt_len=4, max_new_tokens=2, cache_span=99)
    assert seen["span"] == 99
    generate(recording_prefill, stub_decode, None, batch,
             prompt_len=4, max_new_tokens=2)        # default: prompt+new
    assert seen["span"] == 6


def test_generate_legacy_prefill_signature():
    """Pre-jitted (params, batch) closures keep working."""

    def legacy_prefill(params, batch):
        return stub_prefill(params, batch, SPAN)

    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    res = generate(legacy_prefill, stub_decode, None, batch,
                   prompt_len=4, max_new_tokens=3)
    assert res.tokens.shape == (2, 3)


class _CountingNp:
    """numpy facade counting asarray calls (host-transfer sites)."""

    def __init__(self):
        self.asarray_calls = 0

    def __getattr__(self, name):
        return getattr(numpy, name)

    def asarray(self, *a, **kw):
        self.asarray_calls += 1
        return numpy.asarray(*a, **kw)


def test_generate_single_host_transfer(monkeypatch):
    """Tokens accumulate on device: ONE host transfer after the loop, not
    one blocking np.asarray per decoded token."""
    fake = _CountingNp()
    monkeypatch.setattr(serve_loop, "np", fake)
    monkeypatch.setattr(engine_mod, "np", fake)
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    # legacy 2-arg prefill so this test runs (and fails) on pre-fix code
    res = generate(lambda p, b: stub_prefill(p, b, SPAN), stub_decode,
                   None, batch,
                   prompt_len=4, max_new_tokens=8)
    assert res.tokens.shape == (2, 8)
    assert fake.asarray_calls == 1, \
        f"{fake.asarray_calls} host transfers for 8 tokens"


def test_generate_rejects_zero_budget():
    """max_new_tokens=0 used to slip through (steps=-1 built a (B, 0)
    token buffer); now it is rejected with a clear error."""
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
        generate(stub_prefill, stub_decode, None, batch,
                 prompt_len=4, max_new_tokens=0)


def test_generate_single_token_budget_skips_decode_phase():
    """max_new_tokens=1: the whole output comes from prefill, so no
    decode step runs and decode_s must be exactly 0 — throughput used to
    be divided by the timing of an empty decode loop."""
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    res = generate(stub_prefill, stub_decode, None, batch,
                   prompt_len=4, max_new_tokens=1)
    assert res.decode_s == 0.0
    np.testing.assert_array_equal(res.tokens, [[1], [1]])
    assert res.total_new_tokens == 2
    assert res.tokens_per_s == pytest.approx(2 / res.prefill_s)


def test_generate_eos_throughput_counts_live_tokens():
    """Rows that retire early on eos_id contribute only their live
    prefix to tokens_per_s — not the full B * max_new_tokens the
    lockstep batch idled through."""
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    # stub decode emits pos+1 from prompt_len=4: tokens [1, 5, 6, 7, 8];
    # eos_id=6 terminates every row after its third token
    res = generate(stub_prefill, stub_decode, None, batch,
                   prompt_len=4, max_new_tokens=5, eos_id=6)
    np.testing.assert_array_equal(res.new_tokens, [3, 3])
    assert res.total_new_tokens == 6
    assert res.tokens_per_s == pytest.approx(
        6 / (res.prefill_s + res.decode_s))
    # without an eos the full budget counts, matching the old behavior
    res2 = generate(stub_prefill, stub_decode, None, batch,
                    prompt_len=4, max_new_tokens=5)
    np.testing.assert_array_equal(res2.new_tokens, [5, 5])
    assert res2.total_new_tokens == 10


# ------------------------------------- bugfix regression: measure_step
def test_measure_step_blocks_each_warmup(monkeypatch):
    """Every warmup call must be blocked (not just the last), otherwise
    queued warmup work leaks into the first timed iteration."""
    calls = []
    real = jax.block_until_ready

    def spy(x):
        calls.append(1)
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", spy)
    scalability.measure_step(lambda: jnp.zeros(3), (), iters=3, warmup=2)
    assert len(calls) == 2 + 3, f"blocked {len(calls)}x, want warmup+iters"


# ---------------------------------------------------- synthetic arrivals
def test_poisson_arrivals():
    a = poisson_arrivals(16, rate_per_s=8.0, seed=3)
    b = poisson_arrivals(16, rate_per_s=8.0, seed=3)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all() and a[0] > 0
    assert 0.5 < a[-1] < 8.0            # 16 arrivals at 8/s ~ 2s
    np.testing.assert_array_equal(poisson_arrivals(4, 0.0), np.zeros(4))


def test_synth_requests():
    cfg = reduced(ARCHS["granite-3-8b"])
    reqs = synth_requests(cfg, 6, 8, max_new_tokens=(2, 16), rate_per_s=4.0,
                          seed=1)
    assert [r.max_new_tokens for r in reqs] == [2, 16, 2, 16, 2, 16]
    assert all(r.prompt.shape == (8,) for r in reqs)
    assert all(r.prompt.min() >= 1 for r in reqs)   # 0 is reserved for EOS
    reqs2 = synth_requests(cfg, 6, 8, max_new_tokens=(2, 16),
                           rate_per_s=4.0, seed=1)
    np.testing.assert_array_equal(reqs[3].prompt, reqs2[3].prompt)
    assert reqs[3].arrival_s == reqs2[3].arrival_s


# --------------------------------------------------- continuous scheduler
def test_eos_and_budget_termination():
    """stub decode emits pos+1, so with eos_id=7 a request prefilled at
    length 4 stops after [1, 5, 6, 7]; a 2-token budget stops at [1, 5]."""
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=2, cache_span=SPAN, eos_id=7,
                           clock=SimClock())
    r = eng.run([Request(0, np.full(4, 2, np.int32), max_new_tokens=10),
                 Request(1, np.full(4, 2, np.int32), max_new_tokens=2)])
    m0, m1 = r.metrics
    assert m0.finished and m0.new_tokens == 4
    np.testing.assert_array_equal(m0.tokens, [1, 5, 6, 7])
    assert m1.finished and m1.new_tokens == 2
    np.testing.assert_array_equal(m1.tokens, [1, 5])
    assert r.completed == 2


def test_single_token_budget_finishes_at_admission():
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=1, cache_span=SPAN, clock=SimClock())
    r = eng.run(_stub_requests(2, budgets=(1,)))
    assert r.completed == 2 and r.decode_steps == 0
    for m in r.metrics:
        np.testing.assert_array_equal(m.tokens, [1])


def test_slot_reuse_under_continuous_batching():
    """5 requests through 2 slots: every request completes, freed slots
    are re-admitted mid-stream, and per-request token streams stay
    position-correct after reuse."""
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=2, cache_span=SPAN, clock=SimClock())
    reqs = _stub_requests(5, budgets=(4,))
    r = eng.run(reqs)
    assert r.completed == 5
    assert r.prefills == 5
    assert all(s >= 2 for s in r.slot_tokens)       # both slots reused
    assert sum(r.slot_tokens) == r.total_new_tokens == 5 * 4
    for m in r.metrics:                             # pos-derived stream
        np.testing.assert_array_equal(m.tokens, [1, 5, 6, 7])
    # 2 slots x 4-token budgets, 5 requests: ceil(5/2)*3 lockstep waves
    assert r.decode_steps == 9
    assert r.scheduler == "continuous"


def test_continuous_admits_by_arrival_time():
    """A request that hasn't arrived can't be admitted even if a slot is
    free; the pool idles forward to the next arrival."""
    clock = SimClock(prefill_cost_s=1.0, decode_cost_s=1.0)
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=2, cache_span=SPAN, clock=clock)
    reqs = [Request(0, np.full(4, 2, np.int32), 3, arrival_s=0.0),
            Request(1, np.full(4, 2, np.int32), 3, arrival_s=50.0)]
    r = eng.run(reqs)
    m1 = r.metrics[1]
    assert m1.admitted_s >= 50.0
    assert m1.ttft_s == pytest.approx(m1.first_token_s - 50.0)
    assert r.completed == 2


# ------------------------------------------------------ static scheduler
def test_static_lockstep_batches():
    eng = StaticEngine(stub_prefill, stub_decode, None, stub_cache_init,
                       slots=2, cache_span=SPAN, clock=SimClock())
    r = eng.run(_stub_requests(4, budgets=(2, 6)))
    assert r.completed == 4
    assert r.prefills == 2                  # two lockstep chunks
    assert r.decode_steps == 2 * 5          # each chunk runs to max budget
    for m in r.metrics:                     # budgets trimmed per request
        assert m.new_tokens == (2 if m.rid % 2 == 0 else 6)
    # short requests ride along: occupancy strictly below 1
    assert r.occupancy < 1.0


def test_static_rejects_mixed_prompt_lengths():
    eng = StaticEngine(stub_prefill, stub_decode, None, stub_cache_init,
                       slots=2, cache_span=SPAN, clock=SimClock())
    reqs = [Request(0, np.full(4, 2, np.int32), 2),
            Request(1, np.full(6, 2, np.int32), 2)]
    with pytest.raises(ValueError, match="equal prompt lengths"):
        eng.run(reqs)


def test_static_vs_continuous_goodput_ordering():
    """Deterministic SimClock comparison on a mixed-budget burst: the
    continuous scheduler backfills freed slots and must record strictly
    higher goodput than the lockstep static scheduler."""
    results = {}
    for sched in ("static", "continuous"):
        eng = make_engine(sched, stub_prefill, stub_decode, None,
                          stub_cache_init, slots=2, cache_span=SPAN,
                          clock=SimClock(prefill_cost_s=2.0,
                                         decode_cost_s=1.0))
        results[sched] = eng.run(_stub_requests(6, budgets=(2, 12)))
    st, ct = results["static"], results["continuous"]
    assert st.completed == ct.completed == 6
    assert ct.decode_steps < st.decode_steps
    assert ct.goodput_rps > st.goodput_rps
    assert ct.occupancy > st.occupancy


def test_engine_validates_requests():
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=1, cache_span=8, clock=SimClock())
    with pytest.raises(ValueError, match="exceeds cache_span"):
        eng.run([Request(0, np.full(4, 2, np.int32), max_new_tokens=5)])
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.run([Request(0, np.full(4, 2, np.int32), max_new_tokens=0)])
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_engine("fifo", stub_prefill, stub_decode, None,
                    stub_cache_init, slots=1, cache_span=8)


# --------------------------------------------- real-model slot decoding
def _tiny_serve(arch="granite-3-8b", span=24, slots=2):
    cfg = reduced(ARCHS[arch], layers=2, d_model=64, vocab=128, d_ff=128)
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("s", "decode", span, slots),
                     mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
                     attention_backend="dense", param_dtype="float32",
                     decode_attention="simple")
    prefill_fn, decode_fn, model = build_serve_steps(rcfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, prefill_fn, decode_fn, model, params


def _solo_greedy(prefill_fn, decode_fn, params, prompt, steps, span):
    """Reference: one request decoded alone with scalar positions."""
    logits, caches = prefill_fn(
        params, {"tokens": jnp.asarray(prompt[None])}, span)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    toks = [int(tok[0, 0])]
    for i in range(steps - 1):
        logits, caches = decode_fn(params, caches, tok,
                                   jnp.int32(len(prompt) + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    return toks


@pytest.mark.parametrize("arch", ["granite-3-8b", "rwkv6-3b"])
def test_pool_decode_matches_solo(arch):
    """Continuous batching is a scheduling change, not a numerics change:
    requests with different prompt lengths decoded via per-slot vector
    positions in a shared pool must emit exactly the tokens they emit
    when decoded alone — including after slot reuse."""
    span = 24
    cfg, prefill_fn, decode_fn, model, params = _tiny_serve(arch, span=span)
    rng = np.random.default_rng(0)
    pA = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    pB = rng.integers(1, cfg.vocab_size, size=9).astype(np.int32)
    refA = _solo_greedy(prefill_fn, decode_fn, params, pA, 5, span)
    refB = _solo_greedy(prefill_fn, decode_fn, params, pB, 5, span)

    eng = ContinuousEngine(prefill_fn, decode_fn, params, model.cache_init,
                           slots=2, cache_span=span, clock=SimClock())
    rep = eng.run([Request(0, pA, 5), Request(1, pB, 5),
                   Request(2, pA, 5)])          # rid 2 reuses a slot
    assert [list(m.tokens) for m in rep.metrics] == [refA, refB, refA]


def test_vector_pos_matches_scalar_pos():
    """decode_step with a (B,) pos vector of equal entries must equal the
    scalar-pos decode (same caches, same tokens)."""
    span = 16
    cfg, prefill_fn, decode_fn, model, params = _tiny_serve(span=span)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(1, 128, (3, 6)), jnp.int32)}
    logits, caches = prefill_fn(params, batch, span)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    l_s, c_s = decode_fn(params, caches, tok, jnp.int32(6))
    l_v, c_v = decode_fn(params, caches, tok, jnp.full((3,), 6, jnp.int32))
    np.testing.assert_allclose(np.asarray(l_v), np.asarray(l_s), atol=1e-5)
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ----------------------------------------------------------- report math
def test_report_summary_fields():
    eng = ContinuousEngine(stub_prefill, stub_decode, None, stub_cache_init,
                           slots=2, cache_span=SPAN,
                           clock=SimClock(prefill_cost_s=2.0,
                                          decode_cost_s=1.0))
    r = eng.run(_stub_requests(4, budgets=(3,)))
    s = r.summary()
    assert s["completed"] == 4 and s["scheduler"] == "continuous"
    assert s["goodput_rps"] == pytest.approx(4 / r.makespan_s)
    assert 0.0 < s["occupancy"] <= 1.0
    assert 0.0 <= s["slot_balance"] <= 1.0
    assert s["tok_p50_s"] == pytest.approx(1.0)     # SimClock decode cost
    assert s["ttft_p50_s"] >= 2.0                   # at least one prefill


def test_slot_load_balance_metric():
    from repro.core.metrics import slot_load_balance

    assert slot_load_balance([8, 8, 8]) == pytest.approx(1.0)
    assert slot_load_balance([8, 8, 0]) == 0.0      # a starved slot
    assert 0.0 < slot_load_balance([8, 4, 8]) < 1.0
    assert slot_load_balance([]) == 1.0
