"""tools/ci_checks.py: every CI gate assertion must reproduce locally
against a JSONL file, pass on healthy records, and name the offender on
violation (no jax needed — synthetic records only)."""
from __future__ import annotations

import pytest

import tools.ci_checks as ci_checks
from repro.bench import BenchRecord, write_jsonl


def _serving_records(static_rps=98.0, continuous_rps=150.0):
    mk = lambda name, rps: BenchRecord(
        name=name, group="serving", us_per_call=500.0, p50_us=450.0,
        p95_us=900.0, ttft_us=1200.0, derived={"goodput_rps": rps})
    return [mk("serving/sched_static", static_rps),
            mk("serving/sched_continuous", continuous_rps)]


def _matrix_records(pp_tok=(75000.0, 58000.0, 43000.0), model_ok=True):
    recs = []
    for n in (1, 2, 4, 8):
        recs.append(BenchRecord(
            name=f"scaling_matrix/dp{n}", group="scaling_matrix",
            us_per_call=1000.0 * n,
            derived={"efficiency": 1.0 / n, "collective_frac": 1 - 1.0 / n,
                     "shard_balance": 1.0}))
    for n in (2, 4, 8):
        recs.append(BenchRecord(
            name=f"scaling_matrix/tp{n}", group="scaling_matrix",
            us_per_call=900.0 * n,
            derived={"efficiency": 1.2 / n, "collective_frac": 0.5,
                     "shard_balance": 1.0 if n <= 4 else 0.0}))
    for split, max_stage, tok in zip(
            ("2-2-2-2", "1-2-2-3", "1-1-1-5"), (2, 3, 5), pp_tok):
        recs.append(BenchRecord(
            name=f"scaling_matrix/pp_{split}", group="scaling_matrix",
            us_per_call=1e6 / tok,
            derived={"max_stage": max_stage, "tok_s": tok,
                     "model_ratio": 0.9, "model_ok": model_ok,
                     "stage_balance": 1.0, "allocation": 1.0}))
    return recs


def _run(tmp_path, records, *argv):
    jsonl = tmp_path / "latest.jsonl"
    write_jsonl(records, jsonl)
    return ci_checks.main([*argv, "--jsonl", str(jsonl)])


def test_serving_goodput_passes_and_fails(tmp_path, capsys):
    assert _run(tmp_path, _serving_records(), "serving-goodput") == 0
    assert _run(tmp_path, _serving_records(200.0, 150.0),
                "serving-goodput") == 1
    assert "goodput" in capsys.readouterr().err


def test_serving_goodput_requires_both_schedulers(tmp_path):
    assert _run(tmp_path, _serving_records()[:1], "serving-goodput") == 1


def test_scaling_efficiency_passes_on_healthy_matrix(tmp_path):
    assert _run(tmp_path, _matrix_records(), "scaling-efficiency") == 0


def test_scaling_efficiency_rejects_model_escape(tmp_path, capsys):
    assert _run(tmp_path, _matrix_records(model_ok=False),
                "scaling-efficiency") == 1
    assert "most-loaded-stage" in capsys.readouterr().err


def test_scaling_efficiency_rejects_inverted_pp_ordering(tmp_path):
    bad = _matrix_records(pp_tok=(43000.0, 58000.0, 75000.0))
    assert _run(tmp_path, bad, "scaling-efficiency") == 1


def test_scaling_efficiency_requires_full_device_sweep(tmp_path):
    partial = [r for r in _matrix_records() if "dp8" not in r.name]
    assert _run(tmp_path, partial, "scaling-efficiency") == 1


def test_inject_slowdown_scales_all_timings(tmp_path):
    recs = [BenchRecord(name="g/a", us_per_call=100.0, p50_us=90.0,
                        p95_us=110.0, samples_us=[80.0, 90.0, 110.0])]
    jsonl = tmp_path / "latest.jsonl"
    write_jsonl(recs, jsonl)
    assert ci_checks.main(["inject-slowdown", "--factor", "3",
                           "--jsonl", str(jsonl)]) == 0
    from repro.bench import read_jsonl

    back = read_jsonl(jsonl)[0]
    assert back.us_per_call == pytest.approx(300.0)
    assert back.p50_us == pytest.approx(270.0)
    assert back.samples_us == pytest.approx([240.0, 270.0, 330.0])


def test_missing_jsonl_exits_nonzero(tmp_path):
    code = ci_checks.main(
        ["serving-goodput", "--jsonl", str(tmp_path / "nope.jsonl")])
    assert code != 0


def test_regression_gate_full_loop(tmp_path):
    """compare -> bless -> scratch 2x slowdown -> exit 3, in one command;
    the real JSONL and baselines survive untouched by the tripwire."""
    jsonl = tmp_path / "latest.jsonl"
    recs = [BenchRecord(name="g/a", us_per_call=1000.0, p50_us=1000.0,
                        samples_us=[950.0, 1000.0, 1050.0, 990.0, 1010.0])]
    write_jsonl(recs, jsonl)
    args = ["regression-gate", "--jsonl", str(jsonl),
            "--baseline-dir", str(tmp_path / "baselines"),
            "--trajectory", str(tmp_path / "trajectory.jsonl")]
    assert ci_checks.main(args) == 0
    from repro.bench import read_jsonl

    assert read_jsonl(jsonl)[0].us_per_call == 1000.0  # not slowed
    assert ci_checks.main(args) == 0  # idempotent on unchanged perf


def test_regression_gate_propagates_a_real_regression_as_exit_3(tmp_path):
    """A genuine regression vs the restored baselines is exit 3 (the
    reserved regression code), never 1 ('gate broken')."""
    jsonl = tmp_path / "latest.jsonl"
    recs = [BenchRecord(name="g/a", us_per_call=1000.0, p50_us=1000.0,
                        samples_us=[950.0, 1000.0, 1050.0, 990.0, 1010.0])]
    write_jsonl(recs, jsonl)
    args = ["regression-gate", "--jsonl", str(jsonl),
            "--baseline-dir", str(tmp_path / "baselines"),
            "--trajectory", str(tmp_path / "trajectory.jsonl")]
    assert ci_checks.main(args) == 0  # blesses
    ci_checks.main(["inject-slowdown", "--factor", "2",
                    "--jsonl", str(jsonl)])
    assert ci_checks.main(args) == 3
    # exactly one real trajectory point per clean gate run (the bless and
    # self-test compares write to scratch)
    from repro.bench import read_trajectory

    assert len(read_trajectory(tmp_path / "trajectory.jsonl")) == 2


def test_regression_gate_fails_when_it_cannot_trip(tmp_path):
    """Records the gate can never regress on (sub-min_us noise) must fail
    the self-test instead of green-lighting a broken gate."""
    jsonl = tmp_path / "latest.jsonl"
    write_jsonl([BenchRecord(name="g/tiny", us_per_call=10.0)], jsonl)
    code = ci_checks.main(
        ["regression-gate", "--jsonl", str(jsonl),
         "--baseline-dir", str(tmp_path / "baselines"),
         "--trajectory", str(tmp_path / "trajectory.jsonl")])
    assert code == 1


def _trace_records(bad_cell=None, drop_cell=None, whatif=True):
    recs = []
    for name in ci_checks._TRACE_CELLS:
        if name == drop_cell:
            continue
        predicted = 1300.0 if name == bad_cell else 1010.0
        recs.append(BenchRecord(
            name=name, group="trace_replay", us_per_call=1000.0,
            derived={"measured_us": 1000.0, "predicted_us": predicted,
                     "rel_err": 0.01}))  # stale on purpose: gate recomputes
    recs.append(BenchRecord(
        name="trace_replay/serve_paged", group="trace_replay",
        us_per_call=500.0,
        derived={"busy_us": 500.0, "predicted_us": 500.0, "rel_err": 0.0}))
    if whatif:
        recs.append(BenchRecord(
            name="trace_replay/whatif_8x1", group="trace_replay",
            us_per_call=0.0,
            derived={"measured_us": 9000.0, "predicted_us": 120.0,
                     "ratio": 0.013}))
    return recs


def test_trace_replay_passes_on_in_bound_cells(tmp_path, capsys):
    assert _run(tmp_path, _trace_records(), "trace-replay-error") == 0
    assert "self-test tripped OK" in capsys.readouterr().out


def test_trace_replay_recomputes_and_rejects_drifted_cell(tmp_path, capsys):
    """The stored rel_err says 0.01 but predicted/measured says 0.30 —
    the gate must recompute and trip, not trust the stale field."""
    bad = _trace_records(bad_cell="trace_replay/tp4")
    assert _run(tmp_path, bad, "trace-replay-error") == 1
    assert "trace_replay/tp4" in capsys.readouterr().err


def test_trace_replay_requires_every_matrix_cell(tmp_path, capsys):
    partial = _trace_records(drop_cell="trace_replay/mix_2x4")
    assert _run(tmp_path, partial, "trace-replay-error") == 1
    assert "missing record" in capsys.readouterr().err


def test_trace_replay_requires_whatif_report_rows(tmp_path, capsys):
    assert _run(tmp_path, _trace_records(whatif=False),
                "trace-replay-error") == 1
    assert "whatif" in capsys.readouterr().err


def test_trace_replay_does_not_gate_whatif_error(tmp_path):
    """A wildly wrong what-if prediction (simulated-host contention,
    DESIGN.md §4) must NOT fail the gate — only identity cells gate."""
    recs = _trace_records()
    recs[-1].derived["predicted_us"] = 1.0  # ratio 1e-4 vs measured
    assert _run(tmp_path, recs, "trace-replay-error") == 0


def test_doc_refs_passes_on_the_repo(capsys):
    assert ci_checks.main(["doc-refs"]) == 0
    assert "self-test tripped 3 planted findings OK" in (
        capsys.readouterr().out)


def test_doc_refs_trips_on_planted_tree(tmp_path):
    (tmp_path / "DESIGN.md").write_text("## §1 Only section\n")
    (tmp_path / "NOTES.md").write_text(
        "Good: DESIGN.md §1. Bad: DESIGN.md §7 and MISSING.md §1.\n")
    findings = ci_checks._doc_ref_findings(tmp_path)
    assert len(findings) == 2
    assert any("no '§7' heading" in f for f in findings)
    assert any("missing file" in f for f in findings)
    # flags are only policed in the named prose files
    (tmp_path / "findings.md").write_text("pass --not-a-real-flag\n")
    assert any("--not-a-real-flag" in f
               for f in ci_checks._doc_ref_findings(tmp_path))


def test_doc_refs_exit_nonzero_on_dangling_root(tmp_path):
    (tmp_path / "README.md").write_text("see GHOST.md §3\n")
    assert ci_checks.main(["doc-refs", "--root", str(tmp_path)]) == 1


def test_static_analysis_gate_passes(capsys):
    assert ci_checks.main(["static-analysis", "--skip-graphs"]) == 0
    out = capsys.readouterr().out
    assert "repo clean" in out and "rejected 4 illegal" in out


def test_static_analysis_trips_on_dirty_tree(monkeypatch, capsys):
    from repro.analysis import seams
    from repro.analysis.findings import Finding

    real = seams.scan_tree

    def fake(root=None):
        if root is None:
            return [Finding("RS101", "planted.py", 1, "planted violation")]
        return real(root)

    monkeypatch.setattr(seams, "scan_tree", fake)
    assert ci_checks.main(["static-analysis", "--skip-graphs"]) == 1
    assert "planted.py" in capsys.readouterr().err


def test_static_analysis_fails_when_fixtures_cannot_trip(monkeypatch,
                                                         capsys):
    """Neutering the seam lint must fail the gate's self-test — the same
    contract as chaos-parity: a gate that cannot trip is broken."""
    from repro.analysis import seams

    monkeypatch.setattr(seams, "scan_tree", lambda root=None: [])
    assert ci_checks.main(["static-analysis", "--skip-graphs"]) == 1
    assert "cannot fire" in capsys.readouterr().err
