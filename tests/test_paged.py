"""Paged KV-cache subsystem: the page allocator (free-list reuse,
fragmentation accounting, random admit/retire invariants), the Pallas
paged decode-attention kernel vs the gather reference, tuned tile-param
wiring, chunked-prefill equivalence, paged-vs-monolithic greedy token
parity across mixed prompt lengths, and the symmetric admission
validation shared by all three schedulers."""
from _hypothesis_compat import given, settings, st

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
from repro.kernels import tuning
from repro.kernels.paged_attention import paged_attention_fwd
from repro.models.attention import paged_decode_attention_ref
from repro.runtime.steps import build_serve_steps
from repro.serving import (ContinuousEngine, PageAllocator, PagedEngine,
                           Request, SimClock, make_engine, pages_needed)

VOCAB = 17


# ------------------------------------------------------------- allocator
def test_pages_needed():
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2


def test_allocator_free_list_reuse():
    """Freed pages go back on the free list and are reissued LIFO — the
    most recently retired request's pages come out first."""
    a = PageAllocator(num_pages=9, page_size=4)
    p1 = a.allocate(1, 10)                  # 3 pages
    p2 = a.allocate(2, 8)                   # 2 pages
    assert len(p1) == 3 and len(p2) == 2
    assert a.num_used == 5 and a.num_free == 3
    a.free(1)
    a.check()
    p3 = a.allocate(3, 12)                  # reuses rid 1's pages, LIFO
    assert p3 == p1[::-1]
    a.check()


def test_allocator_reserves_null_page():
    a = PageAllocator(num_pages=4, page_size=2)
    got = a.allocate(0, 6)                  # the whole usable pool
    assert 0 not in got and sorted(got) == [1, 2, 3]
    with pytest.raises(MemoryError):
        a.allocate(1, 1)
    assert a.failed_allocs == 1


def test_allocator_double_free_and_double_alloc():
    a = PageAllocator(num_pages=4, page_size=2)
    a.allocate(7, 2)
    with pytest.raises(ValueError, match="already holds"):
        a.allocate(7, 2)
    a.free(7)
    with pytest.raises(ValueError, match="double free"):
        a.free(7)


def test_allocator_fragmentation_accounting():
    a = PageAllocator(num_pages=9, page_size=4)
    a.allocate(1, 5)                        # 2 pages = 8 slots for 5 live
    assert a.fragmentation(5) == pytest.approx(3 / 8)
    assert a.fragmentation(8) == 0.0
    assert a.occupancy == pytest.approx(2 / 8)
    a.free(1)
    assert a.fragmentation(0) == 0.0        # empty pool: no fragmentation


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), st.integers(1, 40)),
                    min_size=1, max_size=60),
       page_size=st.sampled_from([1, 4, 16]))
def test_allocator_random_admit_retire(ops, page_size):
    """Random admit/retire sequences preserve the pool invariants: every
    usable page is free or owned exactly once, the null page is never
    issued, counts balance, and the high-water mark only grows."""
    a = PageAllocator(num_pages=17, page_size=page_size)
    live = []
    next_rid = 0
    hw = 0
    for admit, tokens in ops:
        if admit or not live:
            need = a.pages_needed(tokens)
            if need <= a.num_free:
                got = a.allocate(next_rid, tokens)
                assert len(got) == need
                live.append(next_rid)
                next_rid += 1
            else:
                with pytest.raises(MemoryError):
                    a.allocate(next_rid, tokens)
                next_rid += 1
        else:
            a.free(live.pop(0))
        assert a.num_used + a.num_free == a.usable_pages
        assert a.num_owners == len(live)
        assert 0.0 <= a.occupancy <= 1.0
        assert a.high_water >= hw
        hw = a.high_water
        a.check()
    for rid in live:
        a.free(rid)
    assert a.num_free == a.usable_pages and a.num_used == 0
    a.check()


# ---------------------------------------------------------------- kernel
@pytest.mark.parametrize("ppb", [1, 2, 3, 4])
def test_paged_kernel_matches_reference(ppb):
    """The in-kernel block-table gather must match the gather-then-
    decode_attention reference for every pages_per_block tiling,
    including one that does not divide the table width (null-page
    padding)."""
    rng = np.random.default_rng(0)
    B, Hq, Hkv, D, P, ps, npag = 3, 4, 2, 16, 9, 4, 4
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, ps, Hkv, D)), jnp.float32)
    bt = jnp.asarray(rng.integers(1, P, size=(B, npag)), jnp.int32)
    lens = jnp.asarray([5, 16, 1], jnp.int32)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
    out = paged_attention_fwd(q, kp, vp, bt, lens, pages_per_block=ppb,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_paged_tuning_wiring():
    """None = auto resolves through DEFAULTS; explicit values win; the
    ops wrapper accepts the auto path."""
    from repro.bench.tune import paged_candidates
    from repro.kernels import ops

    sig_args = dict(q_shape=(2, 1, 4, 16), pages_shape=(8, 4, 2, 16),
                    n_pages=4, dtype=np.float32)
    assert tuning.resolve_paged_pages_per_block(None, **sig_args) == \
        tuning.DEFAULTS["paged_attention_fwd"]["pages_per_block"]
    assert tuning.resolve_paged_pages_per_block(4, **sig_args) == 4
    cands, rejected, default = paged_candidates(
        n_pages=8, ps=16, g=2, D=64, itemsize=4)
    assert default == {"pages_per_block": 1} and cands[0] == default
    assert {c["pages_per_block"] for c in cands} <= {1, 2, 4, 8}
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 16)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((8, 4, 2, 16)), jnp.float32)
    bt = jnp.asarray(rng.integers(1, 8, (2, 4)), jnp.int32)
    out = ops.paged_decode_attention(q, kp, kp, bt,
                                     jnp.asarray([3, 9], jnp.int32))
    assert out.shape == (2, 1, 4, 16)


# ------------------------------------------------- model-level paged path
def _tiny_serve(arch="granite-3-8b", span=24, slots=2):
    cfg = reduced(ARCHS[arch], layers=2, d_model=64, vocab=128, d_ff=128)
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("s", "decode", span, slots),
                     mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
                     attention_backend="dense", param_dtype="float32",
                     decode_attention="simple")
    prefill_fn, decode_fn, model = build_serve_steps(rcfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, prefill_fn, decode_fn, model, params


def test_chunked_prefill_matches_one_shot():
    """Feeding the prompt in chunks through the paged pools must produce
    the same next-token logits as the one-shot monolithic prefill, for
    several chunk sizes including non-dividing ones."""
    span, ps = 24, 4
    cfg, prefill_fn, _, model, params = _tiny_serve(span=span)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, size=9).astype(np.int32)
    ref_logits, _ = prefill_fn(params, {"tokens": jnp.asarray(prompt[None])},
                               span)
    btab = jnp.arange(1, 7, dtype=jnp.int32)[None]      # 6 pages = span
    for chunk in (3, 4, 9):
        caches = model.paged_cache_init(8, ps)
        for start in range(0, len(prompt), chunk):
            toks = jnp.asarray(prompt[None, start:start + chunk])
            logits, caches = model.prefill_chunk(params, caches, toks,
                                                 btab, jnp.int32(start))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits),
                                   atol=1e-4, rtol=1e-4)


def test_paged_decode_matches_monolithic_decode():
    """Greedy decode through the paged pool emits exactly the tokens the
    monolithic cache path emits."""
    span, ps, steps = 24, 4, 5
    cfg, prefill_fn, decode_fn, model, params = _tiny_serve(span=span)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=7).astype(np.int32)

    logits, caches = prefill_fn(params, {"tokens": jnp.asarray(prompt[None])},
                                span)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    ref = [int(tok[0, 0])]
    for i in range(steps - 1):
        logits, caches = decode_fn(params, caches, tok,
                                   jnp.int32(len(prompt) + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        ref.append(int(tok[0, 0]))

    pcaches = model.paged_cache_init(8, ps)
    btab = jnp.arange(1, 7, dtype=jnp.int32)[None]
    lg, pcaches = model.prefill_chunk(params, pcaches,
                                      jnp.asarray(prompt[None]), btab,
                                      jnp.int32(0))
    tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
    got = [int(tok[0, 0])]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    for i in range(steps - 1):
        lg, pcaches = model.decode_step_paged(params, pcaches, tok, pos + i,
                                              btab)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        got.append(int(tok[0, 0]))
    assert got == ref


def test_paged_cache_init_rejects_unsupported_families():
    cfg, *_ = _tiny_serve(arch="rwkv6-3b")
    from repro.models import transformer as tfm
    with pytest.raises(ValueError, match="full-attention"):
        tfm.paged_cache_init(cfg, 2, 8, 4, jnp.float32)


# ------------------------------------------------------- paged engine
def test_paged_engine_parity_mixed_prompt_lengths():
    """PagedEngine greedy streams are token-identical to the monolithic
    ContinuousEngine across mixed prompt lengths — including a request
    admitted into reused pages after a retirement."""
    span = 24
    cfg, prefill_fn, decode_fn, model, params = _tiny_serve(span=span)
    rng = np.random.default_rng(0)
    pA = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    pB = rng.integers(1, cfg.vocab_size, size=9).astype(np.int32)
    reqs = lambda: [Request(0, pA, 5), Request(1, pB, 5),
                    Request(2, pA, 5)]
    mono = ContinuousEngine(prefill_fn, decode_fn, params, model.cache_init,
                            slots=2, cache_span=span, clock=SimClock())
    paged = PagedEngine(model.prefill_chunk, model.decode_step_paged,
                        params, model.paged_cache_init, slots=2,
                        cache_span=span, page_size=4,
                        prefill_chunk_tokens=4, clock=SimClock())
    got_m = [list(m.tokens) for m in mono.run(reqs()).metrics]
    rep_p = paged.run(reqs())
    got_p = [list(m.tokens) for m in rep_p.metrics]
    assert got_m == got_p
    assert rep_p.completed == 3
    assert rep_p.page_occupancy_peak > 0
    assert 0.0 <= rep_p.fragmentation_mean < 1.0


# ------------------------------------------ stub engines (scheduling only)
def stub_prefill(params, batch, cache_span):
    B = batch["tokens"].shape[0]
    logits = jnp.zeros((B, 1, VOCAB)).at[:, :, 1].set(100.0)
    return logits, {"k": jnp.zeros((1, B, cache_span, 2))}


def stub_decode(params, caches, tok, pos):
    pos_v = jnp.broadcast_to(jnp.atleast_1d(pos), (tok.shape[0],))
    lg = jax.nn.one_hot(jnp.minimum(pos_v + 1, VOCAB - 1), VOCAB) * 100.0
    return lg[:, None, :], caches


def stub_cache_init(batch, max_len, dtype=jnp.float32):
    return {"k": jnp.zeros((1, batch, max_len, 2), dtype)}


def stub_chunk_prefill(params, caches, tokens, block_tables, start_pos):
    """Paged-signature twin of stub_prefill: same spike-at-1 logits."""
    B = tokens.shape[0]
    logits = jnp.zeros((B, 1, VOCAB)).at[:, :, 1].set(100.0)
    return logits, caches


def stub_paged_decode(params, caches, tok, pos, block_tables):
    return stub_decode(params, caches, tok, pos)


def stub_paged_cache_init(num_pages, page_size, dtype=jnp.float32):
    return {"k": jnp.zeros((1, num_pages, page_size, 2), dtype)}


def _paged_stub_engine(**kw):
    kw.setdefault("clock", SimClock())
    return PagedEngine(stub_chunk_prefill, stub_paged_decode, None,
                       stub_paged_cache_init, **kw)


def test_paged_engine_admits_more_at_equal_budget():
    """Equal KV budget (2 slots x 16-token span = 32 tokens): the
    monolithic engine caps at 2 concurrent requests; the paged pool
    (32 tokens = 8 pages of 4, null page included) fits 3 short
    requests at once."""
    span, n = 16, 6
    reqs = lambda: [Request(i, np.full(4, 2, np.int32), 2)
                    for i in range(n)]
    mono = ContinuousEngine(stub_prefill, stub_decode, None,
                            stub_cache_init, slots=2, cache_span=span,
                            clock=SimClock())
    rep_m = mono.run(reqs())
    paged = _paged_stub_engine(slots=4, cache_span=span, page_size=4,
                               num_pages=2 * span // 4)
    rep_p = paged.run(reqs())
    assert rep_m.completed == rep_p.completed == n
    assert rep_p.peak_concurrency > rep_m.peak_concurrency
    assert rep_p.peak_concurrency == 3      # ceil(6/4)=2 pages x 3 <= 7


def test_paged_engine_blocks_admission_until_pages_free():
    """A request that fits the pool but not the current free list waits
    at the queue head and is admitted after a retirement frees pages —
    counted in admission_blocked_steps."""
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=4)      # 3 usable pages
    reqs = [Request(0, np.full(4, 2, np.int32), 6),    # 10 tok = 3 pages
            Request(1, np.full(4, 2, np.int32), 6)]
    rep = eng.run(reqs)
    assert rep.completed == 2
    assert rep.admission_blocked_steps > 0
    assert rep.peak_concurrency == 1
    m0, m1 = rep.metrics
    assert m1.admitted_s >= m0.finish_s     # strictly after retirement
    np.testing.assert_array_equal(m0.tokens, m1.tokens)


def test_paged_engine_token_streams_and_page_reuse():
    """5 requests through 2 lanes and a small pool: every request
    completes with the position-correct stream, pages are recycled."""
    eng = _paged_stub_engine(slots=2, cache_span=16, page_size=4,
                             num_pages=6)
    reqs = [Request(i, np.full(4, 2, np.int32), 4) for i in range(5)]
    rep = eng.run(reqs)
    assert rep.completed == 5
    for m in rep.metrics:
        np.testing.assert_array_equal(m.tokens, [1, 5, 6, 7])
    assert rep.page_occupancy_peak <= 1.0
    s = rep.summary()
    assert s["num_pages"] == 6 and s["page_size"] == 4


# --------------------------------------------------- symmetric validation
def _make(scheduler, **kw):
    if scheduler == "paged":
        return _paged_stub_engine(**kw)
    return make_engine(scheduler, stub_prefill, stub_decode, None,
                       stub_cache_init, clock=SimClock(), **kw)


@pytest.mark.parametrize("scheduler", ["static", "continuous", "paged"])
def test_admission_validation_symmetric(scheduler):
    """All three engines route rejection through the same validated
    hook: identical errors for a zero budget and for a request
    exceeding the span — no scheduler silently admits what another
    rejects (the continuous/paged paths used to diverge from the
    static one)."""
    eng = _make(scheduler, slots=1, cache_span=8)
    with pytest.raises(ValueError,
                       match="max_new_tokens must be >= 1, got 0"):
        eng.run([Request(0, np.full(4, 2, np.int32), max_new_tokens=0)])
    with pytest.raises(ValueError, match="exceeds cache_span 8"):
        eng.run([Request(0, np.full(4, 2, np.int32), max_new_tokens=5)])
    assert eng.admission_error(
        Request(0, np.full(4, 2, np.int32), max_new_tokens=4)) is None


def test_paged_rejects_over_pool_capacity():
    """The paged engine's admission check speaks pages: a request that
    can never fit the pool is rejected up front with the shared
    validated error, not left to deadlock the queue."""
    eng = _paged_stub_engine(slots=1, cache_span=32, page_size=4,
                             num_pages=4)      # 3 usable = 12 tokens
    with pytest.raises(ValueError, match="usable pages"):
        eng.run([Request(0, np.full(8, 2, np.int32), max_new_tokens=8)])
    # same request against a big-enough pool is admissible
    ok = _paged_stub_engine(slots=1, cache_span=32, page_size=4,
                            num_pages=8)
    assert ok.admission_error(
        Request(0, np.full(8, 2, np.int32), max_new_tokens=8)) is None


def test_make_engine_builds_paged():
    eng = make_engine("paged", stub_chunk_prefill, stub_paged_decode, None,
                      stub_paged_cache_init, slots=2, cache_span=16,
                      page_size=4, clock=SimClock())
    assert isinstance(eng, PagedEngine)
    rep = eng.run([Request(0, np.full(4, 2, np.int32), 3)])
    np.testing.assert_array_equal(rep.metrics[0].tokens, [1, 5, 6])
