"""§Perf optimization flags must not change the MATH — loss under every
opt-in flag combination matches the baseline on a small mesh. (This is the
'debug forward, keep the speedup' guard: a perf flag that breaks numerics
fails here, not in EXPERIMENTS.md.)"""
from conftest import run_with_devices


def test_perf_flags_preserve_loss():
    run_with_devices("""
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
from repro.launch.mesh import make_mesh, set_mesh
from repro.models.frontends import synth_batch
from repro.parallel import sharding as shd
from repro.runtime.steps import build_train_step

def loss_with(arch, flags, mesh_cfg):
    cfg = reduced(ARCHS[arch], layers=4, d_model=128, vocab=512)
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 64, 8),
                     mesh=mesh_cfg, param_dtype="float32",
                     attention_backend="dense", microbatches=2, **flags)
    mesh = make_mesh(mesh_cfg)
    with set_mesh(mesh):
        step, model, opt = build_train_step(rcfg)
        params = model.init_params(jax.random.PRNGKey(0))
        pspecs = shd.param_pspecs(params, cfg, rcfg)
        params = jax.tree.map(lambda x, s: jax.device_put(
            x, NamedSharding(mesh, s)), params, pspecs,
            is_leaf=lambda x: not isinstance(x, dict))
        opt_state = opt.init(params)
        batch = synth_batch(cfg, 8, 64, kind="train")
        _, _, m = jax.jit(step)(params, opt_state, batch)
    return float(m["loss"])

mesh = MeshConfig(shape=(4, 2), axes=("data", "model"))
base = loss_with("granite-3-8b", {}, mesh)
for flags in ({"pin_mixer_output": True}, {"layers_per_block": 2},
              {"norm_local": True}):
    got = loss_with("granite-3-8b", flags, mesh)
    assert abs(got - base) < 1e-4, (flags, base, got)
    print(flags, "ok", got)

# ssm flags on rwkv
base = loss_with("rwkv6-3b", {}, mesh)
for flags in ({"ssm_factored": True}, {"ssm_tp": True},
              {"ssm_factored": True, "ssm_tp": True}):
    got = loss_with("rwkv6-3b", flags, mesh)
    assert abs(got - base) < 1e-3, (flags, base, got)
    print(flags, "ok", got)
print("OK")
""", n_devices=8, timeout=900)
