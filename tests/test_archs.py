"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs forward + one train step + prefill +
decode on CPU, asserting output shapes and finiteness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, MeshConfig, RunConfig, ShapeConfig, \
    reduced
from repro.models import build, Runtime
from repro.models.frontends import synth_batch

ARCH_IDS = sorted(ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ARCHS[name])
            rt = Runtime(attention_backend="dense", chunk=32)
            model = build(cfg, rt, param_dtype=jnp.float32)
            params = model.init_params(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_loss(built, name):
    cfg, model, params = built(name)
    batch = synth_batch(cfg, 2, 32, kind="train")
    loss, aux = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step(built, name):
    cfg, model, params = built(name)
    mesh = MeshConfig(shape=(1, 1), axes=("data", "model"))
    rcfg = RunConfig(model=cfg, mesh=mesh, param_dtype="float32",
                     attention_backend="dense",
                     shape=ShapeConfig("t", "train", 32, 2), microbatches=1)
    from repro.runtime.steps import build_train_step
    step, model2, opt = build_train_step(rcfg)
    params2 = model2.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params2)
    batch = synth_batch(cfg, 2, 32, kind="train")
    p3, o3, metrics = jax.jit(step)(params2, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params2, p3))
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_decode_shapes(built, name):
    cfg, model, params = built(name)
    B, S = 2, 32
    batch = synth_batch(cfg, B, S, kind="prefill")
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, S + 4))(
        params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches2 = jax.jit(model.decode_step)(params, caches, tok,
                                                  jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("name", ["qwen2.5-32b", "stablelm-12b",
                                  "granite-3-8b", "whisper-large-v3",
                                  "hymba-1.5b", "rwkv6-3b", "arctic-480b"])
def test_decode_matches_teacher_forcing(name):
    """Incremental decode after prefill == teacher-forced forward."""
    import dataclasses
    cfg = reduced(ARCHS[name])
    if cfg.moe is not None:  # no-drop capacity => exact equality
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k))
    rt = Runtime(attention_backend="dense", chunk=16)
    model = build(cfg, rt, param_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(1))
    B, S = 2, 24
    batch = synth_batch(cfg, B, S + 1, seed=3, kind="prefill")
    pf = {k: (v if k == "audio_embeds" else v[:, :S]) for k, v in batch.items()}
    _, caches = model.prefill(params, pf, S + 8)
    tok = batch["tokens"][:, S:S + 1]
    logits_dec, _ = model.decode_step(params, caches, tok, jnp.int32(S))
    # teacher-forced logits at position S come from loss-path structure:
    full = synth_batch(cfg, B, S + 1, seed=3, kind="train")
    full["tokens"] = batch["tokens"]
    if "audio_embeds" in batch:
        full["audio_embeds"] = batch["audio_embeds"]
    # reuse prefill on S+1 tokens: its last-position logits == teacher forced
    logits_full, _ = model.prefill(params, batch, S + 9)
    rel = float(jnp.abs(logits_dec - logits_full).max()) / (
        float(jnp.abs(logits_full).max()) + 1e-9)
    assert rel < 5e-3, rel


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters are preserved in the full configs."""
    a = ARCHS["qwen2.5-32b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads,
            a.d_ff, a.vocab_size) == (64, 5120, 40, 8, 27648, 152064)
    assert a.qkv_bias
    b = ARCHS["arctic-480b"]
    assert b.moe.num_experts == 128 and b.moe.top_k == 2
    assert b.moe.dense_residual_ff == 4864
    c = ARCHS["rwkv6-3b"]
    assert c.attention_kind == "none" and c.ssm.kind == "rwkv6"
    d = ARCHS["hymba-1.5b"]
    assert d.ssm is not None and d.attention_kind == "sliding"
    w = ARCHS["whisper-large-v3"]
    assert w.encoder_layers == 32 and w.is_enc_dec
    v = ARCHS["qwen2-vl-72b"]
    assert v.rope == "mrope"
    assert len(ARCHS) == 10


def test_shape_cells_accounting():
    """40 assigned cells = 32 runnable + 8 noted long_500k skips."""
    from repro.configs import cells
    runnable = cells()
    assert len(runnable) == 32
    skipped = [a.name for a in ARCHS.values() if not a.sub_quadratic]
    assert len(skipped) == 8
    assert len(ARCHS) * len(SHAPES) == 40
