"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
with hypothesis sweeps over shapes/dtypes (deterministic fallback sampler
when hypothesis isn't installed — see tests/_hypothesis_compat.py)."""
import numpy as np
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

settings.register_profile("kernels", max_examples=10, deadline=None)
settings.load_profile("kernels")


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 5e-4


# ------------------------------------------------------------------ flash
@given(
    b=st.sampled_from([1, 2]),
    s=st.sampled_from([128, 256, 384]),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4), (8, 2)]),
    d=st.sampled_from([64, 128]),
    causal=st.booleans(),
    window=st.sampled_from([0, 96]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_matches_oracle(b, s, heads, d, causal, window,
                                        dtype):
    hq, hkv = heads
    if window and not causal:
        window = 0
    rng = np.random.default_rng(b * 1000 + s + hq)
    q = _rand(rng, (b, s, hq, d), dtype)
    k = _rand(rng, (b, s, hkv, d), dtype)
    v = _rand(rng, (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_block_shape_sweep():
    rng = np.random.default_rng(0)
    q = _rand(rng, (1, 512, 4, 64), jnp.float32)
    k = _rand(rng, (1, 512, 2, 64), jnp.float32)
    v = _rand(rng, (1, 512, 2, 64), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (512, 512)]:
        out = ops.flash_attention(q, k, v, causal=True,
                                  block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)


# ------------------------------------------------------------------ wkv6
@given(
    mode=st.sampled_from(["rwkv", "ssd"]),
    t=st.sampled_from([64, 96, 128]),
    h=st.sampled_from([1, 3]),
    kdim=st.sampled_from([16, 64]),
    chunk=st.sampled_from([16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_wkv6_matches_recurrence(mode, t, h, kdim, chunk, dtype):
    if t % chunk:
        chunk = 16
    rng = np.random.default_rng(t + h * 7 + kdim)
    B, V = 2, kdim
    q = _rand(rng, (B, t, h, kdim), dtype)
    k = _rand(rng, (B, t, h, kdim), dtype)
    v = _rand(rng, (B, t, h, V), dtype)
    ld = jnp.asarray(-np.exp(rng.standard_normal((B, t, h, kdim)) - 1.0),
                     jnp.float32)
    u = (jnp.asarray(rng.standard_normal((h, kdim)), jnp.float32)
         if mode == "rwkv" else None)
    o, s = ops.wkv6(q, k, v, ld, u, chunk=chunk)
    ow, sw = ref.wkv6_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), ld, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(ow),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sw),
                               atol=tol, rtol=tol)


def test_wkv6_matmul_fast_path_matches_ref():
    """Mild decay keeps every chunk on the decay-rescaled-matmul path
    (in-chunk range << SAFE_DECAY_RANGE); parity vs the exact recurrence."""
    rng = np.random.default_rng(1)
    B, T, H, K = 2, 128, 2, 32
    q = _rand(rng, (B, T, H, K), jnp.float32)
    k = _rand(rng, (B, T, H, K), jnp.float32)
    v = _rand(rng, (B, T, H, K), jnp.float32)
    ld = jnp.full((B, T, H, K), -0.01, jnp.float32)   # range 0.64 per chunk
    for u in (None, jnp.asarray(rng.standard_normal((H, K)), jnp.float32)):
        o, s = ops.wkv6(q, k, v, ld, u, chunk=64)
        ow, sw = ref.wkv6_ref(q, k, v, ld, u)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ow),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sw),
                                   atol=1e-3, rtol=1e-3)


def test_wkv6_extreme_decay_uses_masked_fallback():
    """Near-maximal decay (range ~ 12*chunk >> SAFE_DECAY_RANGE) must take
    the pairwise fallback and stay finite + exact."""
    rng = np.random.default_rng(2)
    B, T, H, K = 1, 128, 1, 16
    q = _rand(rng, (B, T, H, K), jnp.float32)
    k = _rand(rng, (B, T, H, K), jnp.float32)
    v = _rand(rng, (B, T, H, K), jnp.float32)
    ld = jnp.full((B, T, H, K), -11.5, jnp.float32)
    o, s = ops.wkv6(q, k, v, ld, None, chunk=64)
    assert np.isfinite(np.asarray(o)).all()
    ow, sw = ref.wkv6_ref(q, k, v, ld, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ow),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sw),
                               atol=1e-3, rtol=1e-3)


def test_wkv6_long_sequence_stability():
    """Decay products over 4k tokens must not overflow/underflow."""
    rng = np.random.default_rng(0)
    B, T, H, K = 1, 4096, 1, 16
    q = _rand(rng, (B, T, H, K), jnp.float32)
    k = _rand(rng, (B, T, H, K), jnp.float32)
    v = _rand(rng, (B, T, H, K), jnp.float32)
    ld = jnp.asarray(-np.exp(rng.standard_normal((B, T, H, K))),
                     jnp.float32)
    o, s = ops.wkv6(q, k, v, ld, None, chunk=64)
    assert np.isfinite(np.asarray(o)).all()
    assert np.isfinite(np.asarray(s)).all()


# ---------------------------------------------------------------- rmsnorm
@given(rows=st.sampled_from([1, 17, 300]),
       d=st.sampled_from([128, 256, 512]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_rmsnorm_matches_oracle(rows, d, dtype):
    rng = np.random.default_rng(rows + d)
    x = _rand(rng, (rows, d), dtype)
    sc = _rand(rng, (d,), jnp.float32)
    out = ops.rmsnorm(x, sc)
    want = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# ------------------------------------------------------- flash backward
@given(heads=st.sampled_from([(2, 2), (4, 2)]),
       causal=st.booleans(),
       window=st.sampled_from([0, 96]))
def test_flash_attention_grads_match_oracle(heads, causal, window):
    """custom_vjp backward (Pallas dq/dkv kernels) vs dense-reference
    autodiff grads."""
    if window and not causal:
        window = 0
    hq, hkv = heads
    rng = np.random.default_rng(hq * 13 + window)
    B, S, D = 1, 256, 64
    q = _rand(rng, (B, S, hq, D), jnp.float32) * 0.5
    k = _rand(rng, (B, S, hkv, D), jnp.float32) * 0.5
    v = _rand(rng, (B, S, hkv, D), jnp.float32) * 0.5
    ct = _rand(rng, (B, S, hq, D), jnp.float32)

    def loss_pl(q, k, v):
        return (ops.flash_attention(q, k, v, causal=causal,
                                    window=window) * ct).sum()

    def loss_ref(q, k, v):
        return (ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window) * ct).sum()

    g_pl = jax.grad(loss_pl, argnums=(0, 1, 2))(q, k, v)
    g_rf = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_pl, g_rf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)
