"""repro.bench subsystem: BenchRecord round-trips, scenario registry
registration/filtering, and an end-to-end runner smoke test (tiny
scenarios, no jax required)."""
from __future__ import annotations

import json

import pytest

from repro.bench import (BenchRecord, BenchRunner, CSV_HEADER, CsvStdoutSink,
                         JsonlSink, ListSink, Scenario, TimingStats,
                         Workload, read_jsonl, register, scenario, select,
                         unregister, write_jsonl)
from repro.bench.scenario import REGISTRY


# ------------------------------------------------------------- record I/O
def test_record_json_round_trip():
    rec = BenchRecord(
        name="allocation/layers12/O3", scenario="allocation/layers",
        group="allocation", arch="granite-3-8b", shape="bench",
        mesh="16x16", knobs={"mode": "O3", "num_layers": 12},
        us_per_call=123.4, derived={"alloc": 0.998, "n_sections": 13},
        tags=("tier1", "table1"), paper_ref="Table I",
        env={"python": "3.10"})
    back = BenchRecord.from_json_line(rec.to_json_line())
    assert back == rec
    # derived metrics survive as a real dict, not a parsed string
    assert back.derived["alloc"] == pytest.approx(0.998)
    assert isinstance(back.derived["n_sections"], int)


def test_record_from_dict_ignores_unknown_fields():
    d = json.loads(BenchRecord(name="x").to_json_line())
    d["future_field"] = "whatever"
    assert BenchRecord.from_dict(d).name == "x"


def test_record_csv_line_matches_legacy_format():
    rec = BenchRecord(name="deploy/batch8", us_per_call=1234.56,
                      derived={"tok_s": 829, "mfu": 0.51234})
    assert CSV_HEADER == "name,us_per_call,derived"
    assert rec.csv_line() == "deploy/batch8,1234.6,tok_s=829;mfu=0.5123"


def test_jsonl_file_round_trip(tmp_path):
    recs = [BenchRecord(name=f"g/s{i}", us_per_call=float(i),
                        derived={"m": i}) for i in range(3)]
    path = write_jsonl(recs, tmp_path / "out" / "r.jsonl")
    assert read_jsonl(path) == recs


# ----------------------------------------------------------- timing stats
def test_timing_stats_is_a_float_mean_with_percentiles():
    ts = TimingStats([10.0, 20.0, 30.0, 40.0, 100.0])
    assert float(ts) == pytest.approx(40.0)      # drops in as the mean
    assert ts == pytest.approx(40.0)
    assert ts.p50_us == pytest.approx(30.0)
    assert ts.p95_us == pytest.approx(88.0)      # interpolated
    assert ts.samples == (10.0, 20.0, 30.0, 40.0, 100.0)


def test_runner_stamps_percentiles_from_timing_stats():
    scen = Scenario(
        name="_test/p50",
        fn=lambda wl: [BenchRecord(
            name="_test/p50/r",
            us_per_call=TimingStats([1.0, 2.0, 9.0]))],
        group="_test", workloads=(Workload(),))
    rec = BenchRunner().run([scen]).records[0]
    assert rec.us_per_call == pytest.approx(4.0)
    assert type(rec.us_per_call) is float         # stripped for JSON
    assert rec.p50_us == pytest.approx(2.0)
    assert rec.p95_us == pytest.approx(8.3)
    # percentiles survive the JSONL round trip; legacy CSV is unchanged
    back = BenchRecord.from_json_line(rec.to_json_line())
    assert back.p50_us == rec.p50_us and back.p95_us == rec.p95_us
    assert rec.csv_line() == "_test/p50/r,4.0,"


# --------------------------------------------------------------- registry
@pytest.fixture
def scratch_registry():
    """Track scenario names registered inside a test; always unregister."""
    added = []
    yield added
    for name in added:
        unregister(name)


def test_scenario_decorator_registers(scratch_registry):
    @scenario("_test/basic", tags=("unit",), paper_ref="Fig. 0",
              workloads=[Workload(label="a"), Workload(label="b")])
    def fn(wl):
        yield BenchRecord(name=f"_test/{wl.label}")

    scratch_registry.append("_test/basic")
    scen = REGISTRY["_test/basic"]
    assert scen.group == "_test"
    assert scen.tags == ("unit",)
    assert len(scen.workloads) == 2


def test_duplicate_registration_rejected(scratch_registry):
    register(Scenario(name="_test/dup", fn=lambda wl: [], group="_test"))
    scratch_registry.append("_test/dup")
    with pytest.raises(ValueError, match="already registered"):
        register(Scenario(name="_test/dup", fn=lambda wl: [],
                          group="_test"))


def test_select_filters_by_substring_and_tags(scratch_registry):
    for name, tags in [("_test/aaa", ("red",)), ("_test/bbb", ("blue",))]:
        register(Scenario(name=name, fn=lambda wl: [], group="_test",
                          tags=tags))
        scratch_registry.append(name)
    assert [s.name for s in select(only="_test/a")] == ["_test/aaa"]
    assert [s.name for s in select(only="_test", tags=["blue"])] \
        == ["_test/bbb"]
    assert [s.name for s in select(only="_test/nope")] == []


def test_select_exact_name_beats_substring(scratch_registry):
    """An --only term that exactly names a scenario selects just it (the
    CI flaky-retry path), even when it is a substring of siblings; comma
    lists union their terms."""
    for name in ["_test/sched", "_test/sched_static", "_test/other"]:
        register(Scenario(name=name, fn=lambda wl: [], group="_test"))
        scratch_registry.append(name)
    # substring term: matches both sched scenarios
    assert [s.name for s in select(only="sched")] \
        == ["_test/sched", "_test/sched_static"]
    # exact term: only the named scenario, not its prefix-sharing sibling
    assert [s.name for s in select(only="_test/sched")] == ["_test/sched"]
    assert [s.name for s in select(only="_test/sched,_test/other")] \
        == ["_test/sched", "_test/other"]


# ----------------------------------------------------------------- runner
def _tiny_scenarios():
    ok = Scenario(
        name="_test/ok",
        fn=lambda wl: [BenchRecord(name=f"_test/ok/{wl.label}",
                                   us_per_call=1.0,
                                   derived={"x": wl.knobs["x"]})],
        group="_test", tags=("unit",), paper_ref="Fig. 0",
        workloads=(Workload(label="w0", arch="granite-3-8b",
                            knobs={"x": 7}),))

    def boom(wl):
        raise RuntimeError("kaboom")
        yield  # pragma: no cover

    bad = Scenario(name="_test/boom", fn=boom, group="_test",
                   workloads=(Workload(label="w0"),))
    return ok, bad


def test_runner_end_to_end_with_sinks(tmp_path, capsys):
    ok, bad = _tiny_scenarios()
    jsonl = tmp_path / "r.jsonl"
    sink = ListSink()
    summary = BenchRunner(
        sinks=[CsvStdoutSink(), JsonlSink(jsonl), sink]).run([ok, bad])

    # fail-soft: the bad scenario is captured, the sweep completes
    assert [n for n, _ in summary.failures] == ["_test/boom/w0"]
    assert not summary.ok

    good = [r for r in summary.records if r.status == "ok"]
    errs = [r for r in summary.records if r.status == "error"]
    assert len(good) == 1 and len(errs) == 1
    # provenance stamped from scenario + workload
    rec = good[0]
    assert rec.scenario == "_test/ok" and rec.group == "_test"
    assert rec.arch == "granite-3-8b" and rec.tags == ("unit",)
    assert rec.knobs == {"x": 7} and rec.env
    assert "kaboom" in errs[0].error

    # every sink saw every record
    assert sink.records == summary.records
    assert read_jsonl(jsonl) == summary.records
    out = capsys.readouterr().out.splitlines()
    assert out[0] == CSV_HEADER
    assert out[1] == "_test/ok/w0,1.0,x=7"


def test_runner_scenario_timeout_records_and_continues(monkeypatch):
    """A hung workload becomes a ``status: "timeout"`` record and the
    sweep still runs the scenarios after it (S-curve soak runs must not
    wedge the whole matrix behind one deadlocked cell)."""
    import time as _time

    def hang(wl):
        _time.sleep(30.0)
        yield BenchRecord(name="_test/hang/never")  # pragma: no cover

    hung = Scenario(name="_test/hang", fn=hang, group="_test",
                    workloads=(Workload(label="w0"),))
    ok, _ = _tiny_scenarios()
    summary = BenchRunner(timeout_s=0.2).run([hung, ok])

    assert [n for n, _ in summary.failures] == ["_test/hang/w0"]
    timeouts = [r for r in summary.records if r.status == "timeout"]
    assert len(timeouts) == 1
    assert timeouts[0].name == "_test/hang/w0/TIMEOUT"
    assert timeouts[0].derived["timeout_s"] == 0.2
    assert "0s budget" in timeouts[0].error
    # the sweep continued past the hang
    assert [r.name for r in summary.records if r.status == "ok"] \
        == ["_test/ok/w0"]
    # env override feeds the default budget
    monkeypatch.setenv("REPRO_SCENARIO_TIMEOUT_S", "7.5")
    assert BenchRunner().timeout_s == 7.5


def test_runner_timeout_disarmed_after_workload():
    """The alarm is always cancelled — a fast workload must not leave a
    pending SIGALRM to kill unrelated code later."""
    import signal as _signal

    ok, _ = _tiny_scenarios()
    BenchRunner(timeout_s=0.05).run([ok])
    assert _signal.getitimer(_signal.ITIMER_REAL) == (0.0, 0.0)


def test_runner_record_knobs_override_workload_knobs():
    scen = Scenario(
        name="_test/knobs",
        fn=lambda wl: [BenchRecord(name="_test/knobs/r",
                                   knobs={"mode": "O3"})],
        group="_test", workloads=(Workload(knobs={"mode": "O0", "L": 4}),))
    summary = BenchRunner().run([scen])
    assert summary.records[0].knobs == {"mode": "O3", "L": 4}


# ------------------------------------------- fake-device env helper
def test_host_device_env_rewrites_only_the_count_flag():
    """The scaling-matrix children must inherit a CI cell's other XLA
    flags; only the forced device count is rewritten (never duplicated,
    which XLA would resolve unpredictably)."""
    from repro.launch.mesh import host_device_env, simulated_device_count

    base = {"XLA_FLAGS": "--xla_foo=1 "
                         "--xla_force_host_platform_device_count=4",
            "OTHER": "x"}
    env = host_device_env(8, base_env=base)
    assert "--xla_foo=1" in env["XLA_FLAGS"]
    assert env["XLA_FLAGS"].count("force_host_platform_device_count") == 1
    assert simulated_device_count(env) == 8
    assert env["OTHER"] == "x"
    assert simulated_device_count({"XLA_FLAGS": ""}) is None


# ------------------------------------------------- harness CLI glue
def test_run_module_registers_all_benchmark_groups():
    """benchmarks.run imports every module and each registers its group."""
    import benchmarks.run as bench_run

    imported, failures = bench_run.import_benchmarks()
    assert not failures, failures
    from repro.bench import groups

    got = set(groups())
    for groups_for_mod in bench_run.MODULES.values():
        for g in groups_for_mod:
            assert g in got, f"group {g} never registered"
