"""DABench core: Eq. 1-5 unit tests, property tests on metric invariants,
HLO-analyzer verification against hand-built modules, section partitioner
invariants."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCHS, SHAPES, MeshConfig
from repro.core import metrics, sections
from repro.core.hlo_analysis import analyze_hlo, parse_module
from repro.core.roofline import roofline

settings.register_profile("metrics", max_examples=50, deadline=None)
settings.load_profile("metrics")


# ------------------------------------------------------------- equations
def test_eq1_allocation():
    assert metrics.allocation_ratio(92, 100) == pytest.approx(0.92)
    assert metrics.allocation_ratio(0, 0) == 0.0


def test_eq2_weighted_allocation():
    # two sections: 3s at 50%, 1s at 100% -> (3*0.5 + 1*1)/4
    secs = [(3.0, 50, 100), (1.0, 100, 100)]
    assert metrics.weighted_allocation(secs) == pytest.approx(0.625)


def test_eq3_load_imbalance_exact():
    # equal resources, throughputs (1, 2): LI = (1/2)(1 + 0.5) = 0.75
    assert metrics.load_imbalance([1, 1], [1, 2]) == pytest.approx(0.75)
    assert metrics.load_imbalance([1, 1, 1], [5, 5, 5]) == pytest.approx(1.0)


def test_eq4_weighted_li():
    assert metrics.weighted_load_imbalance(
        [(1.0, 1.0), (3.0, 0.5)]) == pytest.approx((1 + 1.5) / 4)


def test_eq5_arithmetic_intensity():
    # paper form: 6PBS / (4P + act)
    ai = metrics.arithmetic_intensity(1e8, 8, 1024, 0.0)
    assert ai == pytest.approx(6 * 1e8 * 8 * 1024 / (4e8))


@given(st.lists(st.tuples(st.floats(0.1, 100), st.floats(0.1, 100)),
                min_size=1, max_size=20))
def test_li_invariants(pairs):
    """Property: LI in (0, 1]; ==1 iff all throughputs equal."""
    r = [p[0] for p in pairs]
    t = [p[1] for p in pairs]
    li = metrics.load_imbalance(r, t)
    assert 0.0 < li <= 1.0 + 1e-9
    if len(set(round(x, 9) for x in t)) == 1:
        assert li == pytest.approx(1.0)


@given(st.lists(st.floats(0.01, 10), min_size=2, max_size=16))
def test_li_scale_invariance(ts):
    """Scaling all throughputs by a constant leaves LI unchanged."""
    r = [1.0] * len(ts)
    li1 = metrics.load_imbalance(r, ts)
    li2 = metrics.load_imbalance(r, [t * 7.3 for t in ts])
    assert li1 == pytest.approx(li2, rel=1e-9)


def test_mxu_tile_efficiency():
    assert metrics.mxu_tile_efficiency(8, 128, 128) == pytest.approx(1.0)
    assert metrics.mxu_tile_efficiency(4, 128, 128) == pytest.approx(0.5)
    assert 0 < metrics.mxu_tile_efficiency(100, 100, 100) < 1


# ----------------------------------------------------------- HLO analyzer
HLO_SAMPLE = """
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %i2 = s32[] add(%i, %c1)
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,32]{1,0} all-gather(%y), channel_id=1, replica_groups=[2,2]<=[4], dimensions={1}
  %z = f32[8,16]{1,0} slice(%ag), slice={[0:8],[0:16]}
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %z)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[8,16]{1,0}) tuple(%c0, %a)
  %w0 = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w0), index=1
}
"""


def test_hlo_parse():
    comps, entry = parse_module(HLO_SAMPLE)
    assert entry == "main"
    assert set(comps) == {"body", "cond", "main"}
    assert any(i.opcode == "dot" for i in comps["body"])


def test_hlo_trip_count_expansion():
    r = analyze_hlo(HLO_SAMPLE)
    # dot: 2*8*16*16 flops per iteration, 10 iterations
    assert r.dot_flops == pytest.approx(10 * 2 * 8 * 16 * 16)
    ags = [c for c in r.collectives if c.opcode == "all-gather"]
    assert len(ags) == 1
    assert ags[0].count == pytest.approx(10)
    assert ags[0].bytes == pytest.approx(8 * 16 * 4)   # operand bytes
    assert ags[0].group_size == 2


def test_roofline_terms():
    r = analyze_hlo(HLO_SAMPLE)
    rl = roofline(r, chips=4, model_flops=1e6)
    assert rl.compute_s == pytest.approx(r.flops / 197e12)
    assert rl.dominant in ("compute", "memory", "collective")
    d = rl.to_dict()
    assert set(d) >= {"compute_s", "memory_s", "collective_s", "dominant"}


# ------------------------------------------------------------- sections
@pytest.mark.parametrize("mode", ["O0", "O1", "O3"])
@pytest.mark.parametrize("arch", ["granite-3-8b", "arctic-480b", "rwkv6-3b"])
def test_section_partitioner(mode, arch):
    cfg = ARCHS[arch]
    rep = sections.analyze(cfg, SHAPES["train_4k"], MeshConfig(), mode)
    assert 0 < rep.allocation <= 1.0
    assert 0 < rep.load_imbalance <= 1.0
    assert rep.total_runtime > 0
    if mode == "O0":
        assert rep.n_sections > cfg.num_layers  # finer than per-layer


def test_sections_flops_conserved():
    """Partitioning must not change total flops (O0 == O1 == O3 totals)."""
    cfg = ARCHS["granite-3-8b"]
    ops = sections.build_op_graph(cfg, SHAPES["train_4k"], MeshConfig())
    total = sum(o.flops for o in ops)
    for mode in ("O0", "O1", "O3"):
        secs = sections.partition(ops, mode)
        assert sum(s.flops for s in secs) == pytest.approx(total)


def test_section_graph_tracks_model_flops():
    """Structural op-graph flops within 2x of the 6ND analytic estimate."""
    cfg = ARCHS["granite-3-8b"]
    shape = SHAPES["train_4k"]
    ops = sections.build_op_graph(cfg, shape, MeshConfig())
    total = sum(o.flops for o in ops)
    model = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert 0.5 < total / model < 2.0
