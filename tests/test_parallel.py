"""Distribution-layer tests. Each test runs in a subprocess with 8 fake
host devices (the main pytest process keeps 1 device — see conftest)."""
from conftest import run_with_devices


def test_dp_tp_matches_single_device():
    """train loss under a (4,2) data x model mesh == single-device loss."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, reduced, RunConfig, ShapeConfig, MeshConfig
from repro.models import build, Runtime
from repro.models.frontends import synth_batch
from repro.parallel import sharding as shd
from repro.launch.mesh import make_mesh, set_mesh

cfg = reduced(ARCHS["granite-3-8b"], d_model=128, vocab=512)
batch = synth_batch(cfg, 4, 32, kind="train")
model1 = build(cfg, Runtime(attention_backend="dense"), jnp.float32)
params = model1.init_params(jax.random.PRNGKey(0))
loss1, _ = jax.jit(model1.loss)(params, batch)

mesh_cfg = MeshConfig(shape=(4, 2), axes=("data", "model"))
mesh = make_mesh(mesh_cfg)
rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 32, 4),
                 mesh=mesh_cfg, param_dtype="float32",
                 attention_backend="dense")
from repro.runtime.steps import make_runtime
rt = make_runtime(rcfg)
model2 = build(cfg, rt, jnp.float32)
pspecs = shd.param_pspecs(params, cfg, rcfg)
with set_mesh(mesh):
    sharded = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                           params, pspecs,
                           is_leaf=lambda x: not isinstance(x, dict))
    loss2, _ = jax.jit(model2.loss)(sharded, batch)
err = abs(float(loss1) - float(loss2))
print("dp_tp loss err:", err)
assert err < 1e-4, (float(loss1), float(loss2))
print("OK")
""")


def test_partitioned_decode_matches_simple():
    """lse-combining seq-sharded decode attention == dense decode."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import partitioned_decode_attention
from repro.models.attention import decode_attention_simple
from repro.launch.mesh import make_mesh, set_mesh
from repro.configs import MeshConfig

mesh = make_mesh(MeshConfig(shape=(2, 4), axes=("data", "model")))
rng = np.random.default_rng(0)
B, S, Hq, Hkv, D = 4, 64, 8, 2, 32
q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
for cache_len in (S, 37, 5):
    want = decode_attention_simple(q, k, v, jnp.int32(cache_len))
    with set_mesh(mesh):
        got = jax.jit(lambda q, k, v, n: partitioned_decode_attention(
            q, k, v, n, batch_axes=("data",)))(q, k, v, jnp.int32(cache_len))
    err = float(jnp.abs(got - want).max())
    print("cache_len", cache_len, "err", err)
    assert err < 1e-5, err
print("OK")
""")


def test_moe_shardmap_matches_dense():
    run_with_devices("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import ARCHS, reduced
from repro.models import moe as moe_mod
from repro.models.transformer import Runtime
from repro.launch.mesh import make_mesh, set_mesh
from repro.configs import MeshConfig

cfg = reduced(ARCHS["arctic-480b"], experts=8)
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=8.0))
mesh = make_mesh(MeshConfig(shape=(4, 2), axes=("data", "model")))
p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model)) * 0.1
rt = Runtime(act_spec=P("data", None, None), mesh_batch_axes=("data",),
             dp_size=4, moe_shardmap=True)
with set_mesh(mesh):
    y_sm, aux = jax.jit(lambda p, x: moe_mod.moe_ffn(p, x, cfg, rt))(p, x)
y_ref, aux_ref = jax.jit(lambda p, x: moe_mod._moe_ffn_dense(p, x, cfg, None))(p, x)
err = float(jnp.abs(y_sm - y_ref).max()) / float(jnp.abs(y_ref).max())
assert err < 1e-5, err
assert float(aux["expert_load"].sum()) == float(aux_ref["expert_load"].sum())
print("OK")
""")


def test_pipeline_matches_sequential():
    run_with_devices("""
import jax, jax.numpy as jnp
from repro.parallel.pipeline import stack_stages, pipeline_forward
from repro.launch.mesh import make_mesh, set_mesh
from repro.configs import MeshConfig

mesh = make_mesh(MeshConfig(shape=(4,), axes=("model",)))
L, D, M, MB, S = 8, 32, 6, 2, 16
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, S, D))
layer_fn = lambda c, p: jnp.tanh(c @ p["w"])

def seq(xx):
    y, _ = jax.lax.scan(lambda c, p: (layer_fn(c, p), None), xx, params)
    return y
ref = jax.vmap(seq)(x)
for stage_layers in [(2, 2, 2, 2), (1, 3, 2, 2), (5, 1, 1, 1)]:
    staged, mask = stack_stages(params, stage_layers)
    with set_mesh(mesh):
        out = jax.jit(lambda s, m, xx: pipeline_forward(
            s, m, xx, layer_fn))(staged, mask, x)
    err = float(jnp.abs(out - ref).max())
    print(stage_layers, err)
    assert err < 1e-5, err
print("OK")
""")


def test_compressed_gradient_allreduce():
    """int8 error-feedback all-reduce ~= exact psum; error feedback shrinks
    the residual over repeated reductions of the same tensor."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import compressed_psum_grads
from repro.launch.mesh import make_mesh, set_mesh
from repro.configs import MeshConfig

mesh = make_mesh(MeshConfig(shape=(8,), axes=("data",)))
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01}
res = {"w": jnp.zeros(1000)}
with set_mesh(mesh):
    out, new_res = jax.jit(lambda g, r: compressed_psum_grads(
        g, r, data_axes=("data",)))(g, res)
exact = g["w"] * 8  # replicated input summed over 8 shards
rel = float(jnp.abs(out["w"] - exact).max()) / float(jnp.abs(exact).max())
print("compressed vs exact rel err:", rel)
assert rel < 0.02, rel
assert float(jnp.abs(new_res["w"]).max()) > 0  # residual captured
print("OK")
""")


def test_multi_pod_axis_shards():
    """(pod, data, model) mesh: batch shards over (pod, data) jointly."""
    run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import MeshConfig
from repro.parallel import sharding as shd
from repro.launch.mesh import make_mesh, set_mesh

mesh_cfg = MeshConfig(shape=(2, 2, 2), axes=("pod", "data", "model"))
mesh = make_mesh(mesh_cfg)
spec = shd.batch_spec(mesh_cfg, 8)
assert spec == ("pod", "data"), spec
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
xs = jax.device_put(x, NamedSharding(mesh, P(spec, None)))
shard_rows = xs.addressable_shards[0].data.shape[0]
assert shard_rows == 2, shard_rows  # 8 rows / (pod2*data2)
print("OK")
""")
