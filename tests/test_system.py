"""End-to-end behaviour tests for the whole system: model-level backend
equivalences, linear-attention algebra, e2e train/serve drivers, dry-run
machinery on a small mesh."""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_with_devices
from repro.models.attention import chunked_attention, dense_attention
from repro.models.ssm import (chunked_linear_attention,
                              recurrent_linear_attention)


# ------------------------------------------------- backend equivalences
@pytest.mark.parametrize("case", [
    dict(S=64, Sk=64, Hq=4, Hkv=2, D=16, causal=True, window=0, chunk=16),
    dict(S=64, Sk=64, Hq=4, Hkv=2, D=16, causal=True, window=24, chunk=16),
    dict(S=50, Sk=50, Hq=6, Hkv=3, D=8, causal=True, window=0, chunk=16),
    dict(S=64, Sk=64, Hq=2, Hkv=2, D=16, causal=False, window=0, chunk=16),
])
def test_chunked_attention_matches_dense(case):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, case["S"], case["Hq"], case["D"])),
                    jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, case["Sk"], case["Hkv"], case["D"])),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, case["Sk"], case["Hkv"], case["D"])),
                    jnp.float32)
    a = dense_attention(q, k, v, causal=case["causal"], window=case["window"])
    b = chunked_attention(q, k, v, causal=case["causal"],
                          window=case["window"], chunk=case["chunk"])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("mode", ["rwkv", "ssd"])
def test_chunked_linear_attention_matches_recurrence(mode):
    rng = np.random.default_rng(1)
    B, T, H, K, V = 2, 70, 3, 16, 16
    q = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, H, K)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, H, V)), jnp.float32)
    ld = jnp.asarray(-np.exp(rng.standard_normal((B, T, H, K)) - 1),
                     jnp.float32)
    u = (jnp.asarray(rng.standard_normal((H, K)), jnp.float32)
         if mode == "rwkv" else None)
    o1, s1 = recurrent_linear_attention(q, k, v, ld, u)
    o2, s2 = chunked_linear_attention(q, k, v, ld, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)
    # split-resume: chunked with carried state == one pass (prefill handoff)
    oa, sa = chunked_linear_attention(q[:, :32], k[:, :32], v[:, :32],
                                      ld[:, :32], u, chunk=16)
    ob, sb = chunked_linear_attention(q[:, 32:], k[:, 32:], v[:, 32:],
                                      ld[:, 32:], u, initial_state=sa,
                                      chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([oa, ob], 1)),
                               np.asarray(o1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(s1), atol=1e-3)


# ------------------------------------------------------------ e2e drivers
def test_train_driver_end_to_end():
    from repro.launch.train import main
    res = main(["--arch", "granite-3-8b", "--steps", "15", "--batch", "4",
                "--seq", "64", "--d-model", "64", "--layers", "2"])
    assert res.final_step == 15
    assert res.losses[-1] < res.losses[0]


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    res = main(["--arch", "hymba-1.5b", "--batch", "2", "--prompt-len", "16",
                "--max-new-tokens", "4", "--num-requests", "2",
                "--scheduler", "continuous"])
    assert res.completed == 2
    assert all(m.new_tokens == 4 for m in res.metrics)
    assert all(m.tokens.shape == (4,) for m in res.metrics)


def test_train_driver_multidevice():
    run_with_devices("""
from repro.launch.train import main
res = main(["--arch", "qwen2.5-32b", "--steps", "8", "--batch", "8",
            "--seq", "32", "--d-model", "64", "--layers", "2"])
assert res.final_step == 8
print("OK")
""", n_devices=8)


# ----------------------------------------------------------- dry-run path
def test_dryrun_machinery_small_mesh():
    """input_specs + lower + compile + analyze on an 8-device mesh (the
    512-device production run is exercised by launch/dryrun.py itself)."""
    run_with_devices("""
import dataclasses
import jax
from repro.configs import RunConfig, SHAPES, MeshConfig, get_arch, reduced
from repro.launch.dryrun import input_specs, _cpu_f32_duplicates
from repro.launch.mesh import make_mesh, set_mesh
from repro.core.hlo_analysis import analyze_hlo

arch = reduced(get_arch("granite-3-8b"), d_model=256, vocab=512, layers=4)
mesh_cfg = MeshConfig(shape=(4, 2), axes=("data", "model"))
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128, global_batch=8)
rcfg = RunConfig(model=arch, shape=shape, mesh=mesh_cfg, microbatches=4)
mesh = make_mesh(mesh_cfg)
with set_mesh(mesh):
    args, in_sh, out_sh, donate, step = input_specs(rcfg, mesh)
    compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate).lower(*args).compile()
    assert compiled.memory_analysis() is not None
r = analyze_hlo(compiled.as_text())
assert r.flops > 0 and r.bytes > 0
assert any(c.opcode in ("all-reduce", "all-gather", "reduce-scatter")
           for c in r.collectives)
print("OK")
""", n_devices=8)
