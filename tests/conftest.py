import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"
sys.path.insert(0, str(SRC))
# make the _hypothesis_compat shim importable regardless of import mode
sys.path.insert(0, str(Path(__file__).resolve().parent))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake host devices.
    (The main pytest process must keep seeing 1 device — see dryrun.py.)
    Env via launch/mesh.host_device_env: only the count flag is rewritten,
    so a CI cell's other XLA_FLAGS survive into the child."""
    from repro.launch.mesh import host_device_env

    env = host_device_env(n_devices)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def tmp_ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")
