"""Tests for the static-analysis subsystem (repro.analysis).

Every rule gets both directions: the real tree passes, and a planted
violation (fixture file, illegal tile config, or poisoned traced
function) trips the exact rule id.
"""

import json
from pathlib import Path

import pytest

import jax
import jax.numpy as jnp

from repro.analysis import __main__ as analysis_cli
from repro.analysis import graph_audit, kernel_lint, seams
from repro.analysis.findings import RULES, Finding
from repro.kernels import tuning

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"

EXPECTED_FIXTURE_RULES = {
    "bad_assert.py": "RS101",
    "bad_free.py": "RS102",
    "bad_admission.py": "RS103",
    "bad_wallclock.py": "RS104",
    "bad_numpy_in_jit.py": "RS105",
}


# ------------------------------------------------------------- seam lint
def test_repo_tree_is_clean():
    findings = seams.scan_tree()
    assert findings == [], [str(f) for f in findings]


@pytest.mark.parametrize("fixture,rule", sorted(EXPECTED_FIXTURE_RULES.items()))
def test_fixture_trips_rule(fixture, rule):
    findings = seams.scan_file(FIXTURES / fixture)
    rules = {f.rule for f in findings}
    assert rule in rules, (fixture, [str(f) for f in findings])


def test_every_seam_rule_has_a_fixture():
    covered = set(EXPECTED_FIXTURE_RULES.values())
    seam_rules = {r for r in RULES if r.startswith("RS")}
    assert covered == seam_rules


def test_admission_fixture_flags_both_run_and_override():
    findings = seams.scan_file(FIXTURES / "bad_admission.py")
    msgs = [f.message for f in findings if f.rule == "RS103"]
    assert len(msgs) == 2
    assert any("run never calls" in m for m in msgs)
    assert any("admission_error override" in m for m in msgs)


def test_scheduler_validate_seam_satisfies_rs103():
    """The role-composed engines reach admission checks through an
    extracted Scheduler (``sched.validate(requests)``) rather than a
    direct ``self._validate`` call; RS103 accepts that seam."""
    src = (
        "class RoleEngine:\n"
        "    def run(self, requests):\n"
        "        sched = Scheduler(self)\n"
        "        reqs, rejected = sched.validate(requests)\n"
        "        return reqs\n"
    )
    assert seams.scan_source(src, "mod.py") == []


def test_pragma_suppresses_rule():
    src = "def f(x):\n    assert x  # repro: allow=RS101\n"
    assert seams.scan_source(src, "mod.py") == []


def test_pragma_on_previous_line_and_wildcard():
    src = "def f(x):\n    # repro: allow=*\n    assert x\n"
    assert seams.scan_source(src, "mod.py") == []


def test_pragma_for_other_rule_does_not_suppress():
    src = "def f(x):\n    assert x  # repro: allow=RS102\n"
    findings = seams.scan_source(src, "mod.py")
    assert [f.rule for f in findings] == ["RS101"]


def test_clock_classes_exempt_from_wallclock_rule():
    src = (
        "import time\n"
        "from repro.serving.request import SimClock\n"
        "class WallClock:\n"
        "    def now(self):\n"
        "        return time.perf_counter()\n"
    )
    assert seams.scan_source(src, "serving/clock.py") == []


def test_release_pages_exempt_from_free_rule():
    src = (
        "class PagedEngine:\n"
        "    def _release_pages(self, alloc, rid):\n"
        "        alloc.free(rid)\n"
    )
    assert seams.scan_source(src, "mod.py") == []


def test_numpy_outside_jit_not_flagged():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "def host_side(x):\n"
        "    return np.asarray(x)\n"
        "def device_side(x):\n"
        "    return x * 2\n"
        "f = jax.jit(device_side)\n"
    )
    assert seams.scan_source(src, "mod.py") == []


def test_jit_decorator_forms_detected():
    src = (
        "import functools\n"
        "import numpy as np\n"
        "import jax\n"
        "@functools.partial(jax.jit, static_argnums=0)\n"
        "def step(n, state):\n"
        "    return np.add(state, n)\n"
    )
    findings = seams.scan_source(src, "mod.py")
    assert [f.rule for f in findings] == ["RS105"]


# ------------------------------------------------------------ kernel lint
def _flash_dims(dtype="float32"):
    return dict(B=1, Sq=2048, Sk=2048, Hq=32, Hkv=8, D=128, dtype=dtype)


def test_defaults_accepted_on_canonical_shapes():
    findings = kernel_lint.check_defaults("tpu")
    assert findings == [], [str(f) for f in findings]


def test_flash_misaligned_tile_rejected():
    findings = kernel_lint.check_config(
        "flash_attention_fwd", _flash_dims(), {"block_q": 100, "block_k": 128}, "tpu"
    )
    rules = {f.rule for f in findings}
    assert "RK003" in rules  # 100 not a multiple of the 8-sublane
    assert "RK001" in rules  # and 2048 % 100 != 0


def test_flash_vmem_overflow_rejected():
    findings = kernel_lint.check_config(
        "flash_attention_fwd", _flash_dims(), {"block_q": 2048, "block_k": 2048}, "tpu"
    )
    rules = {f.rule for f in findings}
    assert "RK002" in rules  # (2048, 2048) f32 intermediates


def test_flash_default_tile_accepted():
    findings = kernel_lint.check_config(
        "flash_attention_fwd",
        _flash_dims(),
        tuning.DEFAULTS["flash_attention_fwd"],
        "tpu",
    )
    assert findings == [], [str(f) for f in findings]


def test_rwkv_oversized_chunk_rejected():
    dims = dict(B=1, T=2048, H=32, K=64, V=64, dtype="float32")
    findings = kernel_lint.check_config("wkv6_fwd", dims, {"chunk": 1024}, "tpu")
    rules = {f.rule for f in findings}
    assert "RK002" in rules  # (1024, 1024, 64) fallback tensor


def test_rwkv_default_chunk_accepted():
    dims = dict(B=1, T=2048, H=32, K=64, V=64, dtype="float32")
    assert (
        kernel_lint.check_config("wkv6_fwd", dims, tuning.DEFAULTS["wkv6_fwd"], "tpu")
        == []
    )


def test_rmsnorm_vmem_overflow_rejected():
    dims = dict(rows=65536, d=512, dtype="float32")
    findings = kernel_lint.check_config(
        "rmsnorm_fwd", dims, {"block_rows": 65536}, "tpu"
    )
    assert "RK002" in {f.rule for f in findings}


def test_rmsnorm_misaligned_rows_rejected():
    dims = dict(rows=8192, d=512, dtype="float32")
    findings = kernel_lint.check_config("rmsnorm_fwd", dims, {"block_rows": 100}, "tpu")
    assert "RK003" in {f.rule for f in findings}


def test_rmsnorm_auto_clamp_fits_wide_models():
    # the historical 256-row default overflows at d=4096/f32; the auto
    # path must clamp it to a block that fits the capability budget
    br = tuning.resolve_rmsnorm_rows(None, rows=8192, d=4096, dtype="float32")
    assert br < 256
    caps = tuning.capabilities("tpu")
    need = 2 * caps.pipeline_buffers * caps.padded_bytes((br, 4096), "float32")
    need += caps.padded_bytes((br, 4096), "float32")
    assert need <= caps.vmem_bytes


def test_rmsnorm_explicit_rows_not_clamped():
    assert tuning.resolve_rmsnorm_rows(4096, rows=8192, d=4096, dtype="float32") == 4096


def test_paged_oversized_pages_per_block_rejected():
    dims = dict(B=8, Hq=32, Hkv=8, D=128, P=512, ps=16, npag=512, dtype="float32")
    findings = kernel_lint.check_config(
        "paged_attention_fwd", dims, {"pages_per_block": 512}, "tpu"
    )
    assert "RK002" in {f.rule for f in findings}  # 1024 page DMAs resident at once


def test_paged_default_accepted():
    dims = dict(B=8, Hq=32, Hkv=8, D=128, P=512, ps=16, npag=128, dtype="float32")
    assert (
        kernel_lint.check_config(
            "paged_attention_fwd", dims, tuning.DEFAULTS["paged_attention_fwd"], "tpu"
        )
        == []
    )


def test_unsupported_dtype_rejected():
    dims = dict(rows=1024, d=512, dtype="float64")
    findings = kernel_lint.check_config("rmsnorm_fwd", dims, {"block_rows": 256}, "tpu")
    assert "RK005" in {f.rule for f in findings}


def test_index_map_bounds_checked():
    # white-box: a plan whose index_map walks off the operand
    plan = kernel_lint.Plan(
        kernel="synthetic",
        path="x.py",
        grid=(4,),
        blocks=[kernel_lint.Block("x", (256, 128), (64, 128), lambda i: (i + 1, 0))],
    )
    findings = kernel_lint._check_plan(plan, tuning.capabilities("tpu"))
    assert {f.rule for f in findings} == {"RK004"}


def test_grid_corner_sampling_covers_large_grids():
    pts = kernel_lint._grid_samples((1000, 2))
    assert (0, 0) in pts and (999, 1) in pts
    assert len(pts) <= 16


def test_tuned_cache_entries_checked(tmp_path, monkeypatch):
    monkeypatch.setenv(tuning.ENV_VAR, str(tmp_path))
    sig = tuning.attention_signature(
        (1, 2048, 32, 128), (1, 2048, 8, 128), "float32", causal=True, window=0
    )
    entries = {
        tuning.entry_key("flash_attention_fwd", sig): {
            "config": {"block_q": 256, "block_k": 256}
        },
    }
    (tmp_path / "cpu.json").write_text(
        json.dumps({"version": 1, "env": {}, "entries": entries})
    )
    assert kernel_lint.check_tuned_cache("cpu") == []

    entries[tuning.entry_key("flash_attention_fwd", sig)] = {
        "config": {"block_q": 100, "block_k": 128}
    }
    (tmp_path / "cpu.json").write_text(
        json.dumps({"version": 1, "env": {}, "entries": entries})
    )
    findings = kernel_lint.check_tuned_cache("cpu")
    assert findings and {f.rule for f in findings} >= {"RK003"}
    assert all("cpu.json" in f.path for f in findings)


def test_gpu_capability_entry_differs():
    caps = tuning.capabilities("gpu")
    assert caps.vmem_bytes < tuning.capabilities("tpu").vmem_bytes
    assert caps.lane == 64


# ------------------------------------------------------------ graph audit
def test_clean_function_passes():
    assert graph_audit.audit_function("f", lambda x: x * 2 + 1, jnp.ones((4, 4))) == []


def test_host_callback_flagged():
    def noisy(x):
        jax.debug.print("x = {}", x)
        return x * 2

    findings = graph_audit.audit_function("noisy", noisy, jnp.ones(4))
    assert any(f.rule == "RG001" for f in findings)


def test_f64_leak_flagged():
    def leak(x):
        return x.astype(jnp.float64).sum()

    with jax.experimental.enable_x64():
        findings = graph_audit.audit_function("leak", leak, jnp.ones(4))
    assert any(f.rule == "RG002" for f in findings)


def test_weak_type_churn_flagged():
    jitted = jax.jit(lambda x: x * 2)
    findings = graph_audit.check_cache_growth("doubler", jitted, [(1,), (1.0,)])
    assert [f.rule for f in findings] == ["RG003"]


def test_stable_signature_no_churn():
    jitted = jax.jit(lambda x: x * 2)
    a = jnp.arange(4.0)
    assert graph_audit.check_cache_growth("doubler", jitted, [(a,), (a + 1,)]) == []


_SYNTH_COLLECTIVE_HLO = """\
HloModule synth

ENTRY %main (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128] parameter(0)
  %ar = f32[128,128] all-reduce(%p0), replica_groups={{0,1}}, \
to_apply=%add
  ROOT %r = f32[128,128] add(%ar, %p0)
}
"""


def test_collective_in_single_device_hlo_flagged():
    findings = graph_audit.audit_hlo_text("step", _SYNTH_COLLECTIVE_HLO)
    assert any(f.rule == "RG004" for f in findings)


def test_collective_ok_when_multi_device_expected():
    assert (
        graph_audit.audit_hlo_text(
            "step", _SYNTH_COLLECTIVE_HLO, expect_single_device=False
        )
        == []
    )


def test_outfeed_in_hlo_flagged():
    text = "HloModule m\n\nENTRY %e () -> f32[] {\n  %o = outfeed()\n}\n"
    findings = graph_audit.audit_hlo_text("step", text)
    assert any(f.rule == "RG005" for f in findings)


def test_compiled_hlo_of_clean_step_passes():
    findings = graph_audit.audit_hlo("mul", lambda x: x @ x, jnp.ones((8, 8)))
    assert findings == [], [str(f) for f in findings]


def test_decode_step_audit_clean():
    findings = graph_audit.audit_decode_step()
    assert findings == [], [str(f) for f in findings]


def test_engine_steady_state_no_recompiles():
    findings = graph_audit.audit_engine_steady_state()
    assert findings == [], [str(f) for f in findings]


# -------------------------------------------------------------------- CLI
def test_cli_clean_tree_exits_zero(capsys):
    rc = analysis_cli.main(["--layer", "seams", "--layer", "kernels"])
    assert rc == 0
    assert "clean" in capsys.readouterr().err


def test_cli_seeded_violation_exits_nonzero(capsys):
    rc = analysis_cli.main(["--layer", "seams", "--root", str(FIXTURES)])
    assert rc == 1
    out = capsys.readouterr().out
    for rule in EXPECTED_FIXTURE_RULES.values():
        assert rule in out


def test_cli_json_output_is_jsonl(capsys):
    rc = analysis_cli.main(["--layer", "seams", "--root", str(FIXTURES), "--json"])
    assert rc == 1
    lines = [ln for ln in capsys.readouterr().out.splitlines() if ln]
    for ln in lines:
        rec = json.loads(ln)
        assert {"rule", "path", "line", "message", "name"} <= set(rec)
    found = {r["rule"] for r in map(json.loads, lines)}
    assert found >= set(EXPECTED_FIXTURE_RULES.values())


def test_cli_list_rules(capsys):
    assert analysis_cli.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


def test_cli_internal_error_exits_two(capsys):
    rc = analysis_cli.main(["--layer", "seams", "--root", "/nonexistent/tree"])
    # an empty/missing tree is not an error, it is just zero findings —
    # but a root that is a file with bad syntax must not crash either
    assert rc in (0, 2)


def test_finding_str_is_clickable():
    f = Finding("RS101", "src/repro/x.py", 42, "boom")
    assert str(f).startswith("src/repro/x.py:42: RS101")


def test_rules_catalog_complete():
    prefixes = {r[:2] for r in RULES}
    assert prefixes == {"RK", "RG", "RS"}
    assert all(RULES[r] for r in RULES)
