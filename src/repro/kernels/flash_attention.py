"""Causal/sliding GQA flash attention — Pallas TPU kernel.

Tiling: grid (B, Hq, Sq/bq, Sk/bk); the kv-block dim is the innermost
SEQUENTIAL ("arbitrary") dim so the online-softmax accumulators live in
VMEM scratch across kv blocks. Block shapes are MXU-aligned (bq, bk
multiples of 128 when the sequence allows; head_dim padded to 128 lanes by
Mosaic). GQA is handled in the kv index_map (hq -> hq // group).

Fully-masked kv blocks are skipped with pl.when (forward AND backward,
including the sliding-window bound), so the causal lower triangle
intersected with the window band is the only work executed — matching the
chunked-jnp stand-in the dry-run compiles and the flop accounting in
§Roofline.

Tile sizes: ``block_q``/``block_k`` default to ``None`` ("auto") and
resolve through the tuned-config cache (:mod:`repro.kernels.tuning`,
populated by ``python -m benchmarks.run --tune``), falling back to the
historical 128/128 constants on a cache miss.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning

# jax < 0.5 ships this as TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int,
                 bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * bq
    k_lo = ik * bk
    # block is live unless fully masked out
    live = True
    if causal:
        live = k_lo <= q_lo + bq - 1
    if window:
        live = jnp.logical_and(live, k_lo + bk - 1 >= q_lo - window + 1) \
            if causal else live

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if isinstance(live, bool):
        _compute()
    else:
        pl.when(live)(_compute)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _attn_kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                     acc_scr, *, scale, causal, window, bq, bk, nk):
    """Forward that also emits logsumexp rows (needed by the backward)."""
    _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 scale=scale, causal=causal, window=window, bq=bq, bk=bk,
                 nk=nk)
    ik = pl.program_id(3)

    @pl.when(ik == nk - 1)
    def _emit_lse():
        lse_ref[0, 0] = (m_scr[...]
                         + jnp.log(jnp.maximum(l_scr[...], 1e-30)))[:, 0]


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int | None = None,
                        block_k: int | None = None,
                        interpret: bool = False, return_lse: bool = False):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)
    [, lse (B, Hq, Sq)]. block_q/block_k None = auto (tuned cache)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    block_q, block_k = tuning.resolve_attention_blocks(
        block_q, block_k, q_shape=q.shape, k_shape=k.shape, dtype=q.dtype,
        causal=causal, window=window, kernel="flash_attention_fwd")
    g = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(
            f"attention blocks must tile the sequence: Sq={Sq} bq={bq} "
            f"Sk={Sk} bk={bk}")
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(D)
    qt = q.transpose(0, 2, 1, 3)      # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kw = dict(scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk)
    in_specs = [
        pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // g, j, 0)),
        pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // g, j, 0)),
    ]
    scratch = [
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, D), jnp.float32),
    ]
    params = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
    o_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0))
    if not return_lse:
        out = pl.pallas_call(
            functools.partial(_attn_kernel, **kw),
            grid=(B, Hq, nq, nk), in_specs=in_specs, out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
            scratch_shapes=scratch, compiler_params=params,
            interpret=interpret,
        )(qt, kt, vt)
        return out.transpose(0, 2, 1, 3)
    out, lse = pl.pallas_call(
        functools.partial(_attn_kernel_lse, **kw),
        grid=(B, Hq, nq, nk), in_specs=in_specs,
        out_specs=[o_spec,
                   pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
                   jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32)],
        scratch_shapes=scratch, compiler_params=params,
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3), lse


# ------------------------------------------------------------------ backward
def _block_mask_iota(q_lo, k_lo, bq, bk, causal, window):
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, qpos >= kpos)
    if window:
        mask = jnp.logical_and(mask, qpos - kpos < window)
    return mask


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, causal, window, bq, bk, nk):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo, k_lo = iq * bq, ik * bk
    live = (k_lo <= q_lo + bq - 1) if causal else True
    if window and causal:
        # sliding window: blocks entirely left of the band are dead too
        live = jnp.logical_and(live, k_lo + bk - 1 >= q_lo - window + 1)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _block_mask_iota(q_lo, k_lo, bq, bk, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        acc_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if isinstance(live, bool):
        _compute()
    else:
        pl.when(live)(_compute)

    @pl.when(ik == nk - 1)
    def _finish():
        dq_ref[0, 0] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                bq, bk, nq):
    ik, iq = pl.program_id(2), pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_lo, k_lo = iq * bq, ik * bk
    live = (k_lo <= q_lo + bq - 1) if causal else True
    if window and causal:
        # sliding window: q blocks entirely past the band see nothing here
        live = jnp.logical_and(live, k_lo + bk - 1 >= q_lo - window + 1)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _block_mask_iota(q_lo, k_lo, bq, bk, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if isinstance(live, bool):
        _compute()
    else:
        pl.when(live)(_compute)

    @pl.when(iq == nq - 1)
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool = True,
                        window: int = 0, block_q: int | None = None,
                        block_k: int | None = None,
                        interpret: bool = False):
    """Returns (dq, dk, dv) with q/k/v in (B, S, H, D) layout.
    block_q/block_k None = auto (tuned cache)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    block_q, block_k = tuning.resolve_attention_blocks(
        block_q, block_k, q_shape=q.shape, k_shape=k.shape, dtype=q.dtype,
        causal=causal, window=window, kernel="flash_attention_bwd")
    g = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(D)
    qt, kt, vt = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
    dot_, ot = do.transpose(0, 2, 1, 3), o.transpose(0, 2, 1, 3)
    delta = jnp.sum(dot_.astype(jnp.float32) * ot.astype(jnp.float32),
                    axis=-1)                                   # (B,Hq,Sq)
    kw = dict(scale=scale, causal=causal, window=window, bq=bq, bk=bk)
    params = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
    row_spec = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, nk=nk, **kw),
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            row_spec, row_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=params, interpret=interpret,
    )(qt, kt, vt, dot_, lse, delta)

    row_spec2 = pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, nq=nq, **kw),
        grid=(B, Hq, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, j, i: (b, h, i, 0)),
            row_spec2, row_spec2,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Sk, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq, Sk, D), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        compiler_params=params, interpret=interpret,
    )(qt, kt, vt, dot_, lse, delta)
    # GQA: per-q-head dk/dv partials sum over the group
    dk = dk_h.reshape(B, Hkv, g, Sk, D).sum(2).transpose(0, 2, 1, 3)
    dv = dv_h.reshape(B, Hkv, g, Sk, D).sum(2).transpose(0, 2, 1, 3)
    return (dq.transpose(0, 2, 1, 3), dk.astype(k.dtype), dv.astype(v.dtype))
