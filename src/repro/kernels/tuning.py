"""Tuned tile-config lookup for the Pallas kernels.

Every kernel entry point (``flash_attention_fwd``/``_bwd``, ``wkv6_fwd``,
``rmsnorm_fwd``) and its jitted ``ops`` wrapper accepts ``None`` for its
tile parameters ("auto"). Resolution order:

1. an explicit value passed by the caller always wins;
2. otherwise the tuned-config cache is consulted under the kernel's
   (shape-signature, dtype, backend) key — winners persisted by the
   autotuner (:mod:`repro.bench.tune`) to ``results/tuned/<backend>.json``;
3. otherwise the historical constants in :data:`DEFAULTS` apply, so a
   cache-less checkout behaves exactly like the pre-tuning code.

Cache entries carry the environment fingerprint of the machine that
produced them; a load on a different backend / jax version / machine
ignores the file (stale tile choices are worse than defaults). Set
``REPRO_TUNED_DIR`` to relocate the cache (tests, CI sandboxes).

The parsed cache is held in memory per process; call :func:`clear_cache`
after writing new winners (the tuner's ``save`` does this) so same-process
lookups see them. Jitted wrappers resolve *before* tracing, so a new
winner means new static block args and a clean retrace.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

REPO = Path(__file__).resolve().parents[3]
DEFAULT_CACHE_DIR = REPO / "results" / "tuned"
ENV_VAR = "REPRO_TUNED_DIR"
CACHE_VERSION = 1

# The pre-tuning constants every kernel falls back to on a cache miss.
DEFAULTS: Dict[str, Dict[str, int]] = {
    "flash_attention_fwd": {"block_q": 128, "block_k": 128},
    "flash_attention_bwd": {"block_q": 128, "block_k": 128},
    "wkv6_fwd": {"chunk": 64},
    "rmsnorm_fwd": {"block_rows": 256},
    "paged_attention_fwd": {"pages_per_block": 1},
}

# env-fingerprint keys that must match for a cache file to be trusted
_ENV_MATCH_KEYS = ("backend", "jax", "machine")

_CACHE: Dict[str, Dict[str, Any]] = {}   # backend -> parsed entries


# ----------------------------------------------------------- capabilities
@dataclass(frozen=True)
class BackendCaps:
    """Per-backend hardware capability contract the kernel layer tiles
    against — the multi-backend seam (ROADMAP item 4): new targets slot
    in as entries here, and both the tile resolvers and the static
    kernel contract checker (:mod:`repro.analysis.kernel_lint`) read
    their legality limits from this table instead of hard-coding TPU
    constants.

    ``min_tile`` maps an operand dtype to the minimum legal
    (sublane, lane) tile of that backend's vector memory layout; block
    dimensions smaller than the minimum are padded (wasting VMEM, which
    the footprint model charges), while larger dimensions must be whole
    multiples to be MXU-friendly.
    """

    name: str
    mxu: Tuple[int, int] = (128, 128)            # systolic matmul tile
    vpu: Tuple[int, int] = (8, 128)              # vector unit shape
    vmem_bytes: int = 16 * 1024 * 1024           # per-core fast memory
    # minimum legal (sublane, lane) tile per operand dtype
    min_tile: Mapping[str, Tuple[int, int]] = field(
        default_factory=lambda: {
            "float32": (8, 128),
            "bfloat16": (16, 128),
            "float16": (16, 128),
            "int8": (32, 128),
            "uint8": (32, 128),
            "int32": (8, 128),
        })
    # double-buffered operand pipelining: in/out blocks are resident
    # twice while the grid streams (the footprint model's multiplier)
    pipeline_buffers: int = 2

    @property
    def lane(self) -> int:
        return self.mxu[1]

    def supports(self, dtype: Any) -> bool:
        return _dtype_name(dtype) in self.min_tile

    def sublane(self, dtype: Any) -> int:
        """Minimum second-minor block extent for ``dtype`` (f32 fallback
        for dtypes outside the table, so footprint stays computable)."""
        return self.min_tile.get(_dtype_name(dtype),
                                 self.min_tile["float32"])[0]

    def padded_bytes(self, shape: Tuple[int, ...], dtype: Any) -> int:
        """VMEM bytes one block/scratch buffer of ``shape`` occupies once
        tiled: the last dim pads to the lane width, the second-minor to
        the dtype's sublane minimum (1-D buffers pad to one sublane)."""
        if not shape:
            return int(np.dtype(dtype).itemsize)
        dims = list(int(d) for d in shape)
        dims[-1] = -(-dims[-1] // self.lane) * self.lane
        sub = self.sublane(dtype)
        if len(dims) >= 2:
            dims[-2] = -(-dims[-2] // sub) * sub
        n = 1
        for d in dims:
            n *= d
        return n * int(np.dtype(dtype).itemsize)


BACKEND_CAPS: Dict[str, BackendCaps] = {
    # real TPU cores and interpret mode (cpu) share one contract: the
    # Pallas kernels are written against TPU tiling either way, and a
    # tile that is illegal on hardware should fail the lint even when
    # the test host happens to interpret it
    "tpu": BackendCaps(name="tpu"),
    "cpu": BackendCaps(name="cpu"),
    # placeholder Mosaic-GPU entry: tensor-core MMA tile with a shared
    # memory budget standing in for VMEM until GPU kernel variants land
    "gpu": BackendCaps(name="gpu", mxu=(64, 64), vpu=(1, 32),
                       vmem_bytes=228 * 1024,
                       min_tile={"float32": (8, 32), "bfloat16": (8, 32),
                                 "float16": (8, 32), "int8": (16, 32),
                                 "int32": (8, 32)}),
}


def capabilities(backend: Optional[str] = None) -> BackendCaps:
    """Capability entry for ``backend`` (default: the executing jax
    backend). Unknown backends get the TPU contract — the conservative
    choice, since every kernel here is authored against TPU tiling."""
    be = backend or backend_name()
    return BACKEND_CAPS.get(be, BACKEND_CAPS["tpu"])


# ------------------------------------------------------------ environment
def _env_fingerprint() -> Dict[str, Any]:
    from repro.bench.record import env_fingerprint

    return env_fingerprint()


def backend_name() -> str:
    """Key the cache by the executing jax backend (cpu = interpret mode)."""
    try:
        import jax

        return jax.default_backend()
    except Exception:
        return "unknown"


def cache_dir() -> Path:
    return Path(os.environ.get(ENV_VAR, str(DEFAULT_CACHE_DIR)))


def cache_path(backend: Optional[str] = None) -> Path:
    return cache_dir() / f"{backend or backend_name()}.json"


# ------------------------------------------------------------- signatures
def _dtype_name(dtype: Any) -> str:
    return np.dtype(dtype).name


def signature(**dims: Any) -> str:
    """Canonical shape-signature string: sorted ``k=v`` pairs."""
    return ",".join(f"{k}={v}" for k, v in sorted(dims.items()))


def attention_signature(q_shape, k_shape, dtype, *, causal: bool,
                        window: int) -> str:
    B, Sq, Hq, D = q_shape
    _, Sk, Hkv, _ = k_shape
    return signature(B=B, Sq=Sq, Sk=Sk, Hq=Hq, Hkv=Hkv, D=D,
                     dtype=_dtype_name(dtype), causal=int(bool(causal)),
                     window=int(window))


def wkv6_signature(q_shape, v_head: int, dtype, *, use_u: bool) -> str:
    B, T, H, K = q_shape
    return signature(B=B, T=T, H=H, K=K, V=int(v_head),
                     dtype=_dtype_name(dtype), u=int(bool(use_u)))


def rmsnorm_signature(rows: int, d: int, dtype) -> str:
    return signature(rows=int(rows), d=int(d), dtype=_dtype_name(dtype))


def paged_attention_signature(q_shape, pages_shape, n_pages: int,
                              dtype) -> str:
    B, _, Hq, D = q_shape
    P, ps, Hkv, _ = pages_shape
    return signature(B=B, Hq=Hq, Hkv=Hkv, D=D, P=P, ps=ps,
                     npag=int(n_pages), dtype=_dtype_name(dtype))


# ------------------------------------------------------------------ cache
def clear_cache() -> None:
    _CACHE.clear()


def _load(path: Path) -> Dict[str, Any]:
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return {}
    if data.get("version") != CACHE_VERSION:
        return {}
    stored = data.get("env", {})
    try:
        current = _env_fingerprint()
    except Exception:
        current = {}
    for key in _ENV_MATCH_KEYS:
        if key in stored or key in current:
            if stored.get(key) != current.get(key):
                return {}   # fingerprint mismatch: tuned elsewhere, ignore
    return dict(data.get("entries", {}))


def _entries(backend: Optional[str] = None) -> Dict[str, Any]:
    be = backend or backend_name()
    if be not in _CACHE:
        _CACHE[be] = _load(cache_path(be))
    return _CACHE[be]


def entry_key(kernel: str, sig: str) -> str:
    return f"{kernel}|{sig}"


def lookup(kernel: str, sig: str,
           backend: Optional[str] = None) -> Optional[Dict[str, int]]:
    """Tuned config for (kernel, signature), or None on a cache miss."""
    entry = _entries(backend).get(entry_key(kernel, sig))
    if not entry:
        return None
    return dict(entry.get("config", {})) or None


def resolve(kernel: str, sig: str, **overrides: Optional[int]
            ) -> Dict[str, int]:
    """DEFAULTS <- tuned cache <- explicit (non-None) caller overrides."""
    cfg = dict(DEFAULTS[kernel])
    tuned = lookup(kernel, sig)
    if tuned:
        cfg.update({k: int(v) for k, v in tuned.items() if k in cfg})
    cfg.update({k: int(v) for k, v in overrides.items() if v is not None})
    return cfg


def save_entries(entries: Dict[str, Dict[str, Any]],
                 backend: Optional[str] = None) -> Path:
    """Merge winners into the per-backend cache file (atomic replace)."""
    be = backend or backend_name()
    path = cache_path(be)
    path.parent.mkdir(parents=True, exist_ok=True)
    merged = _load(path)     # keep prior entries only if env still matches
    merged.update(entries)
    try:
        env = _env_fingerprint()
    except Exception:
        env = {}
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(
        {"version": CACHE_VERSION, "env": env, "entries": merged},
        indent=1, sort_keys=True))
    os.replace(tmp, path)
    clear_cache()
    return path


# -------------------------------------------------- per-kernel resolvers
def resolve_attention_blocks(block_q: Optional[int], block_k: Optional[int],
                             *, q_shape, k_shape, dtype, causal: bool,
                             window: int,
                             kernel: str = "flash_attention_fwd"
                             ) -> Tuple[int, int]:
    if block_q is not None and block_k is not None:
        return int(block_q), int(block_k)
    sig = attention_signature(q_shape, k_shape, dtype, causal=causal,
                              window=window)
    cfg = resolve(kernel, sig, block_q=block_q, block_k=block_k)
    return cfg["block_q"], cfg["block_k"]


def resolve_wkv_chunk(chunk: Optional[int], *, q_shape, v_head: int, dtype,
                      use_u: bool) -> int:
    if chunk is not None:
        return int(chunk)
    sig = wkv6_signature(q_shape, v_head, dtype, use_u=use_u)
    return resolve("wkv6_fwd", sig)["chunk"]


def clamp_rmsnorm_rows(block_rows: int, *, d: int, dtype,
                       backend: Optional[str] = None) -> int:
    """Shrink ``block_rows`` (halving) until the fused footprint — in and
    out blocks double-buffered plus the f32 working copy — fits the
    backend's VMEM budget. The historical 256-row default overflows at
    d=4096/f32 (~21 MB vs 16 MB); the auto path clamps so wide models
    get the largest block that actually fits, the same way the paged
    resolver clamps pages_per_block to the block table."""
    caps = capabilities(backend)
    br = max(int(block_rows), 1)

    def fits(r: int) -> bool:
        blocks = 2 * caps.pipeline_buffers * caps.padded_bytes((r, d), dtype)
        work = caps.padded_bytes((r, d), "float32")
        return blocks + work <= caps.vmem_bytes

    floor = caps.sublane(dtype)
    while br > floor and not fits(br):
        br //= 2
    return max(br, 1)


def resolve_rmsnorm_rows(block_rows: Optional[int], *, rows: int, d: int,
                         dtype) -> int:
    if block_rows is not None:
        return int(block_rows)   # explicit caller value always wins
    sig = rmsnorm_signature(rows, d, dtype)
    return clamp_rmsnorm_rows(resolve("rmsnorm_fwd", sig)["block_rows"],
                              d=d, dtype=dtype)


def resolve_paged_pages_per_block(pages_per_block: Optional[int], *,
                                  q_shape, pages_shape, n_pages: int,
                                  dtype) -> int:
    """Explicit > tuned > default, clamped to [1, n_pages] so any source
    (caller, stale cache entry) yields a tiling the block table can
    satisfy."""
    if pages_per_block is None:
        sig = paged_attention_signature(q_shape, pages_shape, n_pages,
                                        dtype)
        pages_per_block = resolve("paged_attention_fwd",
                                  sig)["pages_per_block"]
    return max(1, min(int(pages_per_block), int(n_pages)))
