"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import dense_attention
from repro.models.ssm import recurrent_linear_attention


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    return dense_attention(q, k, v, causal=causal, window=window)


def wkv6_ref(q, k, v, ld, u=None):
    """Exact per-token recurrence (models/ssm.py oracle)."""
    return recurrent_linear_attention(q, k, v, ld, u)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)
