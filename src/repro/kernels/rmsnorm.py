"""Fused RMSNorm(+scale) — Pallas TPU kernel. One row-block per grid step,
mean-square in f32, single pass over VMEM-resident rows.

``block_rows=None`` ("auto") resolves through the tuned-config cache
(:mod:`repro.kernels.tuning`, populated by ``benchmarks.run --tune``),
falling back to the historical 256-row blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-5,
                block_rows: int | None = None, interpret: bool = False):
    """x: (..., d); scale: (d,). Fused in one VMEM pass per row block.
    block_rows None = auto (tuned cache)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    block_rows = tuning.resolve_rmsnorm_rows(block_rows, rows=rows, d=d,
                                             dtype=x.dtype)
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
