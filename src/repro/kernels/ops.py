"""Jitted public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as Python/XLA on CPU); on TPU `interpret=False` compiles real
Mosaic kernels. The model layer selects these via backend='pallas'.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (flash_attention_bwd,
                                           flash_attention_fwd)
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.rwkv6 import wkv6_fwd

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, block_q, block_k):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=INTERPRET)


def _fa_fwd(q, k, v, causal, window, block_q, block_k):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=INTERPRET, return_lse=True)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, block_q, block_k, res, do):
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=INTERPRET)


_flash_attention.defvjp(_fa_fwd, _fa_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """Differentiable flash attention: Pallas forward AND backward kernels
    (dq + dkv with saved logsumexp), custom_vjp-wired."""
    return _flash_attention(q, k, v, causal, window, block_q, block_k)


@partial(jax.jit, static_argnames=("chunk",))
def wkv6(q, k, v, ld, u=None, initial_state=None, *, chunk: int = 64):
    """Matches models.ssm.linear_attention's (o, state) contract. A nonzero
    initial_state is folded in by running the state-only recurrence first."""
    o, state = wkv6_fwd(q, k, v, ld, u, chunk=chunk, interpret=INTERPRET)
    if initial_state is not None:
        # contribution of the carried-in state: q'_t @ (decay_t . S0)
        f32 = jnp.float32
        p_exc = jnp.cumsum(ld.astype(f32), axis=1) - (
            0.0 if u is None else ld.astype(f32))
        extra = jnp.einsum("bthk,bhkv->bthv",
                           q.astype(f32) * jnp.exp(p_exc),
                           initial_state.astype(f32))
        o = o + extra.astype(o.dtype)
        total_decay = jnp.exp(jnp.sum(ld.astype(f32), axis=1))  # (B,H,K)
        state = state + total_decay[..., None] * initial_state
    return o, state


@partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, scale, *, eps: float = 1e-5):
    return rmsnorm_fwd(x, scale, eps=eps, interpret=INTERPRET)
