"""Jitted public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as Python/XLA on CPU); on TPU `interpret=False` compiles real
Mosaic kernels. The model layer selects these via backend='pallas'.

Tile parameters default to ``None`` ("auto"): each wrapper resolves them
*eagerly* through the tuned-config cache (:mod:`repro.kernels.tuning`,
written by ``python -m benchmarks.run --tune``) before handing concrete
ints to jit as static args — so freshly tuned winners take effect in the
same process via a clean retrace, and a cache-less checkout keeps the
historical constants (128/128 blocks, chunk 64, 256 rows).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.flash_attention import (flash_attention_bwd,
                                           flash_attention_fwd)
from repro.kernels.paged_attention import paged_attention_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.rwkv6 import wkv6_fwd

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not _ON_TPU


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_attention(q, k, v, causal, window, block_q, block_k,
                     bwd_block_q, bwd_block_k):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=INTERPRET)


def _fa_fwd(q, k, v, causal, window, block_q, block_k, bwd_block_q,
            bwd_block_k):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=INTERPRET, return_lse=True)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, block_q, block_k, bwd_block_q, bwd_block_k,
            res, do):
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                               window=window, block_q=bwd_block_q,
                               block_k=bwd_block_k, interpret=INTERPRET)


_flash_attention.defvjp(_fa_fwd, _fa_bwd)

_flash_attention_jit = jax.jit(_flash_attention,
                               static_argnums=(3, 4, 5, 6, 7, 8))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int | None = None,
                    block_k: int | None = None):
    """Differentiable flash attention: Pallas forward AND backward kernels
    (dq + dkv with saved logsumexp), custom_vjp-wired. block_q/block_k
    None = auto: forward and backward each resolve their own tuned tile
    config; an explicit value applies to both."""
    bq, bk = tuning.resolve_attention_blocks(
        block_q, block_k, q_shape=q.shape, k_shape=k.shape, dtype=q.dtype,
        causal=causal, window=window, kernel="flash_attention_fwd")
    bq_b, bk_b = tuning.resolve_attention_blocks(
        block_q, block_k, q_shape=q.shape, k_shape=k.shape, dtype=q.dtype,
        causal=causal, window=window, kernel="flash_attention_bwd")
    return _flash_attention_jit(q, k, v, causal, window, bq, bk, bq_b,
                                bk_b)


@partial(jax.jit, static_argnames=("chunk",))
def _wkv6_jit(q, k, v, ld, u=None, initial_state=None, *, chunk: int = 64):
    o, state = wkv6_fwd(q, k, v, ld, u, chunk=chunk, interpret=INTERPRET)
    if initial_state is not None:
        # contribution of the carried-in state: q'_t @ (decay_t . S0)
        f32 = jnp.float32
        p_exc = jnp.cumsum(ld.astype(f32), axis=1) - (
            0.0 if u is None else ld.astype(f32))
        extra = jnp.einsum("bthk,bhkv->bthv",
                           q.astype(f32) * jnp.exp(p_exc),
                           initial_state.astype(f32))
        o = o + extra.astype(o.dtype)
        total_decay = jnp.exp(jnp.sum(ld.astype(f32), axis=1))  # (B,H,K)
        state = state + total_decay[..., None] * initial_state
    return o, state


def wkv6(q, k, v, ld, u=None, initial_state=None, *,
         chunk: int | None = None):
    """Matches models.ssm.linear_attention's (o, state) contract. A nonzero
    initial_state is folded in by running the state-only recurrence first.
    chunk None = auto (tuned cache -> 64)."""
    c = tuning.resolve_wkv_chunk(chunk, q_shape=q.shape,
                                 v_head=v.shape[-1], dtype=q.dtype,
                                 use_u=u is not None)
    return _wkv6_jit(q, k, v, ld, u, initial_state, chunk=c)


@partial(jax.jit, static_argnames=("pages_per_block",))
def _paged_attention_jit(q, k_pages, v_pages, block_tables, lengths, *,
                         pages_per_block: int = 1):
    return paged_attention_fwd(q, k_pages, v_pages, block_tables, lengths,
                               pages_per_block=pages_per_block,
                               interpret=INTERPRET)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           pages_per_block: int | None = None):
    """Block-table paged decode attention (no backward: decode only).
    pages_per_block None = auto (tuned cache -> 1)."""
    ppb = tuning.resolve_paged_pages_per_block(
        pages_per_block, q_shape=q.shape, pages_shape=k_pages.shape,
        n_pages=block_tables.shape[1], dtype=q.dtype)
    return _paged_attention_jit(q, k_pages, v_pages, block_tables, lengths,
                                pages_per_block=ppb)


@partial(jax.jit, static_argnames=("eps", "block_rows"))
def _rmsnorm_jit(x, scale, *, eps: float = 1e-5, block_rows: int = 256):
    return rmsnorm_fwd(x, scale, eps=eps, block_rows=block_rows,
                       interpret=INTERPRET)


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int | None = None):
    """Fused RMSNorm. block_rows None = auto (tuned cache -> 256)."""
    br = tuning.resolve_rmsnorm_rows(
        block_rows, rows=int(np.prod(x.shape[:-1], dtype=np.int64)),
        d=x.shape[-1], dtype=x.dtype)
    return _rmsnorm_jit(x, scale, eps=eps, block_rows=br)
