"""Chunked WKV6 (data-dependent-decay linear attention) — Pallas TPU kernel.

Grid: (B, H, T/chunk) with the chunk dim SEQUENTIAL so the (K, V) recurrent
state lives in VMEM scratch across chunks. Per chunk the kernel computes

  o_t = q'_t @ S  +  sum_{s<t} (q_t . k_s . exp(p_{t-1}-p_s)) v_s  [+ u bonus]
  S  <- exp(p_last) . S  +  sum_s (k_s exp(p_last - p_s)) (x) v_s

with all decay factors exp(<=0) (numerically safe; see models/ssm.py for
the derivation).

The intra-chunk attention A[t,s] = q_t . (k_s exp(w_t - p_s)) is computed
as a decay-rescaled matmul (q exp(w)) @ (k exp(-p)).T so the inner loop is
MXU work; exp(-p) grows with the in-chunk decay range, so when that range
exceeds SAFE_DECAY_RANGE the kernel falls back to the masked (c, c, K)
pairwise-decay tensor (c=64, K=64 -> 1 MB f32, well inside the 16 MB
budget). Chunk matmuls are MXU-aligned at (64, 64).

Supports both rwkv6 mode (bonus u, current token excluded from the state
it sees) and SSD mode (u=None, current token included). ``chunk=None``
("auto") resolves through the tuned-config cache
(:mod:`repro.kernels.tuning`, populated by ``benchmarks.run --tune``),
falling back to the historical chunk=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning

# jax < 0.5 ships this as TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

# Largest in-chunk |cumsum(ld)| for which the decay-rescaled matmul path
# is used: factors stay <= exp(30) ~ 1e13, far from f32 overflow even
# after the (masked-out) upper-triangle products and the K-dim reduction.
SAFE_DECAY_RANGE = 30.0


def _wkv_kernel(q_ref, k_ref, v_ref, ld_ref, u_ref, o_ref, state_out_ref,
                s_scr, *, chunk: int, n_chunks: int, use_u: bool):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    c = chunk
    q = q_ref[0, 0].astype(jnp.float32)          # (c, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)          # (c, V)
    ld = ld_ref[0, 0].astype(jnp.float32)        # (c, K)

    p_inc = jnp.cumsum(ld, axis=0)
    p_exc = p_inc - ld
    w_exp = p_exc if use_u else p_inc

    # intra-chunk attention A[t,s] = q_t . (k_s exp(w_t - p_s)), s <(=) t
    t_i = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    mask = (t_i > s_i) if use_u else (t_i >= s_i)

    def _intra_matmul(_):
        # decay-rescaled matmul (MXU path): exp(w) <= 1 and exp(-p) is
        # bounded by exp(SAFE_DECAY_RANGE), so both factors are finite
        qs = q * jnp.exp(w_exp)
        ks = k * jnp.exp(-p_inc)
        a = jax.lax.dot_general(qs, ks, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return jnp.where(mask, a, 0.0)

    def _intra_pairwise(_):
        # masked fallback: exact per-pair decay, (c, c, K) tensor in VMEM
        diff = w_exp[:, None, :] - p_inc[None, :, :]          # (c, c, K)
        diff = jnp.where(mask[:, :, None], diff, -jnp.inf)
        return jnp.einsum("tk,sk,tsk->ts", q, k, jnp.exp(diff))

    # p_inc is a cumsum of ld <= 0, so -min(p_inc) is the chunk's largest
    # decay magnitude; beyond SAFE_DECAY_RANGE exp(-p_inc) would overflow
    a = jax.lax.cond(-jnp.min(p_inc) < SAFE_DECAY_RANGE,
                     _intra_matmul, _intra_pairwise, 0)
    o = jax.lax.dot_general(a.astype(v.dtype), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if use_u:
        u = u_ref[0].astype(jnp.float32)                      # (K,)
        diag = jnp.sum(q * u[None, :] * k, axis=1, keepdims=True)
        o = o + diag * v

    # cross-chunk state contribution + recurrence
    S = s_scr[...]                                            # (K, V)
    o = o + jax.lax.dot_general((q * jnp.exp(w_exp)), S,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    p_last = p_inc[-1:, :]                                    # (1, K)
    k_dec = k * jnp.exp(p_last - p_inc)
    s_scr[...] = jnp.exp(p_last).T * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = s_scr[...]


def wkv6_fwd(q, k, v, ld, u=None, *, chunk: int | None = None,
             interpret: bool = False):
    """q/k/ld: (B, T, H, K); v: (B, T, H, V); u: (H, K) or None.
    Returns (o (B,T,H,V), state (B,H,K,V)). chunk None = auto (tuned)."""
    B, T, H, K = q.shape
    V = v.shape[-1]
    chunk = tuning.resolve_wkv_chunk(chunk, q_shape=q.shape, v_head=V,
                                     dtype=q.dtype, use_u=u is not None)
    c = min(chunk, T)
    if T % c:
        raise ValueError(f"wkv6 chunk must tile the sequence: T={T} c={c}")
    n = T // c
    use_u = u is not None
    if u is None:
        u = jnp.zeros((H, K), jnp.float32)

    def tr(x):
        return x.transpose(0, 2, 1, 3)    # (B, H, T, *)

    kernel = functools.partial(_wkv_kernel, chunk=c, n_chunks=n, use_u=use_u)
    o, state = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, c, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, V), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, K), lambda b, h, i: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, V), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, V), q.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tr(q), tr(k), tr(v), tr(ld), u)
    return o.transpose(0, 2, 1, 3), state
