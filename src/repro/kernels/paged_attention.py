"""Paged decode attention — Pallas TPU kernel over a block-table KV pool.

One decode token per sequence attends to its KV history stored in
scattered fixed-size pages of a global pool (see
:mod:`repro.serving.pages`). The physical pages are *gathered inside the
kernel*: the per-sequence block table rides in as a scalar-prefetch SMEM
operand, and each K/V BlockSpec's ``index_map`` reads the table to pick
the physical page its DMA fetches — the pool never has to be gathered
into a contiguous activation on the host side.

Tiling: grid ``(B, Hkv, n_blocks)`` with the page-block dim innermost
and sequential ("arbitrary"), so the online-softmax accumulators live in
VMEM scratch across page blocks. The tunable tile parameter is
``pages_per_block``: how many pages one grid step consumes. It is
realised by passing the pool ``pages_per_block`` times with offset
index maps — each copy is an independent page DMA the pipeline keeps in
flight, so larger values trade VMEM for fewer grid steps. Like every
other kernel, ``pages_per_block=None`` means "auto": resolved from the
tuned-config cache (:mod:`repro.kernels.tuning`, populated by
``python -m benchmarks.run --tune``), default 1.

Pages logically past a sequence's length are skipped with ``pl.when``;
their block-table entries point at the reserved null page (id 0) so even
the skipped DMAs touch valid memory.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning

# jax < 0.5 ships this as TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _paged_kernel(btab_ref, len_ref, q_ref, *refs, scale: float, ps: int,
                  ppb: int, nb: int, g: int):
    """refs = k_ref x ppb, v_ref x ppb, o_ref, m_scr, l_scr, acc_scr."""
    k_refs = refs[:ppb]
    v_refs = refs[ppb:2 * ppb]
    o_ref, m_scr, l_scr, acc_scr = refs[2 * ppb:]
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32) * scale              # (g, D)
    for p in range(ppb):
        page_start = (j * ppb + p) * ps                      # logical pos

        def _consume(p=p, page_start=page_start):
            k = k_refs[p][0, :, 0, :].astype(jnp.float32)    # (ps, D)
            v = v_refs[p][0, :, 0, :].astype(jnp.float32)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            kpos = page_start + jax.lax.broadcasted_iota(
                jnp.int32, (g, ps), 1)
            s = jnp.where(kpos < length, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            pe = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * corr + pe.sum(axis=1, keepdims=True)
            acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
                pe, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_scr[...] = m_new

        pl.when(page_start < length)(_consume)

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_fwd(q, k_pages, v_pages, block_tables, lengths, *,
                        pages_per_block: int | None = None,
                        interpret: bool = False):
    """q: (B, 1, Hq, D); k_pages/v_pages: (P, page_size, Hkv, D);
    block_tables: (B, n_pages) int32 physical page ids (logical order,
    padded with the null page 0); lengths: (B,) int32 valid KV tokens.
    Returns (B, 1, Hq, D). pages_per_block None = auto (tuned cache)."""
    B, one, Hq, D = q.shape
    if one != 1:
        raise ValueError(
            f"paged decode attention takes one query token per row, got "
            f"q.shape={q.shape}")
    P, ps, Hkv, _ = k_pages.shape
    npag = block_tables.shape[1]
    g = Hq // Hkv
    ppb = tuning.resolve_paged_pages_per_block(
        pages_per_block, q_shape=q.shape, pages_shape=k_pages.shape,
        n_pages=npag, dtype=q.dtype)
    nb = -(-npag // ppb)                      # grid steps over page blocks
    pad = nb * ppb - npag
    btab = jnp.asarray(block_tables, jnp.int32)
    if pad:
        btab = jnp.pad(btab, ((0, 0), (0, pad)))      # null-page padding
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    qg = q.reshape(B, Hkv, g, D)              # GQA groups as a row tile

    def q_map(b, h, j, bt, ln):
        return (b, h, 0, 0)

    def kv_map(p):
        # the in-kernel gather: physical page id straight from the table
        def index_map(b, h, j, bt, ln, p=p):
            return (bt[b, j * ppb + p], 0, h, 0)
        return index_map

    in_specs = [pl.BlockSpec((1, 1, g, D), q_map)]
    in_specs += [pl.BlockSpec((1, ps, 1, D), kv_map(p)) for p in range(ppb)]
    in_specs += [pl.BlockSpec((1, ps, 1, D), kv_map(p)) for p in range(ppb)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_kernel, scale=1.0 / np.sqrt(D), ps=ps,
                             ppb=ppb, nb=nb, g=g)
    kv = (k_pages.reshape(P, ps, Hkv, D), v_pages.reshape(P, ps, Hkv, D))
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(btab, lengths, qg, *([kv[0]] * ppb), *([kv[1]] * ppb))
    return out.reshape(B, 1, Hq, D)
