"""GPipe-style pipeline parallelism over a mesh axis (the paper's
Graphcore/IPU execution model, §III.C / Fig. 11c).

Stages map to slices of the stacked layer parameters (the leading L dim is
sharded over the pipe axis), microbatches flow stage-to-stage with
collective_permute, and uneven layer->stage assignments are first-class —
the Tier-2 benchmark reproduces the paper's finding that throughput is
governed by the most-loaded stage.

This is a correctness/benchmark-grade schedule (GPipe with output
collection on the last stage); production would add 1F1B and weight
sharding within stages, noted in DESIGN.md.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import axis_size, shard_map


def stage_layout(num_layers: int, stage_layers: Sequence[int]):
    """Map layer index -> (stage, slot) with per-stage padding to max."""
    if sum(stage_layers) != num_layers:
        raise ValueError(
            f"stage_layers {tuple(stage_layers)} must sum to "
            f"num_layers={num_layers}")
    lmax = max(stage_layers)
    layer_of = []
    for s, n in enumerate(stage_layers):
        for j in range(n):
            layer_of.append((s, j))
    return lmax, layer_of


def stack_stages(stacked_params, stage_layers: Sequence[int]):
    """(L, ...) param leaves -> ((S, Lmax, ...), valid_mask (S, Lmax))."""
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    S = len(stage_layers)
    lmax, _ = stage_layout(L, stage_layers)
    bounds = np.cumsum([0] + list(stage_layers))
    mask = np.zeros((S, lmax), bool)
    for s, n in enumerate(stage_layers):
        mask[s, :n] = True

    def per_leaf(x):
        out = jnp.zeros((S, lmax) + x.shape[1:], x.dtype)
        for s in range(S):
            sl = x[bounds[s]:bounds[s + 1]]
            out = out.at[s, : stage_layers[s]].set(sl)
        return out

    return jax.tree.map(per_leaf, stacked_params), jnp.asarray(mask)


def pipeline_forward(staged_params, valid_mask, mbs, layer_fn,
                     *, axis: str = "model"):
    """GPipe forward. mbs: (M, mb, S_seq, d) microbatch activations
    (replicated over the pipe axis); staged_params leaves: (S, Lmax, ...)
    sharded P(axis, ...). Returns (M, mb, S_seq, d) final-stage outputs.

    layer_fn(x, p_layer) -> x.
    """
    M = mbs.shape[0]

    def local(mbs_l, params_l, mask_l):
        # params_l leaves: (1, Lmax, ...) local stage slice
        params_l = jax.tree.map(lambda x: x[0], params_l)
        mask_l = mask_l[0]
        s = jax.lax.axis_index(axis)
        S = axis_size(axis)

        def run_stage(x):
            def body(c, xs):
                p, valid = xs
                y = layer_fn(c, p)
                return jnp.where(valid, y, c), None
            y, _ = jax.lax.scan(body, x, (params_l, mask_l))
            return y

        zero = jnp.zeros_like(mbs_l[0])
        outs0 = jnp.zeros_like(mbs_l)

        def step(t, carry):
            act, outs = carry
            mb_idx = t - s
            active = (mb_idx >= 0) & (mb_idx < M)
            safe = jnp.clip(mb_idx, 0, M - 1)
            x_in = jnp.where(s == 0, mbs_l[safe], act)
            y = run_stage(x_in)
            y = jnp.where(active, y, zero)
            outs = jnp.where(
                active & (s == S - 1),
                outs.at[safe].set(y), outs)
            # hand activation to the next stage
            y_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(S - 1)])
            return (y_next, outs)

        S_static = valid_mask.shape[0]
        (_, outs) = jax.lax.fori_loop(
            0, M + S_static - 1, step, (zero, outs0))
        # only the last stage holds nonzero outputs; psum broadcasts them so
        # out_specs can be replicated over the pipe axis.
        return jax.lax.psum(outs, axis)

    return shard_map(
        local,
        in_specs=(P(None), jax.tree.map(lambda _: P(axis), staged_params),
                  P(axis)),
        out_specs=P(None),
        check_vma=False,
    )(mbs, staged_params, valid_mask)


def pipeline_step_time(stage_layers: Sequence[int], per_layer_s: float,
                       n_microbatches: int) -> float:
    """Analytic GPipe step time: (M + S - 1) x slowest stage."""
    return (n_microbatches + len(stage_layers) - 1) * \
        max(stage_layers) * per_layer_s


def stage_utilization(stage_layers: Sequence[int]) -> List[float]:
    """Useful-layer fraction per stage under this module's padded scan
    schedule: every stage executes Lmax layer slots and masks the invalid
    ones, so stage s does n_s/Lmax useful work — the per-stage Eq. 1
    allocation ratio of the pipeline."""
    if not stage_layers:
        return []
    lmax = max(stage_layers)
    return [n / lmax for n in stage_layers]


def pipeline_allocation(stage_layers: Sequence[int]) -> float:
    """Eq. 2 over pipeline stages. Every stage is busy for the same wall
    time under the padded schedule (runtime weights are equal), so the
    runtime-weighted allocation collapses to the mean per-stage useful
    fraction: mean(n_s) / Lmax. 1.0 = perfectly even split."""
    util = stage_utilization(stage_layers)
    return sum(util) / len(util) if util else 0.0
