"""Collective building blocks implemented with shard_map.

partitioned_decode_attention: flash-decoding-style single-token attention
against a KV cache whose SEQUENCE dim is sharded over the `model` axis. Each
shard attends to its local cache slice and the partial (max, sum-exp,
weighted-value) triples are combined with two psums — the cache is never
gathered. This is what makes 32k-context decode of 100B-scale models fit
v5e HBM (gathering the cache would need ~85 GB/device).

compressed_psum_grads: int8 error-feedback gradient all-reduce over the data
axes (all-gather-of-quantized-shards form), used by the optional
``grad_compression='int8'`` run flag.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map


def _shard_map(fn, in_specs, out_specs):
    return shard_map(fn, in_specs=in_specs, out_specs=out_specs,
                         check_vma=False)


def partitioned_decode_attention(q, k_cache, v_cache, cache_len,
                                 *, seq_axis: str = "model",
                                 batch_axes=("data",)):
    """q:(B,1,Hq,D); k_cache/v_cache:(B,S,Hkv,D) with S sharded over
    seq_axis and B over batch_axes; cache_len: scalar valid length, or a
    (B,) vector of per-row lengths (continuous batching)."""
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    g = Hq // Hkv
    bspec = batch_axes if batch_axes else None
    per_row = jnp.ndim(cache_len) > 0
    len_spec = P(bspec) if per_row else P()

    def local(q, k, v, cache_len):
        idx = jax.lax.axis_index(seq_axis)
        s_loc = k.shape[1]
        qg = q.reshape(-1, Hkv, g, D)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, k) / np.sqrt(D)
        s = s.astype(jnp.float32)
        gpos = idx * s_loc + jnp.arange(s_loc)
        mask = gpos[None, :] < jnp.reshape(cache_len, (-1, 1))
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        m_loc = s.max(-1)                                     # (b,h,g)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = p.sum(-1)
        o_loc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v)
        # lse-combine across sequence shards
        m_glob = jax.lax.pmax(m_loc, seq_axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, seq_axis)
        o = jax.lax.psum(o_loc * corr[..., None].astype(v.dtype), seq_axis)
        o = o / jnp.maximum(l_glob[..., None], 1e-30).astype(v.dtype)
        return o.reshape(-1, 1, Hq, D)

    return _shard_map(
        local,
        in_specs=(P(bspec, None, None, None), P(bspec, seq_axis, None, None),
                  P(bspec, seq_axis, None, None), len_spec),
        out_specs=P(bspec, None, None, None),
    )(q, k_cache, v_cache, cache_len)


# --------------------------------------------------------------------------
def int8_quantize(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_grads(grads, residuals, data_axes=("data",)):
    """Error-feedback int8 all-reduce over the data axes — the classic
    two-phase compressed ring: (1) quantize local chunks, all_to_all so
    each device owns one chunk's contributions; (2) sum exactly, re-quantize
    the owned chunk and all_gather. Both phases move int8 (~4x fewer wire
    bytes than an f32 ring all-reduce; 2x vs bf16), and the local
    quantization error is fed back into the next step's gradient.
    Returns (reduced_grads, new_residuals).
    """
    axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def reduce_leaf(g, r):
        flat = g.reshape(-1).astype(jnp.float32) + r

        def body(x):
            n = jax.lax.psum(1, axis)
            pad = (-x.shape[0]) % n
            xp = jnp.pad(x, (0, pad))
            c = xp.shape[0] // n
            chunks = xp.reshape(n, c)
            # phase 1: per-chunk int8, all_to_all so device i owns chunk i
            scale = jnp.maximum(jnp.abs(chunks).max(axis=1), 1e-12) / 127.0
            q = jnp.clip(jnp.round(chunks / scale[:, None]),
                         -127, 127).astype(jnp.int8)
            qs = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0)
            ss = jax.lax.all_to_all(scale[:, None], axis,
                                    split_axis=0, concat_axis=0)
            summed = (qs.astype(jnp.float32) * ss).sum(0)          # (c,)
            # phase 2: re-quantize the owned summed chunk, all_gather int8
            s2 = jnp.maximum(jnp.abs(summed).max(), 1e-12) / 127.0
            q2 = jnp.clip(jnp.round(summed / s2), -127, 127).astype(jnp.int8)
            out_q = jax.lax.all_gather(q2, axis, tiled=True)       # (n*c,)
            out_s = jax.lax.all_gather(s2[None], axis, tiled=True)  # (n,)
            out = (out_q.reshape(n, c).astype(jnp.float32)
                   * out_s[:, None]).reshape(-1)[: x.shape[0]]
            # error feedback: local phase-1 loss + (replicated) phase-2 loss
            err1 = (chunks - q.astype(jnp.float32)
                    * scale[:, None]).reshape(-1)[: x.shape[0]]
            err2 = jax.lax.all_gather(summed - q2.astype(jnp.float32) * s2,
                                      axis, tiled=True)[: x.shape[0]]
            return out, err1 + err2 / jnp.maximum(n, 1)
            # (err2/n: each device will re-contribute it next step)

        out, err = _shard_map(
            body, in_specs=P(None), out_specs=(P(None), P(None)))(flat)
        return out.reshape(g.shape).astype(g.dtype), err

    flat, treedef = jax.tree.flatten(grads)
    rflat, _ = jax.tree.flatten(residuals)
    outs = [reduce_leaf(g, r) for g, r in zip(flat, rflat)]
    new_grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_res = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_grads, new_res
