"""jax-version compat for shard_map.

Newer jax exposes ``jax.shard_map(fn, in_specs=..., out_specs=...,
check_vma=...)`` and resolves the mesh from the ambient context; older
releases (<= 0.4.x) ship it as ``jax.experimental.shard_map.shard_map``
with a required positional mesh and the replication check spelled
``check_rep``. Call sites import :func:`shard_map` from here and keep the
new-style keyword signature.
"""
from __future__ import annotations

import jax


def _ambient_mesh():
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    if mesh.empty:
        raise RuntimeError(
            "shard_map outside a mesh context: wrap the call in "
            "`with set_mesh(mesh):` (repro.launch.mesh)")
    return mesh


def axis_size(axis_name):
    """Size of a mapped mesh axis inside shard_map.

    ``jax.lax.axis_size`` is a newer spelling; ``psum(1, axis)`` is the
    classic one and constant-folds to a static int.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(fn, *, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, in_specs=in_specs, out_specs=out_specs,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(fn, _ambient_mesh(), in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
