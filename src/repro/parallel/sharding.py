"""Sharding rules: map every parameter/cache/batch leaf to a PartitionSpec.

Two execution modes (see DESIGN.md — they mirror the paper's Cerebras
whole-graph-resident vs weight-streaming modes):

* ``resident``  — weights sharded over `model` only (TP); replicated over the
  data axes. No per-layer gathers; highest memory.
* ``streaming`` — FSDP x TP: weights additionally shard their contraction dim
  over `data` (ZeRO-3). XLA all-gathers each layer's weights inside the layer
  scan = the TPU-idiomatic analogue of weight streaming.

Heads that don't divide the model axis (rwkv6 d->H*hs reshape, hymba SSD
heads=25) keep their projections replicated over `model`; the Tier-1
allocation-ratio metric surfaces exactly this idle-axis effect.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, RunConfig


def batch_axes(mesh_cfg: MeshConfig) -> Tuple[str, ...]:
    return mesh_cfg.data_axes  # ('pod','data') or ('data',)


def have_ambient_mesh() -> bool:
    try:
        m = jax.sharding.get_abstract_mesh()
        return m is not None and not m.empty
    except Exception:
        return False


def maybe_constrain(x, spec):
    """with_sharding_constraint that degrades to a no-op outside any mesh
    context (single-device smoke tests)."""
    if spec is None or not have_ambient_mesh():
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def batch_spec(mesh_cfg: MeshConfig, global_batch: int,
               exclude: Tuple[str, ...] = ()) -> Optional[Tuple]:
    """Axes to shard the batch dim over, honoring divisibility. `exclude`
    removes axes repurposed elsewhere (e.g. 'pod' under EP-over-pod)."""
    axes = []
    size = 1
    for a in batch_axes(mesh_cfg):
        if a in exclude:
            continue
        s = dict(zip(mesh_cfg.axes, mesh_cfg.shape))[a]
        if global_batch % (size * s) == 0:
            axes.append(a)
            size *= s
    return tuple(axes) if axes else None


def act_pspec(mesh_cfg: MeshConfig, global_batch: int,
              exclude: Tuple[str, ...] = ()) -> P:
    """(B, S, d) activation spec."""
    return P(batch_spec(mesh_cfg, global_batch, exclude), None, None)


def _divisible(n: int, mesh_cfg: MeshConfig, axis: str) -> bool:
    return n % dict(zip(mesh_cfg.axes, mesh_cfg.shape))[axis] == 0


def param_pspecs(params_shape, cfg: ModelConfig, rcfg: RunConfig):
    """PartitionSpec pytree matching the params pytree (built from shapes so
    it works on ShapeDtypeStructs)."""
    mesh_cfg = rcfg.mesh
    fsdp = "data" if rcfg.exec_mode == "streaming" else None

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        in_moe = "moe" in names and "dense" not in names
        in_ssm = "time_mix" in names or "channel_mix" in names or "ssm" in names
        stacked = names[0] in ("layers", "enc_layers")
        L = (None,) if stacked else ()
        nd = len(leaf.shape)

        def spec(*rest):
            if len(L) + len(rest) != nd:
                raise ValueError(
                    f"partition spec rank mismatch for {names}: leaf shape "
                    f"{leaf.shape} vs spec {L + rest}")
            return P(*L, *rest)

        # ---- embedding ----
        if names[0] == "embed":
            if name == "tok":
                vshard = "model" if _divisible(leaf.shape[0], mesh_cfg,
                                               "model") else None
                return P(vshard, fsdp)
            if name == "head":
                vshard = "model" if _divisible(leaf.shape[1], mesh_cfg,
                                               "model") else None
                return P(fsdp, vshard)
        # ---- norms / scalars / vectors ----
        if nd - len(L) <= 1 or name in ("mix", "u", "ln_scale", "ln_bias",
                                        "w0", "dt_bias", "A_log", "D"):
            if name in ("bq", "bk", "bv") and not in_ssm:
                return spec("model")
            return P(*((None,) * nd))
        # ---- MoE ----
        if in_moe:
            ep = ("pod", "model") if getattr(rcfg, "ep_over_pod", False) \
                else "model"
            if name == "router":
                return spec(fsdp, None)
            if name in ("w_in", "w_gate"):
                return spec(ep, fsdp, None)          # (E, d, f): EP sharding
            if name == "w_out":
                return spec(ep, None, fsdp)
        # ---- rwkv6 time/channel mix + hymba ssd: heads don't divide the
        #      model axis -> replicate over model, FSDP over data ----
        if in_ssm:
            if getattr(rcfg, "ssm_tp", False) and "time_mix" in names:
                if name in ("wr", "wk", "wv", "wg") and _divisible(
                        leaf.shape[-1], mesh_cfg, "model"):
                    return spec(fsdp, "model")   # TP; XLA reshards for wkv
                if name == "wo" and _divisible(leaf.shape[-2], mesh_cfg,
                                               "model"):
                    return spec("model", fsdp)
            if "channel_mix" in names:
                if name == "wk" and _divisible(leaf.shape[-1], mesh_cfg,
                                               "model"):
                    return spec(fsdp, "model")     # (d, f) TP on f
                if name == "wv":
                    if _divisible(leaf.shape[-2], mesh_cfg, "model"):
                        return spec("model", None)  # (f, d) contraction TP
                    return spec(None, fsdp)
                return spec(fsdp, None)
            if name == "wo":
                return spec(None, fsdp)
            if name == "wb":
                return spec(None, None)
            return spec(fsdp, None)  # wr/wk/wv/wg/wx/wz/wB/wC/wdt/wa
        # ---- attention ----
        if name in ("wq", "wk", "wv"):
            return spec(fsdp, "model")
        if name == "wo":
            return spec("model", fsdp)
        # ---- dense mlp ----
        if name in ("w_in", "w_gate"):
            ok = _divisible(leaf.shape[-1], mesh_cfg, "model")
            return spec(fsdp, "model" if ok else None)
        if name == "w_out":
            ok = _divisible(leaf.shape[-2], mesh_cfg, "model")
            return spec("model" if ok else None, fsdp)
        return P(*((None,) * nd))

    def guarded(path, leaf):
        # universal divisibility guard: drop axes a dim can't divide
        # (e.g. d_model=1600 over data=128 on extreme mesh splits)
        return _fit_spec(rule(path, leaf), leaf.shape, mesh_cfg)

    return jax.tree_util.tree_map_with_path(guarded, params_shape)


def _fit_spec(spec: P, shape, mesh_cfg: MeshConfig) -> P:
    """Drop sharding on dims the shape can't divide evenly."""
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        out.append(entry if shape[i] % total == 0 else None)
    return P(*out)


def opt_state_shardings(opt_shape, params_pspecs, mesh, mesh_cfg: MeshConfig):
    """Shardings for an AdamWState whose m/v may hold Q8(q, scale) nodes.
    q inherits the param's spec; scale (rank-preserving, last dim /8) gets
    the same spec with divisibility fallback."""
    from jax.sharding import NamedSharding
    from jax.tree_util import keystr, tree_flatten_with_path, \
        tree_map_with_path

    from repro.optim.adamw import AdamWState

    flat, _ = tree_flatten_with_path(
        params_pspecs, is_leaf=lambda x: isinstance(x, P))
    by_path = {keystr(p): s for p, s in flat}

    def rule(path, leaf):
        ks = keystr(path)
        for suffix in (".q", ".scale"):
            if ks.endswith(suffix):
                ks = ks[: -len(suffix)]
        spec = by_path.get(ks, P(*((None,) * leaf.ndim)))
        if len(spec) != leaf.ndim:
            spec = P(*(tuple(spec) + (None,) * leaf.ndim)[: leaf.ndim])
        return NamedSharding(mesh, _fit_spec(spec, leaf.shape, mesh_cfg))

    m = tree_map_with_path(rule, opt_shape.m)
    v = tree_map_with_path(rule, opt_shape.v)
    master = tree_map_with_path(rule, opt_shape.master)
    return AdamWState(step=NamedSharding(mesh, P()), master=master,
                      m=m, v=v)


def cache_pspecs(caches_shape, cfg: ModelConfig, rcfg: RunConfig,
                 global_batch: int):
    """Decode-cache specs: batch over data axes; full-attention KV caches and
    cross caches shard their sequence dim over `model` (paired with the
    lse-combining partitioned decode attention)."""
    mesh_cfg = rcfg.mesh
    bspec = batch_spec(mesh_cfg, global_batch)

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        if name in ("k", "v", "ck", "cv"):
            seq = leaf.shape[2]
            seq_shard = ("model" if cfg.attention_kind != "sliding"
                         and rcfg.decode_attention == "partitioned"
                         and _divisible(seq, mesh_cfg, "model") else None)
            return P(None, bspec, seq_shard, None, None)
        # states: (L, B, H, *, *)
        return P(None, bspec, *((None,) * (len(leaf.shape) - 2)))

    return jax.tree_util.tree_map_with_path(rule, caches_shape)
