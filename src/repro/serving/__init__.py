"""repro.serving — request-level serving: schedulers, slots, metrics.

The Tier-2 deployment subsystem: :class:`Request` streams in,
:class:`StaticEngine` (lockstep batches) or :class:`ContinuousEngine`
(slot-based continuous batching) schedules them onto the jitted
prefill/decode steps, and :class:`ServeReport` carries the measured
TTFT / per-token latency / goodput / slot-occupancy out to the
benchmarks.
"""
from repro.serving.engine import (SCHEDULERS, ContinuousEngine,
                                  StaticEngine, decode_lockstep,
                                  make_engine)
from repro.serving.request import (Request, RequestMetrics, ServeReport,
                                   SimClock, WallClock)

__all__ = [
    "SCHEDULERS",
    "ContinuousEngine",
    "StaticEngine",
    "decode_lockstep",
    "make_engine",
    "Request",
    "RequestMetrics",
    "ServeReport",
    "SimClock",
    "WallClock",
]
