"""repro.serving — request-level serving: schedulers, slots, metrics.

The Tier-2 deployment subsystem: :class:`Request` streams in,
:class:`StaticEngine` (lockstep batches), :class:`ContinuousEngine`
(slot-based continuous batching), or :class:`PagedEngine` (continuous
batching over a block-table paged KV pool, see
:mod:`repro.serving.pages`) schedules them onto the jitted
prefill/decode steps, and :class:`ServeReport` carries the measured
TTFT / per-token latency / goodput / slot-occupancy / page-pool metrics
out to the benchmarks.
"""
from repro.serving.disagg import DisaggregatedEngine
from repro.serving.engine import (SCHEDULERS, ContinuousEngine,
                                  RequestQueue, StaticEngine,
                                  decode_lockstep, make_engine)
from repro.serving.faults import (FAULT_KINDS, Fault, FaultInjector,
                                  FaultPlan, InjectedFault,
                                  resolve_fault_plan)
from repro.serving.paged import PagedEngine
from repro.serving.pages import (PageAllocator, PoolInvariantError,
                                 pages_needed)
from repro.serving.prefix import RadixCache
from repro.serving.request import (OUTCOMES, Request, RequestMetrics,
                                   ServeReport, SimClock, WallClock)
from repro.serving.roles import (DecodeWorker, PageHandoff, PrefillWorker,
                                 Scheduler, prefill_owner)

__all__ = [
    "FAULT_KINDS",
    "Fault",
    "FaultInjector",
    "FaultPlan",
    "InjectedFault",
    "OUTCOMES",
    "SCHEDULERS",
    "ContinuousEngine",
    "DecodeWorker",
    "DisaggregatedEngine",
    "PagedEngine",
    "PageAllocator",
    "PageHandoff",
    "PoolInvariantError",
    "PrefillWorker",
    "RadixCache",
    "RequestQueue",
    "Scheduler",
    "StaticEngine",
    "decode_lockstep",
    "make_engine",
    "pages_needed",
    "prefill_owner",
    "resolve_fault_plan",
    "Request",
    "RequestMetrics",
    "ServeReport",
    "SimClock",
    "WallClock",
]
