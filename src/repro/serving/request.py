"""Request-level serving primitives: requests, per-request metrics, clocks.

The paper's Tier-2 axis is deployment behavior; the unit of deployment is
a *request* (a prompt + a decode budget + an arrival time), not a batch.
Everything the scheduler reasons about and everything the benchmarks
record hangs off the two dataclasses here:

* :class:`Request`        — what arrives at the server;
* :class:`RequestMetrics` — what the server measured for it (TTFT,
  per-token latency, end-to-end latency), the LLM-Inference-Bench
  (arXiv 2411.00136) core metric set.

Clocks decouple *when things happen* from *how long compute takes*:
:class:`WallClock` measures real time (benchmark runs); :class:`SimClock`
charges a fixed cost per prefill/decode step (deterministic tests,
scheduler-policy comparisons independent of host noise).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    """One inference request: token prompt + decode budget + arrival.

    The SLO fields drive the fault-tolerant scheduling layer:
    ``deadline_s`` is a per-request latency budget measured *from
    arrival* (the request must finish by ``arrival_s + deadline_s`` on
    the engine clock; ``None`` = no deadline); ``priority`` orders
    admission (higher wins) and decides who gets preempted under page
    pressure; ``max_retries`` bounds how many times a preempted or
    fault-hit request is requeued before it is failed outright.
    """

    rid: int
    prompt: np.ndarray              # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0          # offered-load arrival time
    deadline_s: Optional[float] = None  # finish-by budget from arrival
    priority: int = 0               # higher = more important
    max_retries: int = 2            # requeues before outcome "failed"

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[-1])

    @property
    def deadline_abs_s(self) -> Optional[float]:
        """Absolute finish-by time on the engine clock (run-relative)."""
        if self.deadline_s is None:
            return None
        return self.arrival_s + self.deadline_s


# terminal states a request can reach — what ``RequestMetrics.outcome``
# holds and what the ServeReport taxonomy counts
OUTCOMES = ("completed", "timed_out", "preempted", "rejected", "failed")


@dataclass
class RequestMetrics:
    """Measured lifecycle of one request (all times on the engine clock)."""

    rid: int
    prompt_len: int
    arrival_s: float
    admitted_s: float = 0.0         # when a slot/batch picked it up
    first_token_s: float = 0.0      # when its first token was ready
    finish_s: float = 0.0           # when its last token was ready
    new_tokens: int = 0             # tokens actually generated (<= budget)
    slot: int = -1                  # KV slot that served it
    finished: bool = False
    # terminal outcome ("" while in flight): completed | timed_out |
    # preempted (evicted, never resumed to completion) | rejected
    # (inadmissible, never scheduled) | failed (retries exhausted or an
    # injected/unrecoverable per-request fault)
    outcome: str = ""
    preemptions: int = 0            # times evicted from a decode lane
    retries: int = 0                # times requeued (preemption or fault)
    # prompt tokens served from the prefix cache (0 = cold prefill; >0
    # means only the suffix was chunk-prefilled — the warm-TTFT lever)
    cached_prompt_tokens: int = 0
    # duration of each decode step that produced one of this request's
    # tokens (token 0 comes from prefill and is covered by TTFT)
    token_latencies_s: List[float] = field(default_factory=list)
    tokens: Optional[np.ndarray] = None   # (new_tokens,) generated ids
    # ---- role attribution (disaggregated engines; -1 = interleaved) ----
    prefill_worker: int = -1        # which prefill worker ran the prompt
    decode_worker: int = -1         # which decode pool generated tokens
    handoff_latency_s: float = 0.0  # prefill-done -> decode-lane pickup

    @property
    def queue_s(self) -> float:
        return self.admitted_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Time to first token, from arrival (queueing + prefill)."""
        return self.first_token_s - self.arrival_s

    @property
    def e2e_s(self) -> float:
        return self.finish_s - self.arrival_s


# ------------------------------------------------------------------ clocks
class WallClock:
    """Real time: durations come from perf_counter, charge() is a no-op."""

    def now(self) -> float:
        return time.perf_counter()

    def charge(self, kind: str, n: int = 1) -> None:
        pass                         # wall time advances by itself

    def wait_until(self, t: float) -> None:
        d = t - self.now()
        if d > 0:
            time.sleep(d)


class SimClock:
    """Deterministic virtual time: each prefill/decode charges a fixed
    cost, waits jump. Scheduler comparisons under SimClock depend only on
    the schedule (admissions, step counts), never on host jitter."""

    def __init__(self, prefill_cost_s: float = 10.0,
                 decode_cost_s: float = 1.0,
                 handoff_cost_s: float = 0.0) -> None:
        self._t = 0.0
        self._cost = {"prefill": prefill_cost_s, "decode": decode_cost_s,
                      "handoff": handoff_cost_s}

    def now(self) -> float:
        return self._t

    def charge(self, kind: str, n: int = 1) -> None:
        self._t += self._cost[kind] * n

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, t)


# ------------------------------------------------------------------ report
@dataclass
class ServeReport:
    """Aggregate result of one engine run over a request set."""

    metrics: List[RequestMetrics]
    scheduler: str                  # "static" | "continuous" | "paged"
    slots: int
    makespan_s: float               # first admission -> last token
    decode_steps: int
    prefills: int
    slot_tokens: np.ndarray         # (slots,) tokens generated per slot
    # max requests simultaneously holding KV memory (the headline the
    # paged engine moves: more admits at equal memory budget)
    peak_concurrency: int = 0
    # ---- paged-KV pool metrics (zero unless scheduler == "paged") ----
    page_size: int = 0
    num_pages: int = 0              # total pool incl. the null page
    page_occupancy_mean: float = 0.0   # allocated/usable, per decode step
    page_occupancy_peak: float = 0.0
    fragmentation_mean: float = 0.0    # 1 - live tokens / allocated slots
    fragmentation_peak: float = 0.0
    pages_high_water: int = 0          # peak pages simultaneously in use
    failed_allocs: int = 0             # pool-side allocation refusals
    admission_blocked_steps: int = 0   # steps the queue head waited on pages
    # ---- prefix-sharing radix cache (unset unless enabled) -----------
    prefix_enabled: bool = False
    prefix_lookups: int = 0            # admissions that consulted the cache
    prefix_hits: int = 0               # admissions with >0 cached tokens
    prefill_tokens_saved: int = 0      # prompt tokens not re-prefilled
    pages_shared_peak: int = 0         # peak logical-minus-physical pages
    prefix_evictions: int = 0          # LRU evictions under pool pressure
    # ---- robustness: SLO enforcement + preemption + fault injection ---
    preemption_events: int = 0         # evictions of an active request
    requeues: int = 0                  # preempted/faulted requests requeued
    pages_leaked: int = 0              # owner-held pages left at drain
    faults_injected: int = 0           # FaultPlan events actually applied
    fault_recoveries: int = 0          # faults the engine recovered from
    # decode steps from each fault's injection to its recovery (the
    # chaos_soak scenario's recovery-latency metric)
    fault_recovery_steps: List[int] = field(default_factory=list)
    # ---- P/D role split (zero/empty unless scheduler=="disaggregated",
    # except decode_stalls_s, which the interleaved paged engine also
    # fills: gaps between consecutive decode steps while lanes stayed
    # active — the prefill-interference metric disaggregation removes)
    prefill_workers: int = 0
    decode_workers: int = 0
    prefill_busy_s: float = 0.0        # summed over prefill workers
    decode_busy_s: float = 0.0         # summed over decode workers
    prefill_util: float = 0.0          # busy / (workers * makespan)
    decode_util: float = 0.0
    handoffs: int = 0                  # prefill->decode page transfers
    handoff_latencies_s: List[float] = field(default_factory=list)
    queue_depth_peak: int = 0          # pending requests, per-step samples
    queue_depth_mean: float = 0.0
    decode_stalls_s: List[float] = field(default_factory=list)

    @property
    def completed(self) -> int:
        return sum(1 for m in self.metrics if m.finished)

    def outcome_counts(self) -> dict:
        """Requests per terminal outcome (see ``OUTCOMES``)."""
        counts = {k: 0 for k in OUTCOMES}
        for m in self.metrics:
            key = m.outcome or ("completed" if m.finished else "")
            if key in counts:
                counts[key] += 1
        return counts

    @property
    def total_retries(self) -> int:
        return sum(m.retries for m in self.metrics)

    @property
    def total_new_tokens(self) -> int:
        return sum(m.new_tokens for m in self.metrics)

    @property
    def goodput_rps(self) -> float:
        """Completed requests per second of makespan."""
        return self.completed / max(self.makespan_s, 1e-9)

    @property
    def goodput_tps(self) -> float:
        """Generated tokens per second of makespan."""
        return self.total_new_tokens / max(self.makespan_s, 1e-9)

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per decode step —
        the serving analogue of the paper's Eq. 1 allocation ratio."""
        if self.decode_steps == 0:
            return 1.0 if self.total_new_tokens else 0.0
        useful = sum(len(m.token_latencies_s) for m in self.metrics)
        return useful / (self.slots * self.decode_steps)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of cache-consulting admissions that reused >= 1 page."""
        return self.prefix_hits / max(self.prefix_lookups, 1)

    def ttft_samples_s(self) -> List[float]:
        return [m.ttft_s for m in self.metrics if m.finished]

    def ttft_warm_samples_s(self) -> List[float]:
        """TTFT of requests that reused cached prefix pages."""
        return [m.ttft_s for m in self.metrics
                if m.finished and m.cached_prompt_tokens > 0]

    def ttft_cold_samples_s(self) -> List[float]:
        """TTFT of requests prefilled entirely from scratch."""
        return [m.ttft_s for m in self.metrics
                if m.finished and m.cached_prompt_tokens == 0]

    def token_latency_samples_s(self) -> List[float]:
        out: List[float] = []
        for m in self.metrics:
            out.extend(m.token_latencies_s)
        return out

    def summary(self) -> dict:
        """Flat dict of headline numbers (launcher stdout, BenchRecords)."""
        from repro.core.metrics import percentile as pct
        from repro.core.metrics import slot_load_balance

        tl = sorted(self.token_latency_samples_s())
        tt = sorted(self.ttft_samples_s())
        out = {
            "scheduler": self.scheduler,
            "completed": self.completed,
            "total_new_tokens": self.total_new_tokens,
            "makespan_s": self.makespan_s,
            "goodput_rps": self.goodput_rps,
            "goodput_tps": self.goodput_tps,
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "occupancy": self.occupancy,
            "peak_concurrency": self.peak_concurrency,
            "slot_balance": slot_load_balance(self.slot_tokens),
            "ttft_p50_s": pct(tt, 50.0),
            "ttft_p95_s": pct(tt, 95.0),
            "tok_p50_s": pct(tl, 50.0),
            "tok_p95_s": pct(tl, 95.0),
        }
        oc = self.outcome_counts()
        out.update({
            "n_timed_out": oc["timed_out"],
            "n_preempted": oc["preempted"],
            "n_rejected": oc["rejected"],
            "n_failed": oc["failed"],
            "preemption_events": self.preemption_events,
            "requeues": self.requeues,
            "retries": self.total_retries,
        })
        if self.faults_injected:
            rs = self.fault_recovery_steps
            out.update({
                "faults_injected": self.faults_injected,
                "fault_recoveries": self.fault_recoveries,
                "recovery_steps_mean": (sum(rs) / len(rs)) if rs else 0.0,
                "recovery_steps_max": max(rs, default=0),
                "pages_leaked": self.pages_leaked,
            })
        if self.num_pages:
            out.update({
                "page_size": self.page_size,
                "num_pages": self.num_pages,
                "page_occupancy_mean": self.page_occupancy_mean,
                "page_occupancy_peak": self.page_occupancy_peak,
                "fragmentation_mean": self.fragmentation_mean,
                "fragmentation_peak": self.fragmentation_peak,
                "pages_high_water": self.pages_high_water,
                "failed_allocs": self.failed_allocs,
                "admission_blocked_steps": self.admission_blocked_steps,
            })
        if self.prefix_enabled:
            warm = sorted(self.ttft_warm_samples_s())
            cold = sorted(self.ttft_cold_samples_s())
            out.update({
                "prefix_hit_rate": self.prefix_hit_rate,
                "prefix_hits": self.prefix_hits,
                "prefix_lookups": self.prefix_lookups,
                "prefill_tokens_saved": self.prefill_tokens_saved,
                "pages_shared_peak": self.pages_shared_peak,
                "prefix_evictions": self.prefix_evictions,
                "ttft_warm_p50_s": pct(warm, 50.0),
                "ttft_cold_p50_s": pct(cold, 50.0),
            })
        if self.decode_stalls_s:
            ds = sorted(self.decode_stalls_s)
            out.update({
                "decode_stall_p50_s": pct(ds, 50.0),
                "decode_stall_p95_s": pct(ds, 95.0),
            })
        if self.prefill_workers:
            hl = sorted(self.handoff_latencies_s)
            out.update({
                "prefill_workers": self.prefill_workers,
                "decode_workers": self.decode_workers,
                "prefill_util": self.prefill_util,
                "decode_util": self.decode_util,
                "handoffs": self.handoffs,
                "handoff_p50_s": pct(hl, 50.0),
                "handoff_p95_s": pct(hl, 95.0),
                "queue_depth_peak": self.queue_depth_peak,
                "queue_depth_mean": self.queue_depth_mean,
            })
        return out
