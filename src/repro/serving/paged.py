"""Paged-KV continuous batching: block-table scheduling over a page pool.

:class:`PagedEngine` keeps the continuous scheduler's slot semantics (a
fixed number of *decode lanes*) but replaces the per-slot monolithic
``cache_span`` KV reservation with a global pool of fixed-size pages
(:mod:`repro.serving.pages`):

* **admission** is gated on *enough free pages* for
  ``prompt_len + max_new_tokens`` tokens — not on a whole span — so at
  equal KV memory budget the paged engine admits strictly more
  concurrent requests whenever real requests are shorter than the span;
* **prefill is chunked**: the prompt streams through
  ``prefill_chunk_tokens``-sized chunks, each writing its K/V straight
  into the request's pages, so a long prompt never needs one contiguous
  span-sized buffer;
* **decode** runs the same fused pool step as the continuous engine,
  but through the block-table paged decode path
  (``model.decode_step_paged`` -> the Pallas paged-attention kernel on
  TPU, the gather reference elsewhere); retirement returns pages to the
  allocator's free list mid-stream.

With ``prefix_cache=True`` a :class:`~repro.serving.prefix.RadixCache`
sits between the queue and the allocator: admission looks up the longest
cached page-aligned prefix of the prompt, attaches the matched pages
read-only into the block table (one physical page, N logical owners via
the allocator's refcounts), and chunk-prefills only the uncached suffix.
When the *entire* prompt is cached, the last matched page is
copy-on-written — duplicated into a fresh page — so re-prefilling the
single token needed for first-token logits never writes a shared page.
Sequences are indexed on prefill completion (the prompt) and again on
retirement (the generated tokens — what makes a returning multi-turn
session warm); LRU refcount-1 entries are evicted when the pool runs
low. Disabled (the default), the engine byte-for-byte matches the
pre-cache scheduler.

Greedy outputs are token-identical to the monolithic engines — paging
and prefix reuse are memory-layout changes, not numerics changes — which
is what ``tools/ci_checks.py paged-parity`` and ``prefix-parity``
enforce.

Unlike the monolithic engines' ``(prefill_fn, decode_fn, cache_init)``
triple, this engine takes the *paged* triple from
:class:`repro.models.model.Model`:

* ``prefill_fn(params, caches, tokens, block_tables, start_pos)``
  (= ``model.prefill_chunk``; ``start_pos`` may land mid-page, the
  warm-suffix path),
* ``decode_fn(params, caches, token, pos, block_tables)``
  (= ``model.decode_step_paged``),
* ``cache_init(num_pages, page_size)`` (= ``model.paged_cache_init``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.engine import SCHEDULERS, _EngineBase, _sample_tokens
from repro.serving.faults import FaultInjector, FaultPlan, InjectedFault
from repro.serving.pages import (PageAllocator, PoolInvariantError, PoolStats,
                                 pages_needed)
from repro.serving.prefix import RadixCache
from repro.serving.request import Request, ServeReport
from repro.serving.roles import (DecodeWorker, PageHandoff, PrefillWorker,
                                 Scheduler)


class PagedEngine(_EngineBase):
    """Continuous batching over ``slots`` decode lanes and a paged KV
    pool of ``num_pages`` pages of ``page_size`` tokens (page 0 is the
    reserved null page). ``num_pages=None`` sizes the pool to the
    monolithic engine's budget (``slots x cache_span`` tokens) plus the
    null page, so the default is budget-equivalent by construction;
    benchmarks pass an explicit pool to compare at exactly equal bytes.
    ``prefill_chunk_tokens=0`` prefills each prompt in one chunk.
    ``prefix_cache=True`` enables the prefix-sharing radix cache."""

    scheduler = "paged"

    def __init__(self, prefill_fn, decode_fn, params, cache_init, *,
                 slots: int, cache_span: int, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefill_chunk_tokens: int = 0,
                 prefix_cache: bool = False,
                 fault_plan: Optional[FaultPlan] = None,
                 requeue_backoff_s: float = 0.0, **kw):
        self.page_size = int(page_size)
        # deterministic chaos: a FaultPlan makes run() consult a
        # FaultInjector at every engine step (see repro.serving.faults)
        self.fault_plan = fault_plan
        # delay before a preempted/faulted request re-enters the queue
        # (0.0 keeps SimClock schedules backoff-free and deterministic)
        self.requeue_backoff_s = float(requeue_backoff_s)
        # block-table width: logical pages a maximal request can touch
        self.npag_max = -(-cache_span // self.page_size)
        if num_pages is None:
            # default: every lane can hold a maximal request at once —
            # the monolithic slots*span budget, rounded up to whole pages
            num_pages = slots * self.npag_max + 1
        self.num_pages = int(num_pages)
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.prefix_cache = bool(prefix_cache)
        super().__init__(prefill_fn, decode_fn, params, cache_init,
                         slots=slots, cache_span=cache_span, **kw)

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1            # minus the null page

    # --------------------------------------------------------- validation
    def admission_error(self, r: Request) -> Optional[str]:
        err = super().admission_error(r)     # budget >= 1, block-table fit
        if err:
            return err
        need = pages_needed(r.prompt_len + r.max_new_tokens, self.page_size)
        if need > self.usable_pages:
            return (f"needs {need} KV pages ({r.prompt_len}+"
                    f"{r.max_new_tokens} tokens at page_size "
                    f"{self.page_size}) but the pool has only "
                    f"{self.usable_pages} usable pages")
        return None

    # --------------------------------------------------------------- jits
    def _setup_jits(self, prefill_fn, decode_fn) -> None:
        donate = self._donate_ok
        # one compile per chunk length; start_pos stays traced
        self._jit_chunk = jax.jit(
            prefill_fn, donate_argnums=(1,) if donate else ())
        # copy-on-write: duplicate page src into page dst across every
        # pool leaf (axis 0 = layers, axis 1 = pages); src/dst stay
        # traced so one compile covers every divergence point
        self._jit_copy = jax.jit(
            lambda caches, src, dst: jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), caches),
            donate_argnums=(0,) if donate else ())
        greedy, eos_id = self.greedy, self.eos_id

        def pool_step(params, caches, state, key):
            logits, caches = decode_fn(params, caches, state["tok"],
                                       state["pos"], state["btab"])
            tok = _sample_tokens(logits[:, -1], key, greedy)      # (B,)
            active = state["active"]
            ncount = state["ncount"]
            B, T = state["tokbuf"].shape
            bidx = jnp.arange(B)
            idx = jnp.minimum(ncount, T - 1)
            cur = state["tokbuf"][bidx, idx]
            tokbuf = state["tokbuf"].at[bidx, idx].set(
                jnp.where(active, tok, cur))
            ncount = ncount + active.astype(jnp.int32)
            stop = ncount >= state["budget"]
            if eos_id is not None:
                stop = stop | (tok == eos_id)
            still = active & ~stop
            return caches, {
                "tok": jnp.where(active, tok, state["tok"][:, 0])[:, None],
                "pos": state["pos"] + active.astype(jnp.int32),
                "active": still,
                "ncount": ncount,
                "budget": state["budget"],
                "tokbuf": tokbuf,
                # retired rows point at the null page so a stale table
                # can never write into a page the allocator reissued
                "btab": jnp.where(still[:, None], state["btab"], 0),
            }

        def admit(state, tok0, btab_row, slot, plen, budget, active0):
            # no cache insertion: chunked prefill already wrote this
            # request's K/V into its own pages of the shared pool
            t0 = tok0[0, 0]
            return {
                "tok": state["tok"].at[slot, 0].set(t0),
                "pos": state["pos"].at[slot].set(plen),
                "active": state["active"].at[slot].set(active0),
                "ncount": state["ncount"].at[slot].set(1),
                "budget": state["budget"].at[slot].set(budget),
                "tokbuf": state["tokbuf"].at[slot, 0].set(t0),
                "btab": state["btab"].at[slot].set(btab_row),
            }

        def evict(state, slot):
            # retire one lane mid-flight (deadline reap / preemption):
            # deactivate it and point its block-table row at the null
            # page so a stale table can never touch a reissued page
            return {
                **state,
                "active": state["active"].at[slot].set(False),
                "btab": state["btab"].at[slot].set(
                    jnp.zeros_like(state["btab"][0])),
            }

        self._pool_step = jax.jit(
            pool_step, donate_argnums=(1, 2) if donate else ())
        self._admit = jax.jit(
            admit, donate_argnums=(0,) if donate else ())
        self._jit_evict = jax.jit(
            evict, donate_argnums=(0,) if donate else ())

    def warmup(self, prompt_len: int) -> None:
        # jit-compile warmup must not consume the fault schedule (every
        # run() builds a fresh injector, but warming up under chaos
        # would fail/requeue dummy requests for nothing)
        plan, self.fault_plan = self.fault_plan, None
        try:
            super().warmup(prompt_len)
        finally:
            self.fault_plan = plan

    # ----------------------------------------------------------- teardown
    def _release_pages(self, alloc: PageAllocator, rid: int) -> None:
        """Return a request's pages to the pool. Every terminal path
        (completion, deadline reap, preemption, fault failure) releases
        through this one seam — ``ci_checks.py chaos-parity`` self-tests
        its leak detection by no-op'ing this method and requiring the
        check to fail."""
        alloc.free(rid)

    # ---------------------------------------------------------- prefill
    def _chunked_prefill(self, prompt: np.ndarray, btab_dev, clock, *,
                         start: int = 0):
        """Stream prompt positions ``[start, len)`` through the pool in
        page-filling chunks; returns the last chunk's logits and the
        number of chunks run. ``start > 0`` is the warm path: positions
        below it are already resident in attached prefix pages, so only
        the suffix pays prefill compute.

        Each chunk sees only the first ``pages_needed(written)`` pages of
        the block table, so attention cost grows with the live prefix
        rather than paying the full cache_span gather on every chunk
        (one jit compile per distinct (chunk length, live pages) pair)."""
        plen = int(prompt.shape[0])
        cs = self.prefill_chunk_tokens or (plen - start)
        logits = None
        chunks = 0
        for lo in range(start, plen, cs):
            end = min(lo + cs, plen)
            n_live = pages_needed(end, self.page_size)
            chunk = jnp.asarray(prompt[None, lo:end])
            logits, self._caches = self._jit_chunk(
                self.params, self._caches, chunk, btab_dev[:, :n_live],
                jnp.int32(lo))
            jax.block_until_ready(logits)
            clock.charge("prefill")     # each chunk is a prefill dispatch
            chunks += 1
        return logits, chunks

    # --------------------------------------------------------- admission
    def _reserve_pages(self, req: Request, alloc: PageAllocator,
                      radix: Optional[RadixCache], owner=None):
        """Try to reserve pages for ``req``, reusing the longest cached
        prefix when the radix cache is on. Returns
        ``(pages, suffix_start)`` or ``None`` when the pool (even after
        LRU eviction) cannot cover the fresh remainder — the caller
        blocks the queue head until a retirement frees pages. ``owner``
        is the allocator key the reservation is held under (default: the
        rid; the prefill role reserves under its own key and hands off —
        see :class:`repro.serving.roles.PageHandoff`).

        The suffix start is capped at ``prompt_len - 1``: at least one
        prompt token must be re-prefilled to produce the first-token
        logits. When the whole prompt is cached that cap lands mid-page,
        so the final matched page is attached *copy-on-write* — its K/V
        is duplicated into a fresh page before the one-token prefill
        writes into it — and every fully-matched page stays read-only."""
        owner = req.rid if owner is None else owner
        total_tokens = req.prompt_len + req.max_new_tokens
        if radix is None:
            if not alloc.can_fit(total_tokens):
                return None
            return alloc.allocate(owner, total_tokens), 0
        match_pages, match_tok = radix.lookup(np.asarray(req.prompt))
        s0 = min(match_tok, req.prompt_len - 1)
        k_full = s0 // self.page_size
        shared = match_pages[:k_full]
        cow_src = match_pages[k_full] if s0 < match_tok else None
        need_fresh = pages_needed(total_tokens, self.page_size) - len(shared)
        if need_fresh > alloc.num_free:
            radix.evict(need_fresh - alloc.num_free,
                        protect=frozenset(match_pages))
        if need_fresh > alloc.num_free:
            return None
        pages = alloc.allocate(owner, total_tokens, shared=shared)
        if cow_src is not None:
            self._caches = self._jit_copy(self._caches, jnp.int32(cow_src),
                                          jnp.int32(pages[k_full]))
        return pages, s0

    # -------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> ServeReport:
        # role composition (interleaved): one Scheduler, one PrefillWorker
        # and one DecodeWorker over all lanes, sharing this engine's clock
        # — same schedule as the old monolithic loop (parity-gated), with
        # the page handoff made explicit between the two roles
        sched = Scheduler(self)
        reqs, rejected = sched.validate(requests)
        B = self.slots
        clock = self.clock
        t0 = clock.now()
        key = jax.random.PRNGKey(self.seed)
        self._caches = self.cache_init(self.num_pages, self.page_size)
        alloc = PageAllocator(self.num_pages, self.page_size)
        radix = RadixCache(alloc) if self.prefix_cache else None
        inj = FaultInjector(self.fault_plan) if self.fault_plan else None
        stats = PoolStats()
        pw = PrefillWorker(self)
        dw = DecodeWorker(self, B, npag_max=self.npag_max)
        handoff = PageHandoff(alloc, self._release_pages, self.page_size)
        metrics = self._make_metrics(reqs, rejected)
        # plen_of tracks the *current* incarnation of each request (a
        # requeue replaces the entry with the extended-prompt version;
        # the Request itself lives in sched.req_of)
        plen_of = {r.rid: r.prompt_len for r in reqs}
        prompt_of: Dict[int, np.ndarray] = {}
        # tokens a preempted/faulted request generated before eviction —
        # its terminal metrics report the cumulative stream
        partial: Dict[int, np.ndarray] = {}
        admissions = 0
        decode_steps = prefills = peak_conc = blocked = 0
        lookups = hits = tokens_saved = 0
        preempt_events = requeues = 0
        qd_samples: List[int] = []
        step = -1                        # engine step (admission or decode)

        def audit() -> None:
            """Under a fault plan the pool is re-checked at every event;
            a poison fault is *supposed* to trip this — the injector
            heals it and the pool must check clean again. A failure the
            injector cannot heal is real corruption and escapes."""
            if inj is None:
                return
            try:
                alloc.check()
            except PoolInvariantError:
                if not inj.heal(alloc):
                    raise
                alloc.check()

        def index_sequence(rid: int, gen_tokens: np.ndarray) -> None:
            """Index the retiring request's full pages: its prompt plus
            every generated token whose K/V was written (the final
            sampled token never reaches the pool — no decode step
            consumed it)."""
            seq = np.concatenate([
                prompt_of[rid],
                np.asarray(gen_tokens[:-1], np.int32)])
            radix.insert(seq, alloc.owned(rid))

        def cumulative(rid: int, gen: np.ndarray) -> np.ndarray:
            prev = partial.get(rid)
            gen = np.asarray(gen, np.int32)
            return gen if prev is None else np.concatenate([prev, gen])

        def requeue_or_fail(rid: int, gen: np.ndarray, now_rel: float,
                            exhausted_outcome: str) -> None:
            """Put an evicted request back in the queue with its
            generated-so-far tokens appended to its prompt (greedy
            re-prefill of the extended prompt reproduces the
            continuation exactly — and warm-restarts through the radix
            cache when enabled). After ``max_retries`` requeues the
            request goes terminal instead."""
            nonlocal requeues
            r = sched.req_of[rid]
            m = metrics[rid]
            cum = cumulative(rid, gen)
            m.retries += 1
            if m.retries > r.max_retries:
                m.outcome = exhausted_outcome
                m.finish_s = now_rel
                m.new_tokens = len(cum)
                m.tokens = cum
                return
            if len(gen):
                partial[rid] = cum
            arrival = now_rel + self.requeue_backoff_s
            nr = Request(
                rid=rid,
                prompt=np.concatenate([np.asarray(r.prompt, np.int32),
                                       np.asarray(gen, np.int32)]),
                max_new_tokens=r.max_new_tokens - len(gen),
                arrival_s=arrival,
                # the *absolute* deadline survives the requeue (an SLO
                # clock does not restart because the scheduler evicted)
                deadline_s=(None if r.deadline_abs_s is None
                            else r.deadline_abs_s - arrival),
                priority=r.priority, max_retries=r.max_retries)
            plen_of[rid] = nr.prompt_len
            sched.requeue(nr)
            requeues += 1

        def evict_lane(s: int, ncounts: np.ndarray) -> np.ndarray:
            """Take lane ``s`` out of service mid-flight: index its pages
            into the radix cache (so a requeue re-prefills warm), free
            them, null the device row. Returns the generated tokens."""
            rid = dw.slot_rid[s]
            n = int(ncounts[s])
            gen = np.asarray(dw.state["tokbuf"][s, :n])
            if radix is not None:
                index_sequence(rid, gen)
            self._release_pages(alloc, rid)
            dw.slot_rid[s] = None
            dw.active_host[s] = False
            return gen

        def try_preempt(for_req: Request) -> bool:
            """Evict the Scheduler's victim choice (lowest priority;
            ties: latest admitted — least sunk prefill), requeued with
            its progress as prompt extension. False = nobody active is
            strictly lower priority than ``for_req``."""
            nonlocal preempt_events
            victim = sched.pick_victim(for_req, dw.slot_rid,
                                       dw.active_host, dw.admit_seq)
            if victim is None:
                return False
            ncounts = np.asarray(dw.state["ncount"])
            rid = dw.slot_rid[victim]
            gen = evict_lane(victim, ncounts)
            dw.evict(victim)
            metrics[rid].preemptions += 1
            preempt_events += 1
            requeue_or_fail(rid, gen, clock.now() - t0, "preempted")
            audit()
            return True

        while sched.queue or dw.active_host.any():
            step += 1
            qd_samples.append(sched.queue_depth())
            if inj is not None:
                inj.begin_step(step, alloc, clock)
                audit()
            # ---- Scheduler role: reap queued then active requests past SLO
            now_rel = clock.now() - t0
            for r in sched.reap_queued(now_rel):
                m = metrics[r.rid]
                m.outcome = "timed_out"
                cum = cumulative(r.rid, np.zeros(0, np.int32))
                if len(cum):          # progress from before eviction
                    m.new_tokens = len(cum)
                    m.tokens = cum
                    m.finish_s = now_rel
            doomed = sched.doomed_slots(now_rel, dw.slot_rid, dw.active_host)
            if doomed:
                ncounts = np.asarray(dw.state["ncount"])
                for s in doomed:
                    rid = dw.slot_rid[s]
                    m = metrics[rid]
                    gen = evict_lane(s, ncounts)
                    dw.evict(s)
                    cum = cumulative(rid, gen)
                    m.outcome = "timed_out"
                    m.new_tokens = len(cum)
                    m.tokens = cum
                    m.finish_s = now_rel
                audit()
            # ---- admission: lane + arrived request + enough pages; a
            # higher-priority arrival may preempt to make room for both
            while sched.queue:
                now_rel = clock.now() - t0
                req = sched.peek_best(now_rel)
                if req is None:
                    break
                if dw.active_host.all() and not try_preempt(req):
                    break
                if inj is not None and inj.refuse_alloc():
                    blocked += 1     # transient injected refusal: retry
                    break            # next engine step
                # PrefillWorker role: reserve under the prefill owner key
                got = pw.reserve(req, alloc, radix)
                if radix is not None:
                    lookups += 1
                while got is None and try_preempt(req):
                    got = pw.reserve(req, alloc, radix)
                if got is None:
                    blocked += 1     # queue head waits for retirements
                    break
                pages, s0 = got
                sched.take(req)
                prompt_np = np.asarray(req.prompt, np.int32)
                prompt_of[req.rid] = prompt_np
                slot = dw.free_lane()
                m = metrics[req.rid]
                base = len(partial.get(req.rid, ()))
                m.admitted_s = clock.now() - t0
                m.slot = slot
                m.cached_prompt_tokens = s0
                if s0 > 0:
                    hits += 1
                    tokens_saved += s0
                peak_conc = max(peak_conc, alloc.num_owners)
                btab_row = np.zeros(self.npag_max, np.int32)
                btab_row[:len(pages)] = pages
                btab_dev = jnp.asarray(btab_row)[None]
                try:
                    if inj is not None:
                        inj.check_prefill()
                    logits, chunks = pw.prefill(
                        prompt_np, btab_dev, clock, start=s0)
                except InjectedFault:
                    # contain the fault to this request: give back its
                    # pages (un-prefilled — check_prefill fires before
                    # any chunk writes) and retry or fail it alone
                    handoff.abort(req.rid)
                    audit()
                    requeue_or_fail(req.rid, np.zeros(0, np.int32),
                                    clock.now() - t0, "failed")
                    inj.note_prefill_resolved(step)
                    continue
                prefills += chunks
                if radix is not None:   # index the prompt's full pages
                    radix.insert(prompt_np, pages)
                key, sub = jax.random.split(key)
                tok0 = _sample_tokens(logits[:, -1:], sub, self.greedy)
                if base == 0:
                    m.first_token_s = clock.now() - t0
                m.new_tokens = base + 1
                done0 = req.max_new_tokens == 1
                if self.eos_id is not None:
                    done0 = done0 or int(tok0[0, 0]) == self.eos_id
                # PageHandoff role: decode takes ownership of the pages.
                # Interleaved, the lane picks the request up in the same
                # engine step, so handoff latency is zero by construction
                # (the disaggregated engine measures the real queue-wait)
                handoff.transfer(req.rid)
                handoff.latencies_s.append(0.0)
                dw.admit(tok0, btab_dev[0], slot, req.prompt_len,
                         req.max_new_tokens, not done0)
                dw.slot_tokens[slot] += 1
                admissions += 1
                dw.admit_seq[slot] = admissions
                if inj is not None:
                    inj.note_admission(step)
                if done0:
                    m.finished = True
                    m.outcome = "completed"
                    m.finish_s = clock.now() - t0
                    m.tokens = cumulative(
                        req.rid, np.asarray([int(tok0[0, 0])], np.int32))
                    self._release_pages(alloc, req.rid)
                    audit()
                else:
                    dw.active_host[slot] = True
                    dw.slot_rid[slot] = req.rid
            if not dw.active_host.any():
                if sched.queue:
                    # pool idle until the next arrival; when admission is
                    # blocked by an injected fault instead, fall through —
                    # the engine-step counter keeps advancing so timed
                    # faults (pressure windows, refusals) can drain
                    clock.wait_until(t0 + sched.next_arrival())
                    continue
                break
            # ---- DecodeWorker role: one fused step over all lanes
            t_step = clock.now()
            dw.note_step_start(t_step - t0)
            key, sub = jax.random.split(key)
            new_active, ncounts = dw.step(sub)
            dur = clock.now() - t_step
            dw.busy_s += dur
            decode_steps += 1
            for s in np.flatnonzero(dw.active_host):
                rid = dw.slot_rid[s]
                m = metrics[rid]
                base = len(partial.get(rid, ()))
                m.token_latencies_s.append(dur)
                m.new_tokens = base + int(ncounts[s])
                dw.slot_tokens[s] += 1
                if not new_active[s]:         # EOS or budget: free pages
                    m.finished = True
                    m.outcome = "completed"
                    m.finish_s = clock.now() - t0
                    gen = np.asarray(dw.state["tokbuf"][s, :int(ncounts[s])])
                    m.tokens = cumulative(rid, gen)
                    if radix is not None:
                        index_sequence(rid, gen)
                    self._release_pages(alloc, rid)
                    audit()
                    dw.slot_rid[s] = None
            dw.active_host = new_active.copy() & dw.active_host
            dw.note_step_end(clock.now() - t0)
            live = sum(plen_of[dw.slot_rid[s]] + int(ncounts[s])
                       for s in np.flatnonzero(dw.active_host))
            stats.sample(alloc, live)
        self._caches = None          # free the pool between runs
        return ServeReport(
            metrics=[metrics[r.rid] for r in (*reqs, *rejected)],
            scheduler=self.scheduler, slots=B,
            makespan_s=clock.now() - t0, decode_steps=decode_steps,
            prefills=prefills, slot_tokens=dw.slot_tokens,
            peak_concurrency=peak_conc, page_size=self.page_size,
            num_pages=self.num_pages,
            page_occupancy_mean=stats.occupancy_mean,
            page_occupancy_peak=stats.occupancy_peak,
            fragmentation_mean=stats.fragmentation_mean,
            fragmentation_peak=stats.fragmentation_peak,
            pages_high_water=alloc.high_water,
            failed_allocs=alloc.failed_allocs,
            admission_blocked_steps=blocked,
            prefix_enabled=self.prefix_cache,
            prefix_lookups=lookups, prefix_hits=hits,
            prefill_tokens_saved=tokens_saved,
            pages_shared_peak=stats.pages_shared_peak,
            prefix_evictions=radix.evictions if radix else 0,
            preemption_events=preempt_events, requeues=requeues,
            pages_leaked=alloc.owned_pages,
            faults_injected=inj.injected if inj else 0,
            fault_recoveries=inj.recoveries if inj else 0,
            fault_recovery_steps=inj.recovery_steps() if inj else [],
            handoffs=handoff.handoffs,
            handoff_latencies_s=list(handoff.latencies_s),
            queue_depth_peak=max(qd_samples, default=0),
            queue_depth_mean=(float(sum(qd_samples) / len(qd_samples))
                              if qd_samples else 0.0),
            decode_stalls_s=list(dw.stalls_s))


SCHEDULERS["paged"] = PagedEngine
