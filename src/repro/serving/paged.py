"""Paged-KV continuous batching: block-table scheduling over a page pool.

:class:`PagedEngine` keeps the continuous scheduler's slot semantics (a
fixed number of *decode lanes*) but replaces the per-slot monolithic
``cache_span`` KV reservation with a global pool of fixed-size pages
(:mod:`repro.serving.pages`):

* **admission** is gated on *enough free pages* for
  ``prompt_len + max_new_tokens`` tokens — not on a whole span — so at
  equal KV memory budget the paged engine admits strictly more
  concurrent requests whenever real requests are shorter than the span;
* **prefill is chunked**: the prompt streams through
  ``prefill_chunk_tokens``-sized chunks, each writing its K/V straight
  into the request's pages, so a long prompt never needs one contiguous
  span-sized buffer;
* **decode** runs the same fused pool step as the continuous engine,
  but through the block-table paged decode path
  (``model.decode_step_paged`` -> the Pallas paged-attention kernel on
  TPU, the gather reference elsewhere); retirement returns pages to the
  allocator's free list mid-stream.

With ``prefix_cache=True`` a :class:`~repro.serving.prefix.RadixCache`
sits between the queue and the allocator: admission looks up the longest
cached page-aligned prefix of the prompt, attaches the matched pages
read-only into the block table (one physical page, N logical owners via
the allocator's refcounts), and chunk-prefills only the uncached suffix.
When the *entire* prompt is cached, the last matched page is
copy-on-written — duplicated into a fresh page — so re-prefilling the
single token needed for first-token logits never writes a shared page.
Sequences are indexed on prefill completion (the prompt) and again on
retirement (the generated tokens — what makes a returning multi-turn
session warm); LRU refcount-1 entries are evicted when the pool runs
low. Disabled (the default), the engine byte-for-byte matches the
pre-cache scheduler.

Greedy outputs are token-identical to the monolithic engines — paging
and prefix reuse are memory-layout changes, not numerics changes — which
is what ``tools/ci_checks.py paged-parity`` and ``prefix-parity``
enforce.

Unlike the monolithic engines' ``(prefill_fn, decode_fn, cache_init)``
triple, this engine takes the *paged* triple from
:class:`repro.models.model.Model`:

* ``prefill_fn(params, caches, tokens, block_tables, start_pos)``
  (= ``model.prefill_chunk``; ``start_pos`` may land mid-page, the
  warm-suffix path),
* ``decode_fn(params, caches, token, pos, block_tables)``
  (= ``model.decode_step_paged``),
* ``cache_init(num_pages, page_size)`` (= ``model.paged_cache_init``).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.engine import SCHEDULERS, _EngineBase, _sample_tokens
from repro.serving.pages import PageAllocator, PoolStats, pages_needed
from repro.serving.prefix import RadixCache
from repro.serving.request import Request, RequestMetrics, ServeReport


class PagedEngine(_EngineBase):
    """Continuous batching over ``slots`` decode lanes and a paged KV
    pool of ``num_pages`` pages of ``page_size`` tokens (page 0 is the
    reserved null page). ``num_pages=None`` sizes the pool to the
    monolithic engine's budget (``slots x cache_span`` tokens) plus the
    null page, so the default is budget-equivalent by construction;
    benchmarks pass an explicit pool to compare at exactly equal bytes.
    ``prefill_chunk_tokens=0`` prefills each prompt in one chunk.
    ``prefix_cache=True`` enables the prefix-sharing radix cache."""

    scheduler = "paged"

    def __init__(self, prefill_fn, decode_fn, params, cache_init, *,
                 slots: int, cache_span: int, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefill_chunk_tokens: int = 0,
                 prefix_cache: bool = False, **kw):
        self.page_size = int(page_size)
        # block-table width: logical pages a maximal request can touch
        self.npag_max = -(-cache_span // self.page_size)
        if num_pages is None:
            # default: every lane can hold a maximal request at once —
            # the monolithic slots*span budget, rounded up to whole pages
            num_pages = slots * self.npag_max + 1
        self.num_pages = int(num_pages)
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.prefix_cache = bool(prefix_cache)
        super().__init__(prefill_fn, decode_fn, params, cache_init,
                         slots=slots, cache_span=cache_span, **kw)

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1            # minus the null page

    # --------------------------------------------------------- validation
    def admission_error(self, r: Request) -> Optional[str]:
        err = super().admission_error(r)     # budget >= 1, block-table fit
        if err:
            return err
        need = pages_needed(r.prompt_len + r.max_new_tokens, self.page_size)
        if need > self.usable_pages:
            return (f"needs {need} KV pages ({r.prompt_len}+"
                    f"{r.max_new_tokens} tokens at page_size "
                    f"{self.page_size}) but the pool has only "
                    f"{self.usable_pages} usable pages")
        return None

    # --------------------------------------------------------------- jits
    def _setup_jits(self, prefill_fn, decode_fn) -> None:
        donate = self._donate_ok
        # one compile per chunk length; start_pos stays traced
        self._jit_chunk = jax.jit(
            prefill_fn, donate_argnums=(1,) if donate else ())
        # copy-on-write: duplicate page src into page dst across every
        # pool leaf (axis 0 = layers, axis 1 = pages); src/dst stay
        # traced so one compile covers every divergence point
        self._jit_copy = jax.jit(
            lambda caches, src, dst: jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), caches),
            donate_argnums=(0,) if donate else ())
        greedy, eos_id = self.greedy, self.eos_id

        def pool_step(params, caches, state, key):
            logits, caches = decode_fn(params, caches, state["tok"],
                                       state["pos"], state["btab"])
            tok = _sample_tokens(logits[:, -1], key, greedy)      # (B,)
            active = state["active"]
            ncount = state["ncount"]
            B, T = state["tokbuf"].shape
            bidx = jnp.arange(B)
            idx = jnp.minimum(ncount, T - 1)
            cur = state["tokbuf"][bidx, idx]
            tokbuf = state["tokbuf"].at[bidx, idx].set(
                jnp.where(active, tok, cur))
            ncount = ncount + active.astype(jnp.int32)
            stop = ncount >= state["budget"]
            if eos_id is not None:
                stop = stop | (tok == eos_id)
            still = active & ~stop
            return caches, {
                "tok": jnp.where(active, tok, state["tok"][:, 0])[:, None],
                "pos": state["pos"] + active.astype(jnp.int32),
                "active": still,
                "ncount": ncount,
                "budget": state["budget"],
                "tokbuf": tokbuf,
                # retired rows point at the null page so a stale table
                # can never write into a page the allocator reissued
                "btab": jnp.where(still[:, None], state["btab"], 0),
            }

        def admit(state, tok0, btab_row, slot, plen, budget, active0):
            # no cache insertion: chunked prefill already wrote this
            # request's K/V into its own pages of the shared pool
            t0 = tok0[0, 0]
            return {
                "tok": state["tok"].at[slot, 0].set(t0),
                "pos": state["pos"].at[slot].set(plen),
                "active": state["active"].at[slot].set(active0),
                "ncount": state["ncount"].at[slot].set(1),
                "budget": state["budget"].at[slot].set(budget),
                "tokbuf": state["tokbuf"].at[slot, 0].set(t0),
                "btab": state["btab"].at[slot].set(btab_row),
            }

        self._pool_step = jax.jit(
            pool_step, donate_argnums=(1, 2) if donate else ())
        self._admit = jax.jit(
            admit, donate_argnums=(0,) if donate else ())

    # ---------------------------------------------------------- prefill
    def _chunked_prefill(self, prompt: np.ndarray, btab_dev, clock, *,
                         start: int = 0):
        """Stream prompt positions ``[start, len)`` through the pool in
        page-filling chunks; returns the last chunk's logits and the
        number of chunks run. ``start > 0`` is the warm path: positions
        below it are already resident in attached prefix pages, so only
        the suffix pays prefill compute.

        Each chunk sees only the first ``pages_needed(written)`` pages of
        the block table, so attention cost grows with the live prefix
        rather than paying the full cache_span gather on every chunk
        (one jit compile per distinct (chunk length, live pages) pair)."""
        plen = int(prompt.shape[0])
        cs = self.prefill_chunk_tokens or (plen - start)
        logits = None
        chunks = 0
        for lo in range(start, plen, cs):
            end = min(lo + cs, plen)
            n_live = pages_needed(end, self.page_size)
            chunk = jnp.asarray(prompt[None, lo:end])
            logits, self._caches = self._jit_chunk(
                self.params, self._caches, chunk, btab_dev[:, :n_live],
                jnp.int32(lo))
            jax.block_until_ready(logits)
            clock.charge("prefill")     # each chunk is a prefill dispatch
            chunks += 1
        return logits, chunks

    # --------------------------------------------------------- admission
    def _reserve_pages(self, req: Request, alloc: PageAllocator,
                      radix: Optional[RadixCache]):
        """Try to reserve pages for ``req``, reusing the longest cached
        prefix when the radix cache is on. Returns
        ``(pages, suffix_start)`` or ``None`` when the pool (even after
        LRU eviction) cannot cover the fresh remainder — the caller
        blocks the queue head until a retirement frees pages.

        The suffix start is capped at ``prompt_len - 1``: at least one
        prompt token must be re-prefilled to produce the first-token
        logits. When the whole prompt is cached that cap lands mid-page,
        so the final matched page is attached *copy-on-write* — its K/V
        is duplicated into a fresh page before the one-token prefill
        writes into it — and every fully-matched page stays read-only."""
        total_tokens = req.prompt_len + req.max_new_tokens
        if radix is None:
            if not alloc.can_fit(total_tokens):
                return None
            return alloc.allocate(req.rid, total_tokens), 0
        match_pages, match_tok = radix.lookup(np.asarray(req.prompt))
        s0 = min(match_tok, req.prompt_len - 1)
        k_full = s0 // self.page_size
        shared = match_pages[:k_full]
        cow_src = match_pages[k_full] if s0 < match_tok else None
        need_fresh = pages_needed(total_tokens, self.page_size) - len(shared)
        if need_fresh > alloc.num_free:
            radix.evict(need_fresh - alloc.num_free,
                        protect=frozenset(match_pages))
        if need_fresh > alloc.num_free:
            return None
        pages = alloc.allocate(req.rid, total_tokens, shared=shared)
        if cow_src is not None:
            self._caches = self._jit_copy(self._caches, jnp.int32(cow_src),
                                          jnp.int32(pages[k_full]))
        return pages, s0

    # -------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> ServeReport:
        reqs = self._validate(requests)
        B = self.slots
        clock = self.clock
        t0 = clock.now()
        key = jax.random.PRNGKey(self.seed)
        T = self.cache_span
        self._caches = self.cache_init(self.num_pages, self.page_size)
        alloc = PageAllocator(self.num_pages, self.page_size)
        radix = RadixCache(alloc) if self.prefix_cache else None
        stats = PoolStats()
        state = {
            "tok": jnp.zeros((B, 1), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), bool),
            "ncount": jnp.zeros((B,), jnp.int32),
            "budget": jnp.ones((B,), jnp.int32),
            "tokbuf": jnp.zeros((B, T), jnp.int32),
            "btab": jnp.zeros((B, self.npag_max), jnp.int32),
        }
        metrics: Dict[int, RequestMetrics] = {
            r.rid: RequestMetrics(rid=r.rid, prompt_len=r.prompt_len,
                                  arrival_s=r.arrival_s) for r in reqs}
        plen_of = {r.rid: r.prompt_len for r in reqs}
        prompt_of: Dict[int, np.ndarray] = {}
        queue = deque(reqs)
        slot_rid: List[Optional[int]] = [None] * B
        active_host = np.zeros(B, bool)
        slot_tokens = np.zeros(B, np.int64)
        decode_steps = prefills = peak_conc = blocked = 0
        lookups = hits = tokens_saved = 0

        def index_sequence(rid: int, gen_tokens: np.ndarray) -> None:
            """Index the retired request's full pages: its prompt plus
            every generated token whose K/V was written (the final
            sampled token never reaches the pool — no decode step
            consumed it)."""
            seq = np.concatenate([
                prompt_of[rid],
                np.asarray(gen_tokens[:-1], np.int32)])
            radix.insert(seq, alloc.owned(rid))

        while queue or active_host.any():
            # ---- admission: free lane + arrived request + enough pages
            while (queue and not active_host.all()
                   and t0 + queue[0].arrival_s <= clock.now()):
                req = queue[0]
                got = self._reserve_pages(req, alloc, radix)
                if radix is not None:
                    lookups += 1
                if got is None:
                    blocked += 1     # FIFO head waits for retirements
                    break
                pages, s0 = got
                queue.popleft()
                prompt_np = np.asarray(req.prompt, np.int32)
                prompt_of[req.rid] = prompt_np
                slot = int(np.flatnonzero(~active_host)[0])
                m = metrics[req.rid]
                m.admitted_s = clock.now() - t0
                m.slot = slot
                m.cached_prompt_tokens = s0
                if s0 > 0:
                    hits += 1
                    tokens_saved += s0
                peak_conc = max(peak_conc, alloc.num_owners)
                btab_row = np.zeros(self.npag_max, np.int32)
                btab_row[:len(pages)] = pages
                btab_dev = jnp.asarray(btab_row)[None]
                logits, chunks = self._chunked_prefill(
                    prompt_np, btab_dev, clock, start=s0)
                prefills += chunks
                if radix is not None:   # index the prompt's full pages
                    radix.insert(prompt_np, pages)
                key, sub = jax.random.split(key)
                tok0 = _sample_tokens(logits[:, -1:], sub, self.greedy)
                m.first_token_s = clock.now() - t0
                m.new_tokens = 1
                done0 = req.max_new_tokens == 1
                if self.eos_id is not None:
                    done0 = done0 or int(tok0[0, 0]) == self.eos_id
                state = self._admit(state, tok0, btab_dev[0], slot,
                                    req.prompt_len, req.max_new_tokens,
                                    not done0)
                slot_tokens[slot] += 1
                if done0:
                    m.finished = True
                    m.finish_s = m.first_token_s
                    m.tokens = np.asarray([int(tok0[0, 0])], np.int32)
                    alloc.free(req.rid)
                else:
                    active_host[slot] = True
                    slot_rid[slot] = req.rid
            if not active_host.any():
                if queue:          # pool idle until the next arrival
                    clock.wait_until(t0 + queue[0].arrival_s)
                    continue
                break
            # ---- one decode step over all lanes
            t_step = clock.now()
            key, sub = jax.random.split(key)
            self._caches, state = self._pool_step(self.params, self._caches,
                                                  state, sub)
            jax.block_until_ready(state["active"])
            clock.charge("decode")
            dur = clock.now() - t_step
            decode_steps += 1
            new_active = np.asarray(state["active"])
            ncounts = np.asarray(state["ncount"])
            for s in np.flatnonzero(active_host):
                m = metrics[slot_rid[s]]
                m.token_latencies_s.append(dur)
                m.new_tokens = int(ncounts[s])
                slot_tokens[s] += 1
                if not new_active[s]:         # EOS or budget: free pages
                    m.finished = True
                    m.finish_s = clock.now() - t0
                    m.tokens = np.asarray(state["tokbuf"][s, :m.new_tokens])
                    if radix is not None:
                        index_sequence(slot_rid[s], m.tokens)
                    alloc.free(slot_rid[s])
                    slot_rid[s] = None
            active_host = new_active.copy()
            live = sum(plen_of[slot_rid[s]] + int(ncounts[s])
                       for s in np.flatnonzero(active_host))
            stats.sample(alloc, live)
        self._caches = None          # free the pool between runs
        return ServeReport(
            metrics=[metrics[r.rid] for r in reqs],
            scheduler=self.scheduler, slots=B,
            makespan_s=clock.now() - t0, decode_steps=decode_steps,
            prefills=prefills, slot_tokens=slot_tokens,
            peak_concurrency=peak_conc, page_size=self.page_size,
            num_pages=self.num_pages,
            page_occupancy_mean=stats.occupancy_mean,
            page_occupancy_peak=stats.occupancy_peak,
            fragmentation_mean=stats.fragmentation_mean,
            fragmentation_peak=stats.fragmentation_peak,
            pages_high_water=alloc.high_water,
            failed_allocs=alloc.failed_allocs,
            admission_blocked_steps=blocked,
            prefix_enabled=self.prefix_cache,
            prefix_lookups=lookups, prefix_hits=hits,
            prefill_tokens_saved=tokens_saved,
            pages_shared_peak=stats.pages_shared_peak,
            prefix_evictions=radix.evictions if radix else 0)


SCHEDULERS["paged"] = PagedEngine
