"""Deterministic fault injection for the serving engines.

A serving stack that has only ever been benchmarked at nominal load
tells you nothing about how it degrades — the interesting regime is
saturation, contention, and partial failure. This module makes that
regime *reproducible*: a :class:`FaultPlan` is data (seeded, like the
bench workloads), the engines consult it at step granularity through a
:class:`FaultInjector`, and every fault lands at exactly the same engine
step on every run.

Injectable fault kinds (``Fault.kind``):

* ``alloc_refusal``  — the next ``count`` page reservations are refused
  as if the pool were exhausted (transient; the queue head blocks and
  retries, exactly the real pool-pressure admission path);
* ``pool_pressure``  — ``pages`` usable pages are withheld from the
  allocator for ``duration`` engine steps (the free list shrinks without
  any allocation, forcing eviction/blocking on an otherwise-healthy
  pool);
* ``slow_step``      — the engine clock stalls ``stall_s`` seconds at
  one step (a straggler device; under SimClock this is deterministic
  virtual time, so deadline interactions are schedule-stable);
* ``prefill_error``  — the ``req_index``-th prefill dispatch raises
  :class:`InjectedFault` mid-admission (a poisoned kernel launch; the
  engine must fail or requeue *that request only* and release its
  pages);
* ``poison_pool``    — the allocator's bookkeeping is deliberately
  corrupted (a duplicate free-list entry). The engine must *detect* the
  corruption via :meth:`~repro.serving.pages.PageAllocator.check` and
  call :meth:`FaultInjector.heal` to restore the invariant — proving
  the audit actually fires at the faulting step, not at shutdown.

The engine contract (gated by ``tools/ci_checks.py chaos-parity`` and
``tests/test_faults.py``): every fault either recovers (the affected
request is retried/requeued) or fails that one request; the pool passes
``check()`` after every fault; and surviving requests' greedy token
streams are **byte-identical** to a fault-free run — faults perturb
scheduling and timing, never numerics (the chaos-parity property).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.serving.pages import PageAllocator

FAULT_KINDS = ("alloc_refusal", "pool_pressure", "slow_step",
               "prefill_error", "poison_pool")


class InjectedFault(RuntimeError):
    """Raised by the injector inside an engine hot path (prefill_error).
    Engines catch exactly this type — a real exception still escapes."""


@dataclass(frozen=True)
class Fault:
    """One injectable event, keyed to an engine step.

    ``step`` counts *engine steps* — one per scheduler loop iteration
    (admission round or decode step), the granularity at which the
    engines consult the injector. Unused parameters are ignored per
    kind (see module docstring).
    """

    step: int
    kind: str
    count: int = 1          # alloc_refusal: reservations refused
    pages: int = 0          # pool_pressure: usable pages withheld
    duration: int = 1       # pool_pressure: steps the pressure lasts
    stall_s: float = 0.0    # slow_step: extra clock time
    req_index: int = 0      # prefill_error: k-th prefill dispatch raises

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


@dataclass
class FaultPlan:
    """A deterministic schedule of faults — data, like bench workloads.

    Build one by hand for targeted tests, from
    :meth:`FaultPlan.default` for the standard chaos mix, or from a
    JSON file (``--fault-plan plan.json``) for custom sweeps.
    """

    faults: List[Fault] = field(default_factory=list)
    seed: int = 0

    @staticmethod
    def default(seed: int = 0) -> "FaultPlan":
        """The standard chaos mix: one fault of every kind, staggered
        across the early steps of a run (deterministic in ``seed`` —
        the seed shifts the schedule, not the composition)."""
        s = seed % 3
        return FaultPlan(seed=seed, faults=[
            Fault(step=1 + s, kind="alloc_refusal", count=2),
            Fault(step=4 + s, kind="pool_pressure", pages=2, duration=3),
            Fault(step=6 + s, kind="slow_step", stall_s=5.0),
            Fault(step=0, kind="prefill_error", req_index=2 + (seed % 2)),
            Fault(step=8 + s, kind="poison_pool"),
        ])

    # ------------------------------------------------------------ (de)ser
    def to_json(self, path: str | Path) -> Path:
        path = Path(path)
        path.write_text(json.dumps(
            {"seed": self.seed, "faults": [asdict(f) for f in self.faults]},
            indent=2, sort_keys=True) + "\n")
        return path

    @staticmethod
    def from_json(path: str | Path) -> "FaultPlan":
        d = json.loads(Path(path).read_text())
        return FaultPlan(seed=int(d.get("seed", 0)),
                         faults=[Fault(**f) for f in d.get("faults", ())])


def resolve_fault_plan(spec: Optional[str],
                       seed: int = 0) -> Optional[FaultPlan]:
    """CLI/bench helper: ``None``/``"none"`` -> no plan, ``"default"``
    -> :meth:`FaultPlan.default`, anything else -> a JSON file path."""
    if spec is None or spec == "none":
        return None
    if spec == "default":
        return FaultPlan.default(seed)
    return FaultPlan.from_json(spec)


class FaultInjector:
    """Per-run state of a :class:`FaultPlan`: which faults have fired,
    which have recovered, and at what step. One injector per
    ``engine.run`` — the plan itself stays immutable data."""

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self.events: List[Dict] = []      # {step, kind, recovered_step}
        self._fired: set = set()          # indices into plan.faults
        self._refusals_left = 0
        self._pressure: List[tuple] = []  # (until_step, pages, event)
        self._poison: Optional[tuple] = None   # (alloc, page) to undo
        self._prefill_faults: List[tuple] = [] # (req_index, event)
        self._prefills_seen = 0
        self._last_step = -1

    # ------------------------------------------------------------ queries
    @property
    def injected(self) -> int:
        return len(self.events)

    @property
    def recoveries(self) -> int:
        return sum(1 for e in self.events
                   if e["recovered_step"] is not None)

    def recovery_steps(self) -> List[int]:
        return [e["recovered_step"] - e["step"] for e in self.events
                if e["recovered_step"] is not None]

    def unrecovered(self) -> List[Dict]:
        return [e for e in self.events if e["recovered_step"] is None]

    # ------------------------------------------------------- step boundary
    def begin_step(self, step: int, alloc: PageAllocator, clock,
                   role: str = "engine") -> None:
        """Apply every fault scheduled at ``step`` (idempotent per step).
        The engine calls this once per scheduler loop iteration, then
        runs ``alloc.check()`` — poison faults are *meant* to make that
        check raise, see :meth:`heal`. ``role`` names which serving role
        drove the step ("prefill"/"decode" under the disaggregated
        engine, "engine" for the interleaved loops) and is recorded on
        every fault event fired this step."""
        if step <= self._last_step:
            return
        self._last_step = step
        # expire pool pressure that has run its duration
        live = [(until, pages, ev) for until, pages, ev in self._pressure
                if until > step]
        self._pressure = live
        alloc.pressure = sum(p for _, p, _ in live)
        for i, f in enumerate(self.plan.faults):
            if i in self._fired or f.step != step:
                continue
            self._fired.add(i)
            ev = {"step": step, "kind": f.kind, "role": role,
                  "recovered_step": None}
            self.events.append(ev)
            if f.kind == "alloc_refusal":
                self._refusals_left += f.count
            elif f.kind == "pool_pressure":
                self._pressure.append((step + f.duration, f.pages, ev))
                alloc.pressure = sum(p for _, p, _ in self._pressure)
            elif f.kind == "slow_step":
                clock.wait_until(clock.now() + f.stall_s)
                ev["recovered_step"] = step      # pure delay: no cleanup
            elif f.kind == "prefill_error":
                self._prefill_faults.append((f.req_index, ev))
            elif f.kind == "poison_pool":
                self._apply_poison(alloc)

    def _apply_poison(self, alloc: PageAllocator) -> None:
        """Corrupt the pool bookkeeping: duplicate a page onto the free
        list (an issued page when one exists — the nastier case)."""
        issued = sorted(set(alloc._refs))
        page = issued[0] if issued else alloc._free[-1]
        alloc._free.append(page)
        self._poison = (alloc, page)

    def heal(self, alloc: PageAllocator) -> bool:
        """Undo an active poison corruption; returns True when one was
        healed. The engine calls this when ``check()`` raises — a raise
        with *no* active poison is real corruption and must escape."""
        if self._poison is None or self._poison[0] is not alloc:
            return False
        _, page = self._poison
        alloc._free.remove(page)
        self._poison = None
        for e in reversed(self.events):
            if e["kind"] == "poison_pool" and e["recovered_step"] is None:
                e["recovered_step"] = self._last_step
                break
        return True

    # --------------------------------------------------------- admission
    def refuse_alloc(self) -> bool:
        """Consume one transient allocation refusal, if any is pending."""
        if self._refusals_left > 0:
            self._refusals_left -= 1
            return True
        return False

    def check_prefill(self) -> None:
        """Called once per prefill dispatch; raises :class:`InjectedFault`
        when this dispatch index is scheduled to fail."""
        idx = self._prefills_seen
        self._prefills_seen += 1
        for k, (req_index, ev) in enumerate(self._prefill_faults):
            if req_index == idx:
                del self._prefill_faults[k]
                self._open_prefill_event = ev
                raise InjectedFault(
                    f"injected prefill failure at dispatch {idx}")

    def note_prefill_resolved(self, step: int) -> None:
        """The request hit by a prefill_error was requeued or failed —
        either way the engine contained the fault."""
        ev = getattr(self, "_open_prefill_event", None)
        if ev is not None and ev["recovered_step"] is None:
            ev["recovered_step"] = step
            self._open_prefill_event = None

    def note_admission(self, step: int) -> None:
        """A reservation succeeded: any admission-blocking fault whose
        effect has drained (refusals consumed, pressure expired) is now
        recovered — the pool is serving again."""
        for e in self.events:
            if e["recovered_step"] is not None:
                continue
            if e["kind"] == "alloc_refusal" and not self._refusals_left:
                e["recovered_step"] = step
            elif e["kind"] == "pool_pressure" and not self._pressure:
                e["recovered_step"] = step
