"""P/D-disaggregated serving: separate prefill and decode worker pools
over one shared page pool (DESIGN.md §5).

The interleaved :class:`~repro.serving.paged.PagedEngine` runs admission
prefills and decode steps on one timeline, so every chunked prefill
stalls every in-flight decode — the inter-token-latency interference the
findings (results/findings.md §Serving) measure and llm-d-style
prefill/decode disaggregation removes. :class:`DisaggregatedEngine`
composes the same roles (:mod:`repro.serving.roles`) into separate
pools:

* ``prefill_workers`` :class:`PrefillWorker`\\ s pull from the shared
  :class:`Scheduler` queue, reserve pages under the prefill owner key,
  chunk-prefill, and publish (request, first token, block table) to a
  ready set;
* ``decode_workers`` :class:`DecodeWorker`\\ s each own
  ``slots / decode_workers`` lanes; they accept ready requests through
  :meth:`PageHandoff.transfer` (page ownership moves prefill -> decode,
  refcount-conserving, zero KV copy — one shared pool) and run fused
  decode steps that no prefill dispatch can interleave with.

Scheduling is event-driven over per-worker *virtual timelines*: the
engine clock meters each dispatch's cost (the same ``charge`` seam every
engine uses), and the cost is billed to the acting worker's timeline;
the next action always goes to the earliest-runnable worker (prefill
wins ties, mirroring the interleaved engine's admission-first loop).
Under :class:`~repro.serving.request.SimClock` this is a deterministic
simulation of N+M parallel workers; under a wall clock the timelines
degrade to measured sequential cost attribution (dispatches still issue
one at a time from one host process — the *schedule*, not host-level
parallelism, is what disaggregation changes).

Greedy outputs are token-identical to the interleaved paged engine —
per-lane decode math is batch-composition-independent and chunked
prefill writes the same pages either way — which is what
``tools/ci_checks.py pd-parity`` enforces, along with decode-step p95
stall strictly below interleaved under a chunked-prefill-heavy load.

v1 limitation: no preemption (priority still orders admission, but a
decode lane is never evicted for a higher-priority arrival — the victim
choice seam is there, the requeue plumbing across worker pools is not).
Deadlines, faults, the prefix cache, and requeue-on-fault all work.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.engine import SCHEDULERS, _sample_tokens
from repro.serving.faults import FaultInjector, InjectedFault
from repro.serving.pages import PageAllocator, PoolStats
from repro.serving.paged import PagedEngine
from repro.serving.prefix import RadixCache
from repro.serving.request import Request, ServeReport
from repro.serving.roles import (DecodeWorker, PageHandoff, PrefillWorker,
                                 Scheduler)


class DisaggregatedEngine(PagedEngine):
    """Prefill/decode-disaggregated paged serving. ``slots`` is the
    *total* decode-lane count (equal-hardware comparisons against the
    interleaved engine hold it fixed) and must divide evenly across
    ``decode_workers`` so every worker pool shares one jit compile."""

    scheduler = "disaggregated"

    def __init__(self, *args, prefill_workers: int = 1,
                 decode_workers: int = 1, **kw):
        self.prefill_workers = int(prefill_workers)
        self.decode_workers = int(decode_workers)
        if self.prefill_workers < 1 or self.decode_workers < 1:
            raise ValueError(
                f"need >= 1 worker per role, got prefill_workers="
                f"{prefill_workers} decode_workers={decode_workers}")
        super().__init__(*args, **kw)
        if self.slots % self.decode_workers:
            raise ValueError(
                f"slots {self.slots} must divide evenly across "
                f"{self.decode_workers} decode workers")

    # -------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> ServeReport:
        sched = Scheduler(self)
        reqs, rejected = sched.validate(requests)
        clock = self.clock
        key = jax.random.PRNGKey(self.seed)
        self._caches = self.cache_init(self.num_pages, self.page_size)
        alloc = PageAllocator(self.num_pages, self.page_size)
        radix = RadixCache(alloc) if self.prefix_cache else None
        inj = FaultInjector(self.fault_plan) if self.fault_plan else None
        stats = PoolStats()
        handoff = PageHandoff(alloc, self._release_pages, self.page_size)
        lanes_per_dw = self.slots // self.decode_workers
        pws = [PrefillWorker(self, wid=w)
               for w in range(self.prefill_workers)]
        dws = [DecodeWorker(self, lanes_per_dw, wid=w,
                            npag_max=self.npag_max)
               for w in range(self.decode_workers)]
        metrics = self._make_metrics(reqs, rejected)
        plen_of = {r.rid: r.prompt_len for r in reqs}
        prompt_of: Dict[int, np.ndarray] = {}
        partial: Dict[int, np.ndarray] = {}
        # prefilled requests waiting for a decode lane (the handoff queue)
        ready: List[dict] = []
        qd_samples: List[int] = []
        admissions = 0
        decode_steps = prefills = peak_conc = blocked = 0
        lookups = hits = tokens_saved = 0
        requeues = 0
        step = -1

        def audit() -> None:
            if inj is None:
                return
            try:
                alloc.check()
            except AssertionError:
                if not inj.heal(alloc):
                    raise
                alloc.check()

        def index_sequence(rid: int, gen_tokens: np.ndarray) -> None:
            seq = np.concatenate([
                prompt_of[rid],
                np.asarray(gen_tokens[:-1], np.int32)])
            radix.insert(seq, alloc.owned(rid))

        def cumulative(rid: int, gen: np.ndarray) -> np.ndarray:
            prev = partial.get(rid)
            gen = np.asarray(gen, np.int32)
            return gen if prev is None else np.concatenate([prev, gen])

        def requeue_or_fail(rid: int, gen: np.ndarray, now_rel: float,
                            exhausted_outcome: str) -> None:
            nonlocal requeues
            r = sched.req_of[rid]
            m = metrics[rid]
            cum = cumulative(rid, gen)
            m.retries += 1
            if m.retries > r.max_retries:
                m.outcome = exhausted_outcome
                m.finish_s = now_rel
                m.new_tokens = len(cum)
                m.tokens = cum
                return
            if len(gen):
                partial[rid] = cum
            arrival = now_rel + self.requeue_backoff_s
            nr = Request(
                rid=rid,
                prompt=np.concatenate([np.asarray(r.prompt, np.int32),
                                       np.asarray(gen, np.int32)]),
                max_new_tokens=r.max_new_tokens - len(gen),
                arrival_s=arrival,
                deadline_s=(None if r.deadline_abs_s is None
                            else r.deadline_abs_s - arrival),
                priority=r.priority, max_retries=r.max_retries)
            plen_of[rid] = nr.prompt_len
            sched.requeue(nr)
            requeues += 1

        def metered(fn, *args, **kw):
            """Run a dispatch, return (result, clock cost) — the cost a
            worker bills to its own virtual timeline."""
            c0 = clock.now()
            out = fn(*args, **kw)
            return out, clock.now() - c0

        def injector_step(role: str, t: float) -> float:
            """Advance the fault schedule by one engine step billed to
            the acting worker's timeline (a slow_step stall charges the
            clock; that elapsed time lands on this worker alone)."""
            if inj is None:
                return t
            c0 = clock.now()
            inj.begin_step(step, alloc, clock, role=role)
            t += clock.now() - c0
            audit()
            return t

        def decode_ready_t(d: DecodeWorker) -> float:
            """Earliest time decode worker ``d`` can act (inf = no work:
            no active lanes and nothing seatable in the ready set)."""
            if d.active_host.any():
                return d.t
            if ready and d.free_lane() is not None:
                return max(d.t, min(h["ready_t"] for h in ready))
            return float("inf")

        # ---- event loop over worker virtual timelines
        while sched.queue or ready or any(d.active_host.any() for d in dws):
            cands = []
            if sched.queue:
                pw = min(pws, key=lambda w: (w.t, w.wid))
                # 0 = prefill acts first on a tie, mirroring the
                # interleaved engine's admission-before-decode loop
                cands.append((max(pw.t, sched.next_arrival()), 0,
                              pw.wid, pw))
            for d in dws:
                t_d = decode_ready_t(d)
                if t_d != float("inf"):
                    cands.append((t_d, 1, d.wid, d))
            t_act, kind, _, w = min(cands, key=lambda c: c[:3])
            step += 1
            qd_samples.append(sched.queue_depth())

            if kind == 0:
                # ---------------------------------------- prefill action
                w.t = max(w.t, t_act)
                w.t = injector_step("prefill", w.t)
                now_rel = w.t
                for r in sched.reap_queued(now_rel):
                    m = metrics[r.rid]
                    m.outcome = "timed_out"
                    cum = cumulative(r.rid, np.zeros(0, np.int32))
                    if len(cum):
                        m.new_tokens = len(cum)
                        m.tokens = cum
                        m.finish_s = now_rel
                req = sched.peek_best(now_rel)
                if req is None:
                    # nothing arrived yet: idle until the next arrival
                    if sched.queue:
                        w.t = max(w.t, sched.next_arrival())
                    continue
                if inj is not None and inj.refuse_alloc():
                    blocked += 1     # transient injected refusal: retry
                    continue
                got = w.reserve(req, alloc, radix)
                if radix is not None:
                    lookups += 1
                if got is None:
                    blocked += 1     # wait for decode-side retirements
                    pending = [d.t for d in dws if d.active_host.any()]
                    pending += [max(d.t, h["ready_t"]) for h in ready
                                for d in dws if d.free_lane() is not None]
                    if pending:
                        w.t = max(w.t, min(pending))
                    elif inj is None:
                        raise RuntimeError(
                            f"request {req.rid} cannot reserve pages and "
                            "no decode work is pending — the pool cannot "
                            "make progress")
                    # under an injector, fall through: the engine-step
                    # counter keeps advancing so pressure windows drain
                    continue
                pages, s0 = got
                sched.take(req)
                prompt_np = np.asarray(req.prompt, np.int32)
                prompt_of[req.rid] = prompt_np
                m = metrics[req.rid]
                base = len(partial.get(req.rid, ()))
                m.admitted_s = w.t
                m.prefill_worker = w.wid
                m.cached_prompt_tokens = s0
                if s0 > 0:
                    hits += 1
                    tokens_saved += s0
                peak_conc = max(peak_conc, alloc.num_owners)
                btab_row = np.zeros(self.npag_max, np.int32)
                btab_row[:len(pages)] = pages
                btab_dev = jnp.asarray(btab_row)[None]
                try:
                    if inj is not None:
                        inj.check_prefill()
                    (logits, chunks), cost = metered(
                        w.prefill, prompt_np, btab_dev, clock, start=s0)
                except InjectedFault:
                    handoff.abort(req.rid)
                    audit()
                    requeue_or_fail(req.rid, np.zeros(0, np.int32),
                                    w.t, "failed")
                    inj.note_prefill_resolved(step)
                    continue
                prefills += chunks
                w.t += cost
                w.busy_s += cost
                if radix is not None:
                    radix.insert(prompt_np, pages)
                key, sub = jax.random.split(key)
                tok0 = _sample_tokens(logits[:, -1:], sub, self.greedy)
                if base == 0:
                    m.first_token_s = w.t
                m.new_tokens = base + 1
                admissions += 1
                if inj is not None:
                    inj.note_admission(step)
                done0 = req.max_new_tokens == 1
                if self.eos_id is not None:
                    done0 = done0 or int(tok0[0, 0]) == self.eos_id
                if done0:
                    # completed at prefill: never reaches a decode lane,
                    # so the prefill-role hold is released, not handed off
                    m.finished = True
                    m.outcome = "completed"
                    m.finish_s = w.t
                    m.tokens = cumulative(
                        req.rid, np.asarray([int(tok0[0, 0])], np.int32))
                    handoff.abort(req.rid)
                    audit()
                else:
                    ready.append({"req": req, "tok0": tok0,
                                  "btab_row": btab_row, "base": base,
                                  "ready_t": w.t})
                continue

            # -------------------------------------------- decode action
            d = w
            d.t = max(d.t, t_act)
            d.t = injector_step("decode", d.t)
            # accept every ready handoff this worker can seat now
            while True:
                slot = d.free_lane()
                if slot is None:
                    break
                avail = [h for h in ready if h["ready_t"] <= d.t]
                if not avail:
                    break
                h = min(avail, key=lambda h: (h["ready_t"], h["req"].rid))
                ready.remove(h)
                req = h["req"]
                handoff.transfer(req.rid)
                _, cost = metered(clock.charge, "handoff")
                d.t += cost
                lat = d.t - h["ready_t"]
                handoff.latencies_s.append(lat)
                m = metrics[req.rid]
                m.handoff_latency_s = lat
                m.decode_worker = d.wid
                m.slot = d.wid * lanes_per_dw + slot
                d.admit(h["tok0"], jnp.asarray(h["btab_row"]), slot,
                        req.prompt_len, req.max_new_tokens, True)
                d.slot_rid[slot] = req.rid
                d.active_host[slot] = True
                d.slot_tokens[slot] += 1
                d.admit_seq[slot] = admissions
            now_rel = d.t
            doomed = sched.doomed_slots(now_rel, d.slot_rid, d.active_host)
            if doomed:
                ncounts = np.asarray(d.state["ncount"])
                for s in doomed:
                    rid = d.slot_rid[s]
                    m = metrics[rid]
                    n = int(ncounts[s])
                    gen = np.asarray(d.state["tokbuf"][s, :n])
                    if radix is not None:
                        index_sequence(rid, gen)
                    self._release_pages(alloc, rid)
                    d.slot_rid[s] = None
                    d.active_host[s] = False
                    d.evict(s)
                    cum = cumulative(rid, gen)
                    m.outcome = "timed_out"
                    m.new_tokens = len(cum)
                    m.tokens = cum
                    m.finish_s = now_rel
                audit()
            if not d.active_host.any():
                # nothing seated (all ready_t in the future): jump ahead
                if ready:
                    d.t = max(d.t, min(h["ready_t"] for h in ready))
                continue
            d.note_step_start(d.t)
            key, sub = jax.random.split(key)
            (new_active, ncounts), cost = metered(d.step, sub)
            d.t += cost
            d.busy_s += cost
            decode_steps += 1
            for s in np.flatnonzero(d.active_host):
                rid = d.slot_rid[s]
                m = metrics[rid]
                base = len(partial.get(rid, ()))
                m.token_latencies_s.append(cost)
                m.new_tokens = base + int(ncounts[s])
                d.slot_tokens[s] += 1
                if not new_active[s]:
                    m.finished = True
                    m.outcome = "completed"
                    m.finish_s = d.t
                    gen = np.asarray(d.state["tokbuf"][s, :int(ncounts[s])])
                    m.tokens = cumulative(rid, gen)
                    if radix is not None:
                        index_sequence(rid, gen)
                    self._release_pages(alloc, rid)
                    audit()
                    d.slot_rid[s] = None
            d.active_host = new_active.copy() & d.active_host
            d.note_step_end(d.t)
            live = sum(plen_of[d.slot_rid[s]] + int(ncounts[s])
                       for s in np.flatnonzero(d.active_host))
            stats.sample(alloc, live)

        self._caches = None
        makespan = max([w.t for w in pws] + [d.t for d in dws] + [0.0])
        prefill_busy = sum(w.busy_s for w in pws)
        decode_busy = sum(d.busy_s for d in dws)
        denom = max(makespan, 1e-9)
        return ServeReport(
            metrics=[metrics[r.rid] for r in (*reqs, *rejected)],
            scheduler=self.scheduler, slots=self.slots,
            makespan_s=makespan, decode_steps=decode_steps,
            prefills=prefills,
            slot_tokens=np.concatenate([d.slot_tokens for d in dws]),
            peak_concurrency=peak_conc, page_size=self.page_size,
            num_pages=self.num_pages,
            page_occupancy_mean=stats.occupancy_mean,
            page_occupancy_peak=stats.occupancy_peak,
            fragmentation_mean=stats.fragmentation_mean,
            fragmentation_peak=stats.fragmentation_peak,
            pages_high_water=alloc.high_water,
            failed_allocs=alloc.failed_allocs,
            admission_blocked_steps=blocked,
            prefix_enabled=self.prefix_cache,
            prefix_lookups=lookups, prefix_hits=hits,
            prefill_tokens_saved=tokens_saved,
            pages_shared_peak=stats.pages_shared_peak,
            prefix_evictions=radix.evictions if radix else 0,
            preemption_events=0, requeues=requeues,
            pages_leaked=alloc.owned_pages,
            faults_injected=inj.injected if inj else 0,
            fault_recoveries=inj.recoveries if inj else 0,
            fault_recovery_steps=inj.recovery_steps() if inj else [],
            prefill_workers=self.prefill_workers,
            decode_workers=self.decode_workers,
            prefill_busy_s=prefill_busy, decode_busy_s=decode_busy,
            prefill_util=prefill_busy / (self.prefill_workers * denom),
            decode_util=decode_busy / (self.decode_workers * denom),
            handoffs=handoff.handoffs,
            handoff_latencies_s=list(handoff.latencies_s),
            queue_depth_peak=max(qd_samples, default=0),
            queue_depth_mean=(float(sum(qd_samples) / len(qd_samples))
                              if qd_samples else 0.0),
            decode_stalls_s=[s for d in dws for s in d.stalls_s])


SCHEDULERS["disaggregated"] = DisaggregatedEngine
