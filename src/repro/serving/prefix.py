"""Prefix-sharing radix cache over the paged KV pool.

Two requests with the same system prompt pay for the prefix twice in the
plain paged engine — once in KV pages, once in redundant chunked-prefill
compute. This module is the reuse layer (SGLang's RadixAttention idea
applied to our page pool): a trie over **page-aligned token prefixes**
where every node is one full page of tokens mapped to the physical page
holding its K/V.

* :meth:`RadixCache.insert` indexes a sequence's full pages (called on
  prefill completion for the prompt and again on retirement for the
  generated tokens, which is what makes multi-turn sessions warm). Each
  newly indexed page gets an ownerless +1 refcount via
  :meth:`~repro.serving.pages.PageAllocator.share`, so it stays resident
  after its writer retires.
* :meth:`RadixCache.lookup` walks the trie for the longest indexed
  page-aligned prefix of a new prompt; the engine attaches the matched
  pages read-only into the request's block table and chunk-prefills only
  the uncached suffix.
* :meth:`RadixCache.evict` drops least-recently-used leaves whose pages
  nobody but the cache references (refcount 1) when the pool runs low —
  cached-but-idle prefixes never block a live admission.

Only full pages are indexed: a page is immutable once every position in
it is written (prompt pages before the decode region, and on retirement
everything the request wrote), so sharing is read-only by construction.
The divergence *inside* a page is the engine's job — it copies the page
before writing into it (copy-on-write, see
:class:`repro.serving.paged.PagedEngine`).

The cache is also the engine's warm-restart path for fault tolerance:
a request evicted mid-decode (deadline reap or priority preemption)
has its prompt + generated-so-far tokens indexed *before* its pages are
freed — the ownerless cache refcount keeps those pages resident — so
when the preempted request requeues with its progress appended to the
prompt, admission matches the indexed prefix and re-prefills only the
final partial page instead of the whole extended prompt.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.pages import PageAllocator


class _Node:
    """One full page of tokens: ``key`` (page_size token ids) -> the
    physical ``page`` holding their K/V."""

    __slots__ = ("key", "page", "children", "parent", "last_used")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["_Node"], last_used: int) -> None:
        self.key = key
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_used = last_used


class RadixCache:
    """Radix/trie index of page-aligned prefixes over ``alloc``'s pages.

    The cache and the allocator it indexes share one lifetime (the
    engine builds both per run); eviction order is LRU by last
    lookup/insert touch."""

    def __init__(self, alloc: PageAllocator) -> None:
        self.alloc = alloc
        self.page_size = alloc.page_size
        self._root = _Node((), -1, None, 0)
        self._tick = 0
        self.evictions = 0           # pages evicted (refcount-1 LRU drops)

    # ------------------------------------------------------------ queries
    @property
    def num_pages(self) -> int:
        """Pages currently indexed (== trie nodes)."""
        n = 0
        stack = [self._root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    def _chunks(self, tokens: Sequence[int]):
        toks = np.asarray(tokens).reshape(-1)
        ps = self.page_size
        for i in range(len(toks) // ps):
            yield tuple(int(t) for t in toks[i * ps:(i + 1) * ps])

    # ------------------------------------------------------------- lookup
    def lookup(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest indexed page-aligned prefix of ``tokens``: returns
        ``(pages, matched_tokens)`` with ``pages`` the physical page ids
        in logical order and ``matched_tokens == len(pages) * page_size``.
        Touches the matched path (LRU)."""
        self._tick += 1
        node = self._root
        pages: List[int] = []
        for key in self._chunks(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._tick
            pages.append(child.page)
            node = child
        return pages, len(pages) * self.page_size

    # ------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Index every full page of ``tokens`` (whose K/V lives in
        ``pages``, the owner's block table in logical order). Existing
        nodes are kept (first writer wins — identical token content, so
        the physical copies are interchangeable); each *newly* indexed
        page gains an ownerless cache reference. Returns the number of
        pages newly indexed."""
        self._tick += 1
        node = self._root
        added = 0
        for i, key in enumerate(self._chunks(tokens)):
            child = node.children.get(key)
            if child is None:
                page = int(pages[i])
                child = _Node(key, page, node, self._tick)
                node.children[key] = child
                self.alloc.share([page])
                added += 1
            else:
                child.last_used = self._tick
            node = child
        return added

    # ------------------------------------------------------------- evict
    def _evictable_leaves(self, protect: FrozenSet[int]):
        out = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                if child.children:
                    stack.append(child)
                elif (child.page not in protect
                        and self.alloc.refcount(child.page) == 1):
                    out.append(child)
        return out

    def evict(self, need_pages: int,
              protect: FrozenSet[int] = frozenset()) -> int:
        """Free at least ``need_pages`` pages by dropping LRU leaves whose
        pages only the cache references (refcount 1). ``protect`` pins a
        just-looked-up match so eviction can never cannibalize the prefix
        it is making room for. Returns the number of pages freed (may be
        less than asked when nothing else is evictable)."""
        freed = 0
        while freed < need_pages:
            leaves = self._evictable_leaves(protect)
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_used)
            del victim.parent.children[victim.key]
            freed += len(self.alloc.release([victim.page]))
            self.evictions += 1
        return freed
