"""Request-level serving engines on top of the jitted prefill/decode steps.

Two schedulers over the same (prefill_fn, decode_fn, params) triple:

* :class:`StaticEngine`     — the classic lockstep loop: requests are
  grouped into fixed batches in arrival order; a batch prefills together
  and decodes to the *longest* budget in the batch. This is the old
  ``serve_loop.generate`` behavior recast as a request-level scheduler
  (finished rows ride along as dead weight until the batch drains).
* :class:`ContinuousEngine` — continuous batching (Orca/vLLM-style) on a
  fixed pool of B KV slots: every decode step advances all occupied slots
  with per-slot positions; a request that hits EOS or its budget frees
  its slot *mid-stream* and the next queued request is admitted into it.

Both engines are model-agnostic: they only require

* ``prefill_fn(params, batch, cache_span) -> (logits, caches)`` where
  every cache leaf carries the batch dimension on axis 1 (the repro
  models' ``(L, B, ...)`` stacked-layer layout);
* ``decode_fn(params, caches, token, pos) -> (logits, caches)`` accepting
  a scalar ``pos`` (static) or a ``(B,)`` vector (continuous);
* ``cache_init(batch, max_len) -> caches`` to allocate the slot pool.

Tokens accumulate in a device buffer and cross to the host once per
request (continuous) or once per batch (static) — never one host sync
per token.  The engines *do* block once per decode step: per-token
latency (the Tier-2 metric) is measured per step, and the continuous
scheduler needs the per-slot done flags to make admission decisions —
that per-step host roundtrip is the scheduling cost continuous batching
pays for its occupancy win, and it is part of what we measure.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.request import (Request, RequestMetrics, ServeReport,
                                   WallClock)
# RequestQueue lives with the other scheduling roles now; re-exported here
# because it predates the role split and callers import it from this module
from repro.serving.roles import (PrefillWorker, RequestQueue,  # noqa: F401
                                 Scheduler)


def _default_prompt_to_batch(prompts: np.ndarray) -> dict:
    """(b, prompt_len) int32 token prompts -> a prefill batch dict."""
    return {"tokens": jnp.asarray(np.asarray(prompts, np.int32))}


def _sample_tokens(logits, key, greedy: bool):
    """logits (..., V) -> token ids with the leading shape of logits."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits).astype(jnp.int32)


# ------------------------------------------------------------------ lockstep
def decode_lockstep(decode_step: Callable, params, caches, tok0, *,
                    start_pos: int, steps: int, greedy: bool = True,
                    key=None, timer=None):
    """Lockstep decode: every row advances one token per step starting at
    ``start_pos``. Tokens accumulate in a device buffer and transfer to the
    host ONCE after the loop — the per-step ``np.asarray`` host sync the
    old loop paid is gone, so dispatch runs ahead of the device.

    With ``timer`` (a clock from :mod:`repro.serving.request`), each step
    is instead blocked and individually timed — the latency-measuring mode
    StaticEngine uses; ``step_times`` is then a list of per-step seconds.

    Returns ``(tokens, caches, step_times)`` with tokens a host
    ``(B, steps + 1)`` array (row 0 is ``tok0``).
    """
    if key is None and not greedy:
        key = jax.random.PRNGKey(0)
    B = tok0.shape[0]
    buf = jnp.zeros((B, steps + 1), jnp.int32).at[:, 0].set(tok0[:, 0])
    tok = tok0
    times: Optional[List[float]] = [] if timer is not None else None
    for i in range(steps):
        t0 = timer.now() if timer is not None else 0.0
        logits, caches = decode_step(params, caches, tok,
                                     jnp.int32(start_pos + i))
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        buf = buf.at[:, i + 1].set(tok[:, 0])
        if timer is not None:
            jax.block_until_ready(tok)
            timer.charge("decode")
            times.append(timer.now() - t0)
    jax.block_until_ready(buf)
    return np.asarray(buf), caches, times


# -------------------------------------------------------------------- base
class _EngineBase:
    scheduler = "base"

    def __init__(self, prefill_fn: Callable, decode_fn: Callable, params,
                 cache_init: Callable, *, slots: int, cache_span: int,
                 eos_id: Optional[int] = None, greedy: bool = True,
                 seed: int = 0, clock=None, reject_invalid: bool = False,
                 prompt_to_batch: Callable = _default_prompt_to_batch):
        self.params = params
        # reject_invalid=True turns impossible requests into outcome
        # "rejected" metrics instead of a ValueError — the serving-facing
        # mode; tests/tools keep the strict raise as their default
        self.reject_invalid = reject_invalid
        self.cache_init = cache_init
        self.slots = slots
        self.cache_span = cache_span
        self.eos_id = eos_id
        self.greedy = greedy
        self.seed = seed
        self.clock = clock or WallClock()
        self.prompt_to_batch = prompt_to_batch
        self._decode_fn = decode_fn
        # buffer donation is a no-op on CPU and only triggers warnings
        self._donate_ok = jax.default_backend() != "cpu"
        self._setup_jits(prefill_fn, decode_fn)

    def _setup_jits(self, prefill_fn, decode_fn) -> None:
        """Build the jitted entry points (the paged engine overrides this:
        its prefill/decode callables carry block tables instead of a
        monolithic batch)."""
        greedy = self.greedy

        def prefill_sample(params, batch, cache_span, key):
            logits, caches = prefill_fn(params, batch, cache_span)
            return _sample_tokens(logits[:, -1:], key, greedy), caches

        # cache_span is static: jit specializes per (prompt_len, span);
        # first-token sampling is fused in so admission is one dispatch
        self._jit_prefill = jax.jit(prefill_sample, static_argnums=(2,))
        self._jit_decode = jax.jit(
            decode_fn, donate_argnums=(1,) if self._donate_ok else ())

    # ---- helpers shared by all schedulers
    def admission_error(self, r: Request) -> Optional[str]:
        """Why ``r`` can NEVER be served by this engine (None = servable).

        The single validation hook every scheduler routes through, so
        rejection is *symmetric*: static, continuous, and paged engines
        refuse the same impossible requests with the same message —
        rather than one scheduler raising while another admits the
        request and silently corrupts slot state past its capacity. The
        paged engine overrides this with its page-pool capacity check."""
        if r.max_new_tokens < 1:
            return f"max_new_tokens must be >= 1, got {r.max_new_tokens}"
        if r.prompt_len + r.max_new_tokens > self.cache_span:
            return (f"prompt_len + max_new_tokens "
                    f"({r.prompt_len}+{r.max_new_tokens}) exceeds "
                    f"cache_span {self.cache_span}")
        return None

    def _validate(self, requests: Sequence[Request]
                  ) -> Tuple[List[Request], List[Request]]:
        """Sort by arrival and split servable from impossible requests.
        With ``reject_invalid`` the impossible ones come back in the
        second list (outcome "rejected"); otherwise they raise."""
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        ok: List[Request] = []
        rejected: List[Request] = []
        for r in reqs:
            err = self.admission_error(r)
            if err and not self.reject_invalid:
                raise ValueError(f"request {r.rid}: {err}")
            (rejected if err else ok).append(r)
        return ok, rejected

    @staticmethod
    def _make_metrics(reqs: Sequence[Request], rejected: Sequence[Request]
                      ) -> Dict[int, RequestMetrics]:
        """Per-request metrics for a run; rejected requests are terminal
        immediately (never admitted, never scheduled)."""
        metrics = {
            r.rid: RequestMetrics(rid=r.rid, prompt_len=r.prompt_len,
                                  arrival_s=r.arrival_s)
            for r in (*reqs, *rejected)}
        for r in rejected:
            metrics[r.rid].outcome = "rejected"
        return metrics

    def _prefill_one_batch(self, prompts: np.ndarray, key):
        """Prefill (b, L) prompts; returns (tok0 (b,1), caches)."""
        batch = self.prompt_to_batch(prompts)
        tok0, caches = self._jit_prefill(self.params, batch,
                                         self.cache_span, key)
        jax.block_until_ready(tok0)
        self.clock.charge("prefill")
        return tok0, caches

    def warmup(self, prompt_len: int) -> None:
        """Trigger jit compiles (prefill at prompt_len + decode steps)
        outside the measured run — one full slot pool of dummy requests,
        so the static engine also compiles its full-batch prefill."""
        budget = max(1, min(2, self.cache_span - prompt_len))
        self.run([Request(rid=-1 - i, prompt=np.ones(prompt_len, np.int32),
                          max_new_tokens=budget)
                  for i in range(self.slots)])

    def run(self, requests: Sequence[Request]) -> ServeReport:
        raise NotImplementedError


# ------------------------------------------------------------------ static
class StaticEngine(_EngineBase):
    """Lockstep batch-at-a-time scheduling: the old ``generate`` loop as a
    request-level scheduler. Each batch admits together (waiting for its
    slowest arrival), prefills together, and decodes to the longest budget
    in the batch; rows that finish early occupy their slot doing useless
    work until the batch drains. Requests within one batch must share a
    prompt length (no padding path).

    SLO semantics: lockstep batches cannot free a row mid-flight, so
    priorities are ignored (arrival-order batching — the baseline the
    preempting schedulers are measured against) and deadline misses are
    detected after the batch drains — but credited by the same
    :meth:`Scheduler.deadline_truncate` rule the per-step reapers use:
    only tokens whose decode step finished by the deadline count as
    generated (the lane kept lockstepping past it, but that work is
    wasted, not goodput), so an expired request no longer over-counts
    ``new_tokens`` relative to the continuous/paged engines."""

    scheduler = "static"

    def run(self, requests: Sequence[Request]) -> ServeReport:
        sched = Scheduler(self)
        reqs, rejected = sched.validate(requests)
        B = self.slots
        clock = self.clock
        t0 = clock.now()
        key = jax.random.PRNGKey(self.seed)
        metrics = self._make_metrics(reqs, rejected)
        slot_tokens = np.zeros(B, np.int64)
        decode_steps = prefills = peak_conc = 0

        for start in range(0, len(reqs), B):
            chunk = reqs[start:start + B]
            plens = {r.prompt_len for r in chunk}
            if len(plens) > 1:
                raise ValueError(
                    "StaticEngine requires equal prompt lengths within a "
                    f"batch, got {sorted(plens)} — bucket the workload or "
                    "use the continuous scheduler")
            # the whole batch waits for its slowest member
            clock.wait_until(t0 + max(r.arrival_s for r in chunk))
            t_adm = clock.now() - t0
            prompts = np.stack([np.asarray(r.prompt, np.int32)
                                for r in chunk])
            if len(chunk) < B:
                # pad a partial final batch to full width (dummy rows are
                # discarded) so the prefill/decode shapes — and their
                # warmup()-time compiles — are identical for every chunk
                prompts = np.concatenate(
                    [prompts, np.repeat(prompts[:1], B - len(chunk), 0)])
            key, sub = jax.random.split(key)
            tok0, caches = self._prefill_one_batch(prompts, sub)
            prefills += 1
            peak_conc = max(peak_conc, len(chunk))
            t_first = clock.now() - t0
            budget_max = max(r.max_new_tokens for r in chunk)
            key, sub = jax.random.split(key)
            toks, caches, times = decode_lockstep(
                self._jit_decode, self.params, caches, tok0,
                start_pos=chunk[0].prompt_len, steps=budget_max - 1,
                greedy=self.greedy, key=sub, timer=clock)
            decode_steps += budget_max - 1
            for i, r in enumerate(chunk):
                own = toks[i, :r.max_new_tokens]
                n = r.max_new_tokens
                if self.eos_id is not None:
                    hits = np.flatnonzero(own == self.eos_id)
                    if hits.size:
                        n = int(hits[0]) + 1
                m = metrics[r.rid]
                m.admitted_s, m.first_token_s = t_adm, t_first
                n, finish, timed_out = Scheduler.deadline_truncate(
                    t_first, times[:n - 1], r.deadline_abs_s)
                m.slot, m.new_tokens, m.tokens = i, n, own[:n]
                m.token_latencies_s = list(times[:n - 1])
                m.finish_s = finish
                if timed_out:
                    m.outcome = "timed_out"   # credited only to the SLO
                else:
                    m.finished = True
                    m.outcome = "completed"
                slot_tokens[i] += n
        return ServeReport(metrics=[metrics[r.rid] for r in (*reqs,
                                                             *rejected)],
                           scheduler=self.scheduler, slots=B,
                           makespan_s=clock.now() - t0,
                           decode_steps=decode_steps, prefills=prefills,
                           slot_tokens=slot_tokens,
                           peak_concurrency=peak_conc)


# -------------------------------------------------------------- continuous
class ContinuousEngine(_EngineBase):
    """Continuous batching over a fixed pool of B KV slots.

    Device state per slot: last token, position, active flag, generated
    count, budget, and a row of the token buffer. One fused jitted step
    decodes the whole pool with per-slot positions, samples, appends to
    the token buffer, and retires slots that hit EOS or budget; the host
    reads back only the tiny per-slot flags each step to drive admission.
    """

    scheduler = "continuous"

    def _pool_step_fn(self):
        decode_fn, greedy, eos_id = self._decode_fn, self.greedy, self.eos_id

        def pool_step(params, caches, state, key):
            logits, caches = decode_fn(params, caches, state["tok"],
                                       state["pos"])
            tok = _sample_tokens(logits[:, -1], key, greedy)      # (B,)
            active = state["active"]
            ncount = state["ncount"]
            B, T = state["tokbuf"].shape
            bidx = jnp.arange(B)
            idx = jnp.minimum(ncount, T - 1)
            cur = state["tokbuf"][bidx, idx]
            tokbuf = state["tokbuf"].at[bidx, idx].set(
                jnp.where(active, tok, cur))
            ncount = ncount + active.astype(jnp.int32)
            stop = ncount >= state["budget"]
            if eos_id is not None:
                stop = stop | (tok == eos_id)
            return caches, {
                "tok": jnp.where(active, tok, state["tok"][:, 0])[:, None],
                "pos": state["pos"] + active.astype(jnp.int32),
                "active": active & ~stop,
                "ncount": ncount,
                "budget": state["budget"],
                "tokbuf": tokbuf,
            }

        return jax.jit(pool_step,
                       donate_argnums=(1, 2) if self._donate_ok else ())

    def _admit_fn(self):
        """One fused dispatch per admission: insert the prefilled caches
        into the slot (traced index — one compile for every slot) and set
        the slot's scheduler state."""

        def admit(caches, state, one, tok0, slot, plen, budget, active0):
            caches = jax.tree.map(
                lambda pool, o: jax.lax.dynamic_update_index_in_dim(
                    pool, o[:, 0], slot, axis=1), caches, one)
            t0 = tok0[0, 0]
            return caches, {
                "tok": state["tok"].at[slot, 0].set(t0),
                "pos": state["pos"].at[slot].set(plen),
                "active": state["active"].at[slot].set(active0),
                "ncount": state["ncount"].at[slot].set(1),
                "budget": state["budget"].at[slot].set(budget),
                "tokbuf": state["tokbuf"].at[slot, 0].set(t0),
            }

        return jax.jit(admit,
                       donate_argnums=(0, 1) if self._donate_ok else ())

    def run(self, requests: Sequence[Request]) -> ServeReport:
        sched = Scheduler(self)
        reqs, rejected = sched.validate(requests)
        pw = PrefillWorker(self)
        B = self.slots
        clock = self.clock
        t0 = clock.now()
        key = jax.random.PRNGKey(self.seed)
        if not hasattr(self, "_pool_step"):
            self._pool_step = self._pool_step_fn()
            self._admit = self._admit_fn()
        # token buffer sized by the cache span (an upper bound on any
        # budget) so the pool step's shape — and its jit compile — is
        # stable across runs with different budget mixes
        T = self.cache_span
        caches = self.cache_init(B, self.cache_span)
        state = {
            "tok": jnp.zeros((B, 1), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), bool),
            "ncount": jnp.zeros((B,), jnp.int32),
            "budget": jnp.ones((B,), jnp.int32),
            "tokbuf": jnp.zeros((B, T), jnp.int32),
        }
        metrics = self._make_metrics(reqs, rejected)
        slot_rid: List[Optional[int]] = [None] * B
        active_host = np.zeros(B, bool)
        slot_tokens = np.zeros(B, np.int64)
        decode_steps = prefills = peak_conc = 0

        while sched.queue or active_host.any():
            # ---- Scheduler role: reap queued then active requests past SLO
            now_rel = clock.now() - t0
            for r in sched.reap_queued(now_rel):
                metrics[r.rid].outcome = "timed_out"
            doomed = sched.doomed_slots(now_rel, slot_rid, active_host)
            if doomed:
                ncounts = np.asarray(state["ncount"])
                for s in doomed:
                    m = metrics[slot_rid[s]]
                    m.outcome = "timed_out"
                    m.new_tokens = int(ncounts[s])
                    m.finish_s = now_rel
                    m.tokens = np.asarray(
                        state["tokbuf"][s, :m.new_tokens])
                    slot_rid[s] = None
                    active_host[s] = False
                # retire the lanes on device too, so the pool step
                # stops advancing (and charging for) the dead rows
                keep = jnp.asarray(active_host)
                state["active"] = state["active"] & keep
            # ---- admission: free slot + arrived request -> prefill into it
            while sched.queue and not active_host.all():
                req = sched.peek_best(clock.now() - t0)
                if req is None:
                    break
                sched.take(req)
                slot = int(np.flatnonzero(~active_host)[0])
                m = metrics[req.rid]
                m.admitted_s = clock.now() - t0
                m.slot = slot
                key, sub = jax.random.split(key)
                tok0, one = pw.prefill_batch(
                    np.asarray(req.prompt, np.int32)[None, :], sub)
                prefills += 1
                # the admitted request holds its slot's KV from here even
                # if it finishes on its first token — count it, matching
                # the paged engine's owner-based accounting
                peak_conc = max(peak_conc, int(active_host.sum()) + 1)
                m.first_token_s = clock.now() - t0
                m.new_tokens = 1
                # the first token only crosses to the host when the
                # scheduler must inspect it (EOS check / 1-token budget)
                done0 = req.max_new_tokens == 1
                if self.eos_id is not None:
                    done0 = done0 or int(tok0[0, 0]) == self.eos_id
                caches, state = self._admit(
                    caches, state, one, tok0, slot, req.prompt_len,
                    req.max_new_tokens, not done0)
                slot_tokens[slot] += 1        # the prefill-produced token
                if done0:
                    m.finished = True
                    m.outcome = "completed"
                    m.finish_s = m.first_token_s
                    m.tokens = np.asarray([int(tok0[0, 0])], np.int32)
                else:
                    active_host[slot] = True
                    slot_rid[slot] = req.rid
            if not active_host.any():
                if sched.queue:    # pool idle until the next arrival
                    clock.wait_until(t0 + sched.next_arrival())
                    continue
                break
            # ---- one decode step over the whole pool
            t_step = clock.now()
            key, sub = jax.random.split(key)
            caches, state = self._pool_step(self.params, caches, state, sub)
            jax.block_until_ready(state["active"])
            clock.charge("decode")
            dur = clock.now() - t_step
            decode_steps += 1
            new_active = np.asarray(state["active"])
            ncounts = np.asarray(state["ncount"])
            for s in np.flatnonzero(active_host):
                m = metrics[slot_rid[s]]
                m.token_latencies_s.append(dur)
                m.new_tokens = int(ncounts[s])
                slot_tokens[s] += 1
                if not new_active[s]:           # EOS or budget: retire slot
                    m.finished = True
                    m.outcome = "completed"
                    m.finish_s = clock.now() - t0
                    m.tokens = np.asarray(state["tokbuf"][s, :m.new_tokens])
                    slot_rid[s] = None
            active_host = new_active.copy()
        return ServeReport(metrics=[metrics[r.rid] for r in (*reqs,
                                                             *rejected)],
                           scheduler=self.scheduler, slots=B,
                           makespan_s=clock.now() - t0,
                           decode_steps=decode_steps, prefills=prefills,
                           slot_tokens=slot_tokens,
                           peak_concurrency=peak_conc)


SCHEDULERS = {"static": StaticEngine, "continuous": ContinuousEngine}


def make_engine(scheduler: str, prefill_fn, decode_fn, params, cache_init,
                **kw) -> _EngineBase:
    if scheduler not in SCHEDULERS:
        # the paged + disaggregated engines register themselves on import
        # (kept out of this module to avoid circular imports)
        import repro.serving.disagg  # noqa: F401
        import repro.serving.paged  # noqa: F401
    try:
        cls = SCHEDULERS[scheduler]
    except KeyError:
        raise ValueError(f"unknown scheduler {scheduler!r}; "
                         f"expected one of {sorted(SCHEDULERS)}") from None
    return cls(prefill_fn, decode_fn, params, cache_init, **kw)
