"""Paged KV-cache memory management: a global pool of fixed-size pages.

The monolithic engines reserve one contiguous ``cache_span``-sized KV
region per slot, so KV memory is ``slots x cache_span`` tokens no matter
how long the admitted requests actually are. This module is the
vLLM-style alternative: the KV cache is a pool of ``num_pages`` pages of
``page_size`` tokens each, every request owns a *block table* (logical
page index -> physical page id), and admission is gated on free pages
rather than free slots' worth of span.

Only host-side bookkeeping lives here — the device-side pool tensors and
the block-table-driven attention are in :mod:`repro.models.transformer`
and :mod:`repro.kernels.paged_attention`. The allocator is the source of
truth for the paper-facing memory metrics the benchmarks record:

* **occupancy**    — allocated pages / usable pages (Eq.-1-style
  allocation ratio applied to KV memory);
* **fragmentation** — 1 - live tokens / (allocated pages x page_size):
  the *internal* fragmentation of partially-filled last pages (paging's
  only waste; the monolithic layout instead wastes the whole unused tail
  of every span).

Page 0 is reserved as the *null page*: retired decode slots and padded
block-table entries point at it, so masked lanes always gather valid
memory and a freed page can be handed to a new request without ever
being written through a stale table.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

NULL_PAGE = 0


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages required to hold ``tokens`` KV entries (ceil division)."""
    if tokens <= 0:
        return 0
    return -(-tokens // page_size)


@dataclass
class PageAllocator:
    """Free-list allocator over a fixed pool of KV pages.

    ``num_pages`` counts the whole device pool *including* the reserved
    null page, so "equal memory budget" comparisons against a monolithic
    engine can equate ``num_pages * page_size`` with ``slots x span``
    directly. The free list is LIFO: the most recently retired request's
    pages are re-issued first (warm-cache reuse, and what the free-list
    reuse test pins down).
    """

    num_pages: int
    page_size: int
    reserved: int = 1               # page ids [0, reserved) never issued

    def __post_init__(self) -> None:
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages <= self.reserved:
            raise ValueError(
                f"num_pages {self.num_pages} leaves no usable pages after "
                f"reserving {self.reserved}")
        self._free: List[int] = list(
            range(self.num_pages - 1, self.reserved - 1, -1))
        self._owned: Dict[int, List[int]] = {}      # owner -> page ids
        self.high_water = 0                         # peak pages in use
        self.failed_allocs = 0

    # ------------------------------------------------------------ queries
    @property
    def usable_pages(self) -> int:
        return self.num_pages - self.reserved

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.usable_pages - len(self._free)

    @property
    def num_owners(self) -> int:
        return len(self._owned)

    def pages_needed(self, tokens: int) -> int:
        return pages_needed(tokens, self.page_size)

    def can_fit(self, tokens: int) -> bool:
        return self.pages_needed(tokens) <= self.num_free

    @property
    def occupancy(self) -> float:
        """Allocated fraction of the usable pool."""
        return self.num_used / max(self.usable_pages, 1)

    def fragmentation(self, live_tokens: int) -> float:
        """Internal fragmentation: allocated-but-unfilled token slots as a
        fraction of allocated capacity (0 when nothing is allocated)."""
        cap = self.num_used * self.page_size
        if cap <= 0:
            return 0.0
        return max(0.0, 1.0 - live_tokens / cap)

    # -------------------------------------------------------- allocation
    def allocate(self, owner: int, tokens: int) -> List[int]:
        """Reserve pages for ``tokens`` KV entries under ``owner`` (a
        request id). Raises MemoryError when the pool cannot satisfy the
        request — callers gate admission on :meth:`can_fit` first."""
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds pages")
        n = self.pages_needed(tokens)
        if n > len(self._free):
            self.failed_allocs += 1
            raise MemoryError(
                f"owner {owner}: need {n} pages, only {len(self._free)} "
                f"of {self.usable_pages} free")
        pages = [self._free.pop() for _ in range(n)]
        self._owned[owner] = pages
        self.high_water = max(self.high_water, self.num_used)
        return list(pages)

    def free(self, owner: int) -> List[int]:
        """Return ``owner``'s pages to the free list (retirement)."""
        try:
            pages = self._owned.pop(owner)
        except KeyError:
            raise ValueError(f"owner {owner} holds no pages "
                             "(double free?)") from None
        self._free.extend(pages)
        return pages

    def owned(self, owner: int) -> List[int]:
        return list(self._owned.get(owner, ()))

    def check(self) -> None:
        """Invariant check (tests): every usable page is free or owned by
        exactly one owner; the null page is never issued."""
        held = [p for pages in self._owned.values() for p in pages]
        all_pages = sorted(self._free + held)
        assert all_pages == list(range(self.reserved, self.num_pages)), \
            "page leak or duplicate issue"
        assert NULL_PAGE not in held, "null page was issued"


@dataclass
class PoolStats:
    """Per-decode-step samples of the allocator state, aggregated for
    :class:`~repro.serving.request.ServeReport`."""

    occupancy: List[float] = field(default_factory=list)
    fragmentation: List[float] = field(default_factory=list)

    def sample(self, alloc: PageAllocator, live_tokens: int) -> None:
        self.occupancy.append(alloc.occupancy)
        self.fragmentation.append(alloc.fragmentation(live_tokens))

    @staticmethod
    def _mean(xs: Sequence[float]) -> float:
        return float(sum(xs) / len(xs)) if xs else 0.0

    @property
    def occupancy_mean(self) -> float:
        return self._mean(self.occupancy)

    @property
    def occupancy_peak(self) -> float:
        return float(max(self.occupancy, default=0.0))

    @property
    def fragmentation_mean(self) -> float:
        return self._mean(self.fragmentation)
