"""Paged KV-cache memory management: a global pool of fixed-size pages.

The monolithic engines reserve one contiguous ``cache_span``-sized KV
region per slot, so KV memory is ``slots x cache_span`` tokens no matter
how long the admitted requests actually are. This module is the
vLLM-style alternative: the KV cache is a pool of ``num_pages`` pages of
``page_size`` tokens each, every request owns a *block table* (logical
page index -> physical page id), and admission is gated on free pages
rather than free slots' worth of span.

Pages are **reference counted**: the prefix cache
(:mod:`repro.serving.prefix`) lets several requests attach the same
physical page read-only (a shared system prompt's KV is stored once and
appears in N block tables), and lets retired requests' prefix pages stay
resident until evicted. ``allocate(owner, tokens, shared=...)`` attaches
already-issued pages at +1 refcount alongside freshly issued ones;
``share``/``release`` adjust refcounts without an owner (the cache's own
holds); ``free(owner)`` decrements and only returns pages whose refcount
reaches zero to the free list.

Only host-side bookkeeping lives here — the device-side pool tensors and
the block-table-driven attention are in :mod:`repro.models.transformer`
and :mod:`repro.kernels.paged_attention`. The allocator is the source of
truth for the paper-facing memory metrics the benchmarks record:

* **occupancy**    — allocated pages / usable pages (Eq.-1-style
  allocation ratio applied to KV memory);
* **fragmentation** — 1 - live tokens / (allocated pages x page_size):
  the *internal* fragmentation of partially-filled last pages (paging's
  only waste; the monolithic layout instead wastes the whole unused tail
  of every span);
* **shared surplus** — logical block-table entries minus distinct
  physical pages under owners: how many pages prefix sharing turned
  from physical into merely logical (the concurrency multiplier).

Page 0 is reserved as the *null page*: retired decode slots and padded
block-table entries point at it, so masked lanes always gather valid
memory and a freed page can be handed to a new request without ever
being written through a stale table.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Sequence

NULL_PAGE = 0


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages required to hold ``tokens`` KV entries (ceil division)."""
    if tokens <= 0:
        return 0
    return -(-tokens // page_size)


class PoolInvariantError(AssertionError):
    """Raised by :meth:`PageAllocator.check` on a broken pool invariant.

    An ``AssertionError`` subclass so existing ``pytest.raises`` /
    CI expectations keep matching, but raised explicitly — invariant
    checking must NOT silently no-op under ``python -O`` the way bare
    ``assert`` statements do."""


@dataclass
class PageAllocator:
    """Refcounting free-list allocator over a fixed pool of KV pages.

    ``num_pages`` counts the whole device pool *including* the reserved
    null page, so "equal memory budget" comparisons against a monolithic
    engine can equate ``num_pages * page_size`` with ``slots x span``
    directly. The free list is LIFO: the most recently retired request's
    pages are re-issued first (warm-cache reuse, and what the free-list
    reuse test pins down).
    """

    num_pages: int
    page_size: int
    reserved: int = 1               # page ids [0, reserved) never issued

    def __post_init__(self) -> None:
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages <= self.reserved:
            raise ValueError(
                f"num_pages {self.num_pages} leaves no usable pages after "
                f"reserving {self.reserved}")
        self._free: List[int] = list(
            range(self.num_pages - 1, self.reserved - 1, -1))
        # owner -> page ids; owners are any hashable key — plain rids for
        # decode-side holds, ("prefill", rid) tuples for the prefill
        # role's pre-handoff reservations (see repro.serving.roles)
        self._owned: Dict[Hashable, List[int]] = {}
        self._refs: Dict[int, int] = {}             # page id -> refcount
        self.high_water = 0                         # peak pages in use
        self.failed_allocs = 0
        # pages temporarily withheld from allocation (fault injection /
        # external memory pressure): num_free shrinks but the pages stay
        # on the free list, so check() invariants are untouched
        self.pressure = 0
        # REPRO_DEBUG_POOL=1: re-verify the pool invariants on every
        # mutation, so a corruption raises at the faulting call site
        # instead of at the next explicit check() (env-gated — the
        # full-pool scan is O(pages) and would tax the decode hot path)
        self._audit = os.environ.get("REPRO_DEBUG_POOL") == "1"

    # ------------------------------------------------------------ queries
    @property
    def usable_pages(self) -> int:
        return self.num_pages - self.reserved

    @property
    def num_free(self) -> int:
        """Pages available to allocate — the free list minus any
        withheld under :attr:`pressure`."""
        return max(0, len(self._free) - self.pressure)

    @property
    def num_used(self) -> int:
        return self.usable_pages - len(self._free)

    @property
    def num_owners(self) -> int:
        return len(self._owned)

    @property
    def owned_pages(self) -> int:
        """Distinct pages still held by owners — nonzero after a drained
        run means the engine leaked (the chaos checks' leak metric;
        ownerless prefix-cache holds are intentionally not counted)."""
        return len({p for pages in self._owned.values() for p in pages})

    def pages_needed(self, tokens: int) -> int:
        return pages_needed(tokens, self.page_size)

    def can_fit(self, tokens: int, shared_pages: int = 0) -> bool:
        """Whether ``tokens`` KV entries fit, given that the first
        ``shared_pages`` pages are attached from the prefix cache rather
        than drawn from the free list."""
        return self.pages_needed(tokens) - shared_pages <= self.num_free

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    @property
    def occupancy(self) -> float:
        """Allocated fraction of the usable pool."""
        return self.num_used / max(self.usable_pages, 1)

    def fragmentation(self, live_tokens: int) -> float:
        """Internal fragmentation: allocated-but-unfilled token slots as a
        fraction of allocated capacity (0 when nothing is allocated;
        clamped at 0 when sharing makes logical tokens exceed physical
        capacity)."""
        cap = self.num_used * self.page_size
        if cap <= 0:
            return 0.0
        return max(0.0, 1.0 - live_tokens / cap)

    def shared_page_surplus(self) -> int:
        """Logical block-table entries minus distinct physical pages under
        owners — how many pages sharing deduplicated. 0 without sharing."""
        logical = 0
        distinct: set = set()
        for pages in self._owned.values():
            logical += len(pages)
            distinct.update(pages)
        return logical - len(distinct)

    # -------------------------------------------------------- allocation
    def allocate(self, owner: Hashable, tokens: int,
                 shared: Sequence[int] = ()) -> List[int]:
        """Reserve pages for ``tokens`` KV entries under ``owner`` (a
        request id). ``shared`` pages (a page-aligned cached prefix, in
        logical order) are attached at +1 refcount instead of being drawn
        from the free list; fresh pages fill the remainder, so the
        returned block table is ``list(shared) + fresh``. Raises
        MemoryError when the free list cannot cover the fresh remainder —
        callers gate admission on :meth:`can_fit` first."""
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds pages")
        shared = list(shared)
        total = self.pages_needed(tokens)
        if len(shared) > total:
            raise ValueError(
                f"owner {owner}: {len(shared)} shared pages exceed the "
                f"{total} pages needed for {tokens} tokens")
        for p in shared:
            if p not in self._refs:
                raise ValueError(
                    f"owner {owner}: shared page {p} is not issued")
        fresh_n = total - len(shared)
        if fresh_n > self.num_free:
            self.failed_allocs += 1
            raise MemoryError(
                f"owner {owner}: need {fresh_n} fresh pages "
                f"(+{len(shared)} shared), only {self.num_free} "
                f"of {self.usable_pages} free")
        fresh = [self._free.pop() for _ in range(fresh_n)]
        for p in shared:
            self._refs[p] += 1
        for p in fresh:
            self._refs[p] = 1
        self._owned[owner] = shared + fresh
        self.high_water = max(self.high_water, self.num_used)
        if self._audit:
            self.check()
        return list(shared + fresh)

    def share(self, pages: Sequence[int]) -> None:
        """Take an ownerless +1 reference on already-issued pages (the
        prefix cache's hold, which keeps indexed pages resident after
        their writer retires)."""
        pages = list(pages)
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"cannot share page {p}: not issued")
        for p in pages:
            self._refs[p] += 1
        if self._audit:
            self.check()

    def release(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; pages reaching refcount 0 return
        to the free list. Returns the pages actually freed."""
        freed: List[int] = []
        for p in pages:
            try:
                c = self._refs[p]
            except KeyError:
                raise ValueError(f"cannot release page {p}: not issued"
                                 ) from None
            if c <= 1:
                del self._refs[p]
                self._free.append(p)
                freed.append(p)
            else:
                self._refs[p] = c - 1
        if self._audit:
            self.check()     # free()/retire routes through here too
        return freed

    def free(self, owner: Hashable) -> List[int]:
        """Retire ``owner``: drop its reference on every page it holds.
        Only pages whose refcount reaches zero go back to the free list
        (shared prefix pages survive while the cache or another request
        still references them). Returns the pages actually freed."""
        try:
            pages = self._owned.pop(owner)
        except KeyError:
            raise ValueError(f"owner {owner} holds no pages "
                             "(double free?)") from None
        return self.release(pages)

    def owned(self, owner: Hashable) -> List[int]:
        return list(self._owned.get(owner, ()))

    def holds(self, owner: Hashable) -> bool:
        """Whether ``owner`` currently holds any pages — the dual-role
        ownership probe the P/D handoff invariants assert on."""
        return owner in self._owned

    def check(self) -> None:
        """Invariant check: every usable page is either on the free list
        or issued with refcount >= 1 (never both), refcounts cover every
        owner holding the page, and the null page is never issued.

        Raises :class:`PoolInvariantError` explicitly — these checks
        stay live under ``python -O`` (bare ``assert`` would vanish)."""
        def fail(msg: str) -> None:
            raise PoolInvariantError(msg)

        if len(set(self._free)) != len(self._free):
            fail(f"duplicate pages on the free list: {sorted(self._free)}")
        issued = set(self._refs)
        if issued & set(self._free):
            fail(f"pages both issued and free: "
                 f"{sorted(issued & set(self._free))}")
        universe = set(range(self.reserved, self.num_pages))
        if issued | set(self._free) != universe:
            fail("page leak: issued+free != usable range "
                 f"(missing {sorted(universe - issued - set(self._free))}, "
                 f"extra {sorted((issued | set(self._free)) - universe)})")
        holders: Dict[int, int] = {}
        for owner, pages in self._owned.items():
            if len(set(pages)) != len(pages):
                fail(f"owner {owner} holds duplicate pages: {pages}")
            for p in pages:
                holders[p] = holders.get(p, 0) + 1
        for p, c in self._refs.items():
            if c < 1:
                fail(f"issued page {p} has refcount {c} < 1")
            if c < holders.get(p, 0):
                fail(f"page {p}: refcount {c} < {holders[p]} owners "
                     "holding it")
        for p in holders:
            if p not in issued:
                fail(f"owned page {p} missing from the refcount table")
        if NULL_PAGE in issued or NULL_PAGE in self._free:
            fail("null page was issued")


@dataclass
class PoolStats:
    """Per-decode-step samples of the allocator state, aggregated for
    :class:`~repro.serving.request.ServeReport`."""

    occupancy: List[float] = field(default_factory=list)
    fragmentation: List[float] = field(default_factory=list)
    pages_shared: List[int] = field(default_factory=list)

    def sample(self, alloc: PageAllocator, live_tokens: int) -> None:
        self.occupancy.append(alloc.occupancy)
        self.fragmentation.append(alloc.fragmentation(live_tokens))
        self.pages_shared.append(alloc.shared_page_surplus())

    @staticmethod
    def _mean(xs: Sequence[float]) -> float:
        return float(sum(xs) / len(xs)) if xs else 0.0

    @property
    def occupancy_mean(self) -> float:
        return self._mean(self.occupancy)

    @property
    def occupancy_peak(self) -> float:
        return float(max(self.occupancy, default=0.0))

    @property
    def fragmentation_mean(self) -> float:
        return self._mean(self.fragmentation)

    @property
    def fragmentation_peak(self) -> float:
        return float(max(self.fragmentation, default=0.0))

    @property
    def pages_shared_peak(self) -> int:
        return int(max(self.pages_shared, default=0))
