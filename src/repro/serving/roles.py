"""Composable serving roles: scheduler, prefill worker, decode worker,
and the page-ownership handoff between them (DESIGN.md §5).

The engines in :mod:`repro.serving.engine` / :mod:`repro.serving.paged`
used to be monolithic ``run()`` loops; this module is the role split
those loops now compose:

* :class:`Scheduler`     — admission policy: arrival-aware priority
  queueing, deadline reaping (queued and in-flight), preemption victim
  choice, and the deadline-truncation rule every engine credits tokens
  by. One scheduler per run; the engines own the device state, the
  scheduler owns *which request runs next and for how long*.
* :class:`PrefillWorker` — owns prefill compute: page reservation under
  the *prefill* role key (prefix-cache attach included) and the chunked
  prefill dispatches. The monolithic engines use its batch flavor.
* :class:`DecodeWorker`  — owns a pool of decode lanes: the fused
  pool-step device state, lane bookkeeping (which rid sits where), a
  virtual timeline for disaggregated scheduling, and decode-stall
  samples (gaps between consecutive steps while lanes stayed active —
  the prefill-interference metric).
* :class:`PageHandoff`   — the ownership transfer protocol: prefill
  reserves pages under ``("prefill", rid)``, decode takes them over
  under plain ``rid``. The transfer re-attaches every page at +1
  refcount before the prefill hold is released through the engine's
  ``_release_pages`` seam, so refcounts are conserved, the pool is
  never transiently unowned, and the RS102 free choke point (and the
  chaos-parity leak self-test behind it) still sees every release.

One *shared* page pool backs both roles — a pool-per-role design would
need a cross-pool KV copy per handoff; with shared pages the handoff is
pure bookkeeping (refcount +1/-1) and costs zero KV traffic.

The interleaved engines compose these roles in one loop (behavior
unchanged — parity-gated); :class:`repro.serving.disagg.DisaggregatedEngine`
runs separate prefill/decode worker pools over the same roles.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.pages import PageAllocator, PoolInvariantError
from repro.serving.request import Request


class RequestQueue:
    """Arrival-aware priority queue the continuous/paged schedulers admit
    from. Among *arrived* requests the highest ``priority`` wins; ties
    break by earliest arrival then lowest rid — so an all-default-priority
    workload admits in exactly the old FIFO order. Requeues (preemption,
    fault retry) :meth:`push` back with a fresh arrival time."""

    def __init__(self, requests: Sequence[Request] = ()) -> None:
        self._items: List[Request] = list(requests)

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def push(self, req: Request) -> None:
        self._items.append(req)

    def remove(self, req: Request) -> None:
        self._items.remove(req)

    def next_arrival(self) -> float:
        return min(r.arrival_s for r in self._items)

    def peek_best(self, now_rel: float) -> Optional[Request]:
        """Highest-priority request that has arrived by ``now_rel``."""
        ready = [r for r in self._items if r.arrival_s <= now_rel]
        if not ready:
            return None
        return min(ready, key=lambda r: (-r.priority, r.arrival_s, r.rid))

    def pop_expired(self, now_rel: float) -> List[Request]:
        """Remove and return queued requests already past their deadline —
        admitting them would burn prefill on work that cannot meet its
        SLO, so the reaper retires them straight from the queue."""
        dead = [r for r in self._items
                if r.deadline_abs_s is not None and now_rel > r.deadline_abs_s]
        for r in dead:
            self._items.remove(r)
        return dead


# ------------------------------------------------------------------ handoff
def prefill_owner(rid: int) -> Tuple[str, int]:
    """Allocator owner key for pages held by the *prefill* role. The
    decode role holds under the plain ``rid`` — every pre-existing
    consumer of decode-side ownership (``alloc.owned(rid)``,
    ``_release_pages(alloc, rid)``, leak accounting) keeps working
    unchanged."""
    return ("prefill", rid)


class PageHandoff:
    """Transfer a request's pages from prefill to decode ownership.

    ``release_fn`` is the engine's bound ``_release_pages`` — the RS102
    free choke point — so every refcount drop the handoff performs goes
    through the same seam the chaos-parity leak self-test no-ops.

    :meth:`transfer` is refcount-conserving by construction: the decode
    role attaches every page at +1 *before* the prefill hold drops its
    +1, so a shared prefix page's cache reference is never the last one
    standing mid-handoff and a crash between the two halves can only
    over-hold (leak-detected), never free a live page.
    """

    def __init__(self, alloc: PageAllocator, release_fn,
                 page_size: int) -> None:
        self.alloc = alloc
        self._release = release_fn
        self.page_size = int(page_size)
        self.handoffs = 0
        self.latencies_s: List[float] = []

    def roles_of(self, rid: int) -> Tuple[bool, bool]:
        """(prefill holds, decode holds) — the dual-ownership probe the
        handoff invariant tests assert on."""
        return (self.alloc.holds(prefill_owner(rid)), self.alloc.holds(rid))

    def transfer(self, rid: int) -> List[int]:
        """Move ``rid``'s pages from the prefill hold to the decode hold.
        Raises :class:`PoolInvariantError` on a double handoff (decode
        already holds) or a handoff without a reservation (prefill holds
        nothing). Returns the transferred block table."""
        pkey = prefill_owner(rid)
        if self.alloc.holds(rid):
            raise PoolInvariantError(
                f"handoff of rid {rid}: decode role already holds pages "
                "(double handoff?)")
        if not self.alloc.holds(pkey):
            raise PoolInvariantError(
                f"handoff of rid {rid}: prefill role holds no pages "
                "(handoff without reservation?)")
        pages = self.alloc.owned(pkey)
        # attach decode-side first (+1 per page), then drop the prefill
        # hold through the engine's release seam (-1 per page): net-zero
        # refcounts, and len(pages) * page_size tokens need exactly
        # len(pages) pages, so no fresh allocation can occur here
        self.alloc.allocate(rid, len(pages) * self.page_size, shared=pages)
        self._release(self.alloc, pkey)
        self.handoffs += 1
        return pages

    def abort(self, rid: int) -> None:
        """Release the prefill-role hold without transferring — the
        containment path for a failed prefill (the request's pages go
        straight back) and the completed-at-prefill path (a 1-token
        budget or first-token EOS never reaches a decode lane)."""
        pkey = prefill_owner(rid)
        if not self.alloc.holds(pkey):
            raise PoolInvariantError(
                f"abort of rid {rid}: prefill role holds no pages")
        self._release(self.alloc, pkey)


# ---------------------------------------------------------------- scheduler
class Scheduler:
    """The admission/reaping/preemption policy extracted from the engine
    loops — behavior-identical, now one seam all engines route through
    (the RS103 lint accepts ``run`` bodies that call ``.validate``).

    Owns the queue and the rid -> current-Request map (a requeue swaps in
    the extended-prompt incarnation); the engines keep the device state
    and call back in for every policy decision.
    """

    def __init__(self, engine) -> None:
        self.engine = engine
        self.queue = RequestQueue()
        self.req_of: Dict[int, Request] = {}
        self.has_deadlines = False

    def validate(self, requests: Sequence[Request]
                 ) -> Tuple[List[Request], List[Request]]:
        """Admission-validate ``requests`` through the engine's
        ``admission_error`` hook (via ``_validate``) and seed the queue
        with the servable ones. Returns (servable, rejected)."""
        ok, rejected = self.engine._validate(requests)
        self.queue = RequestQueue(ok)
        self.req_of = {r.rid: r for r in ok}
        self.has_deadlines = any(r.deadline_s is not None for r in ok)
        return ok, rejected

    # ------------------------------------------------------------- queue
    def peek_best(self, now_rel: float) -> Optional[Request]:
        return self.queue.peek_best(now_rel)

    def take(self, req: Request) -> None:
        self.queue.remove(req)

    def requeue(self, req: Request) -> None:
        """Re-admit a preempted/faulted request (its prompt now carries
        any generated progress); it becomes the rid's current
        incarnation."""
        self.req_of[req.rid] = req
        self.queue.push(req)

    def next_arrival(self) -> float:
        return self.queue.next_arrival()

    def queue_depth(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------------ reaping
    def reap_queued(self, now_rel: float) -> List[Request]:
        """Queued requests past their deadline (removed from the queue)."""
        if not self.has_deadlines:
            return []
        return self.queue.pop_expired(now_rel)

    def doomed_slots(self, now_rel: float, slot_rid: Sequence[Optional[int]],
                     active_host: np.ndarray) -> List[int]:
        """Active lanes whose request is past its deadline."""
        if not self.has_deadlines:
            return []
        return [int(s) for s in np.flatnonzero(active_host)
                if (d := self.req_of[slot_rid[s]].deadline_abs_s)
                is not None and now_rel > d]

    # --------------------------------------------------------- preemption
    def pick_victim(self, for_req: Request,
                    slot_rid: Sequence[Optional[int]],
                    active_host: np.ndarray,
                    admit_seq: Sequence[int]) -> Optional[int]:
        """Lane to evict for ``for_req``: the lowest-priority active
        request (ties: latest admitted — least sunk prefill), and only
        if it is *strictly* lower priority. ``None`` = don't preempt."""
        cands = [int(s) for s in np.flatnonzero(active_host)]
        if not cands:
            return None
        victim = min(cands, key=lambda s: (
            self.req_of[slot_rid[s]].priority, -admit_seq[s]))
        if self.req_of[slot_rid[victim]].priority >= for_req.priority:
            return None
        return victim

    # ---------------------------------------------------------- deadlines
    @staticmethod
    def deadline_truncate(t_first: float, step_times: Sequence[float],
                          deadline: Optional[float]
                          ) -> Tuple[int, float, bool]:
        """Credit tokens only up to the deadline — the uniform rule the
        per-step reapers already implement and the static engine now
        shares (it used to credit every generated token post hoc, so an
        expired request over-counted).

        ``t_first`` is when token 0 (the prefill token) was ready and
        ``step_times`` the durations of the decode steps that produced
        tokens 1..N. Returns ``(n_tokens, finish_s, timed_out)``; at
        least the prefill token is always counted (matching the per-step
        engines, which count the admission token before their reaper can
        fire)."""
        if deadline is None:
            return len(step_times) + 1, t_first + float(sum(step_times)), False
        n, t = 1, t_first
        for dt in step_times:
            if t + dt > deadline:
                break
            t += dt
            n += 1
        timed_out = (t_first > deadline) or (n < len(step_times) + 1)
        return n, t, timed_out


# ------------------------------------------------------------------ workers
class PrefillWorker:
    """The prefill role: page reservation (under the prefill owner key)
    and the chunked prefill dispatches, with a virtual timeline for
    disaggregated scheduling. Thin by design — compute stays on the
    engine's jitted entry points; the worker owns *whose clock the work
    bills to* and the role-local counters."""

    def __init__(self, engine, wid: int = 0) -> None:
        self.engine = engine
        self.wid = wid
        self.t = 0.0                 # virtual timeline (disaggregated)
        self.busy_s = 0.0
        self.dispatches = 0

    # ---- paged flavor (block-table chunked prefill)
    def reserve(self, req: Request, alloc: PageAllocator, radix):
        """Reserve ``req``'s pages under the *prefill* role key (prefix
        attach included); ``None`` when the pool cannot cover it yet."""
        return self.engine._reserve_pages(req, alloc, radix,
                                          owner=prefill_owner(req.rid))

    def prefill(self, prompt: np.ndarray, btab_dev, clock, *,
                start: int = 0):
        """Chunk-prefill ``prompt[start:]`` into the reserved pages;
        returns (last chunk's logits, chunks dispatched)."""
        logits, chunks = self.engine._chunked_prefill(prompt, btab_dev,
                                                      clock, start=start)
        self.dispatches += chunks
        return logits, chunks

    # ---- monolithic flavor (whole-batch prefill, static/continuous)
    def prefill_batch(self, prompts: np.ndarray, key):
        """One-shot batch prefill; returns (tok0 (b, 1), caches)."""
        self.dispatches += 1
        return self.engine._prefill_one_batch(prompts, key)


class DecodeWorker:
    """The decode role over a pool of ``lanes`` decode lanes: the fused
    pool-step device state (block-table flavored when ``npag_max`` is
    given), per-lane bookkeeping, a virtual timeline, and decode-stall
    samples.

    A *stall* is the gap between the end of one decode step and the
    start of the next while the worker still had active lanes — exactly
    the time interleaved engines spend on admission prefills between
    decode steps, the interference P/D disaggregation removes. The
    engine calls :meth:`note_step_start` / :meth:`note_step_end` with
    run-relative times (clock-based for interleaved, the worker
    timeline for disaggregated)."""

    def __init__(self, engine, lanes: int, wid: int = 0,
                 npag_max: Optional[int] = None) -> None:
        self.engine = engine
        self.wid = wid
        self.lanes = lanes
        T = engine.cache_span
        state = {
            "tok": jnp.zeros((lanes, 1), jnp.int32),
            "pos": jnp.zeros((lanes,), jnp.int32),
            "active": jnp.zeros((lanes,), bool),
            "ncount": jnp.zeros((lanes,), jnp.int32),
            "budget": jnp.ones((lanes,), jnp.int32),
            "tokbuf": jnp.zeros((lanes, T), jnp.int32),
        }
        if npag_max is not None:
            state["btab"] = jnp.zeros((lanes, npag_max), jnp.int32)
        self.state = state
        self.slot_rid: List[Optional[int]] = [None] * lanes
        self.admit_seq = [0] * lanes     # admission order, victim choice
        self.active_host = np.zeros(lanes, bool)
        self.slot_tokens = np.zeros(lanes, np.int64)
        self.t = 0.0                 # virtual timeline (disaggregated)
        self.busy_s = 0.0
        self.steps = 0
        self.stalls_s: List[float] = []
        self._prev_end = 0.0
        self._carry = False

    def free_lane(self) -> Optional[int]:
        free = np.flatnonzero(~self.active_host)
        return int(free[0]) if free.size else None

    # ---- stall accounting (run-relative times supplied by the engine)
    def note_step_start(self, now_rel: float) -> None:
        if self._carry:
            self.stalls_s.append(max(0.0, now_rel - self._prev_end))

    def note_step_end(self, now_rel: float) -> None:
        self._prev_end = now_rel
        self._carry = bool(self.active_host.any())

    # ---- fused device ops (paged pool-step signatures)
    def admit(self, tok0, btab_row, slot: int, plen: int, budget: int,
              active0: bool) -> None:
        self.state = self.engine._admit(self.state, tok0, btab_row, slot,
                                        plen, budget, active0)

    def evict(self, slot: int) -> None:
        self.state = self.engine._jit_evict(self.state, slot)

    def step(self, key):
        """One fused decode dispatch over this worker's lanes: runs the
        engine's pool step on the shared caches, blocks, charges the
        clock. Returns host copies of (new_active, ncounts)."""
        eng = self.engine
        eng._caches, self.state = eng._pool_step(eng.params, eng._caches,
                                                 self.state, key)
        jax.block_until_ready(self.state["active"])
        eng.clock.charge("decode")
        self.steps += 1
        return (np.asarray(self.state["active"]),
                np.asarray(self.state["ncount"]))


__all__ = [
    "DecodeWorker",
    "PageHandoff",
    "PrefillWorker",
    "RequestQueue",
    "Scheduler",
    "prefill_owner",
]
