from repro.core.hlo_analysis import CostReport, HloAnalyzer, analyze_hlo, \
    top_consumers
from repro.core.metrics import (allocation_ratio, arithmetic_intensity,
                                load_imbalance, weighted_allocation,
                                weighted_load_imbalance)
from repro.core.mesh_advisor import MeshAdvice, advise, best_mesh
from repro.core.profiler import Tier1Report, profile
from repro.core.roofline import RooflineReport, roofline

__all__ = [
    "CostReport", "HloAnalyzer", "MeshAdvice", "RooflineReport", "Tier1Report", "advise", "best_mesh",
    "allocation_ratio", "analyze_hlo", "arithmetic_intensity",
    "load_imbalance", "profile", "roofline", "top_consumers",
    "weighted_allocation", "weighted_load_imbalance",
]
