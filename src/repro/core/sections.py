"""Section partitioner — the paper's SambaNova O0/O1/O3 compile-mode
analysis (Fig. 4/7/8), applied to a structural op graph built from the
ModelConfig.

For each op we know its FLOPs, bytes and which mesh axes participate (from
the same sharding rules the real program uses), so every paper metric
evaluates analytically:

* O0  — one section per operator
* O1  — operator-fusion modules (attention block / mlp block / moe block)
* O3  — one section per decoder layer

Section runtime model: max(flops / (participation * peak),
bytes / (participation * hbm_bw)) — the roofline-optimistic estimate on the
chips the section actually occupies.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.core import metrics
from repro.core.roofline import HBM_BW, PEAK_FLOPS_BF16


@dataclass
class OpNode:
    name: str
    module: str              # fusion group for O1 (attn | ffn | embed | head)
    layer: int               # -1 for non-layer ops
    flops: float             # global
    bytes: float             # global
    participation: float     # fraction of mesh devices doing useful work
    unit: str = "mxu"        # mxu | vpu


@dataclass
class Section:
    name: str
    ops: List[OpNode] = field(default_factory=list)

    @property
    def flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def bytes(self) -> float:
        return sum(o.bytes for o in self.ops)

    @property
    def participation(self) -> float:
        if not self.ops:
            return 0.0
        t = sum(o.flops + o.bytes for o in self.ops)
        if not t:
            return max(o.participation for o in self.ops)
        return sum(o.participation * (o.flops + o.bytes) for o in self.ops) / t

    def runtime(self, n_devices: int) -> float:
        p = max(self.participation, 1e-9) * n_devices
        return max(self.flops / (p * PEAK_FLOPS_BF16),
                   self.bytes / (p * HBM_BW), 1e-12)

    def throughput(self, n_devices: int) -> float:
        return 1.0 / self.runtime(n_devices)


# ------------------------------------------------------------- op graph
def build_op_graph(cfg: ModelConfig, shape: ShapeConfig,
                   mesh: MeshConfig) -> List[OpNode]:
    """Structural op graph for one training/prefill step (per step, global
    flops/bytes). Participation comes from the sharding rules: ops whose
    weights replicate over `model` (rwkv/ssd projections, non-divisible
    vocab) occupy only the data axes."""
    B, S = shape.global_batch, shape.seq_len
    T = B * S
    d, f, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    hd, nq, nkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    bf = 2.0
    fwd_bwd = 3.0 if shape.kind == "train" else 1.0
    data_frac = (min(B, mesh.data_size) / mesh.data_size
                 if mesh.data_size else 1.0)
    full = data_frac                      # sharded over data + model axes
    model_idle = data_frac / mesh.model_size  # replicated over model

    ops: List[OpNode] = []

    def add(name, module, layer, fl, by, part, unit="mxu"):
        ops.append(OpNode(name, module, layer, fl * fwd_bwd, by * fwd_bwd,
                          min(part, 1.0), unit))

    add("embed", "embed", -1, 2 * T * d, T * d * bf + cfg.vocab_size * d * bf,
        full)
    for l in range(L):
        if cfg.family == "ssm":
            hs = cfg.ssm.head_size
            add(f"l{l}.norm1", "attn", l, 5 * T * d, 2 * T * d * 4,
                model_idle, "vpu")
            add(f"l{l}.rkvgw_proj", "attn", l, 2 * T * d * d * 5,
                5 * (T * d * bf + d * d * bf), model_idle)
            add(f"l{l}.wkv", "attn", l, 4 * T * d * hs + 2 * T * d * hs,
                4 * T * d * bf, model_idle)
            add(f"l{l}.time_out", "attn", l, 2 * T * d * d,
                T * d * bf + d * d * bf, model_idle)
            add(f"l{l}.norm2", "ffn", l, 5 * T * d, 2 * T * d * 4,
                model_idle, "vpu")
            add(f"l{l}.channel_mix", "ffn", l, 2 * T * d * f * 2 + 2 * T * d * d,
                T * (d + f) * bf + (2 * d * f + d * d) * bf, full)
            continue
        # attention family
        add(f"l{l}.norm1", "attn", l, 5 * T * d, 2 * T * d * 4, full, "vpu")
        qkv_f = 2 * T * d * (nq + 2 * nkv) * hd
        add(f"l{l}.qkv_proj", "attn", l, qkv_f,
            T * d * bf + d * (nq + 2 * nkv) * hd * bf, full)
        span = min(S, cfg.window) if cfg.attention_kind == "sliding" else S
        attn_f = 2 * 2 * B * S * span * nq * hd * (0.5 if span == S else 1.0)
        add(f"l{l}.attention", "attn", l, attn_f,
            2 * T * nq * hd * bf + 2 * B * span * nkv * hd * bf, full)
        add(f"l{l}.o_proj", "attn", l, 2 * T * nq * hd * d,
            T * d * bf * 2, full)
        if cfg.family == "hybrid":
            N = cfg.ssm.state_size
            H = d // cfg.ssm.head_size
            add(f"l{l}.ssd", "attn", l,
                2 * T * d * (2 * d + 2 * H * N) + 6 * T * H * N * cfg.ssm.head_size,
                4 * T * d * bf, model_idle)
        if cfg.encoder_layers:
            add(f"l{l}.cross_attn", "attn", l,
                2 * T * d * (nq + 2 * nkv) * hd + 4 * B * S * S * nq * hd,
                2 * T * d * bf, full)
        add(f"l{l}.norm2", "ffn", l, 5 * T * d, 2 * T * d * 4, full, "vpu")
        if cfg.moe is not None:
            e = cfg.moe
            add(f"l{l}.router", "ffn", l, 2 * T * d * e.num_experts,
                T * d * bf, model_idle)
            add(f"l{l}.dispatch", "ffn", l, T * e.top_k * 8,
                2 * T * d * bf, full, "vpu")
            mult = 3 if cfg.activation == "swiglu" else 2
            cap = T * e.top_k * e.capacity_factor
            add(f"l{l}.experts", "ffn", l, 2 * cap * d * e.expert_ff * mult,
                cap * (d + e.expert_ff) * bf
                + e.num_experts * mult * d * e.expert_ff * bf, full)
            add(f"l{l}.combine", "ffn", l, T * e.top_k * d,
                2 * T * d * bf, full, "vpu")
            if e.dense_residual_ff:
                add(f"l{l}.dense_mlp", "ffn", l,
                    2 * T * d * e.dense_residual_ff * mult,
                    T * d * bf * 2 + mult * d * e.dense_residual_ff * bf, full)
        else:
            mult = 3 if cfg.activation == "swiglu" else 2
            add(f"l{l}.mlp", "ffn", l, 2 * T * d * f * mult,
                T * (2 * d + f) * bf + mult * d * f * bf, full)
    vpad = cfg.vocab_size
    add("lm_head", "head", -1, 2 * T * d * vpad,
        T * d * bf + d * vpad * bf + T * vpad * 4, full)
    return ops


# ------------------------------------------------------------ partitioning
def partition(ops: List[OpNode], mode: str) -> List[Section]:
    if mode == "O0":
        return [Section(o.name, [o]) for o in ops]
    if mode == "O1":
        groups: dict = {}
        for o in ops:
            key = (o.module if o.layer < 0 else f"{o.module}")
            groups.setdefault(key, Section(key)).ops.append(o)
        return list(groups.values())
    if mode == "O3":
        groups = {}
        for o in ops:
            key = "pre_post" if o.layer < 0 else f"layer{o.layer}"
            groups.setdefault(key, Section(key)).ops.append(o)
        return list(groups.values())
    raise ValueError(mode)


# ------------------------------------------------------------- reporting
@dataclass
class SectionReport:
    mode: str
    n_sections: int
    allocation: float         # Eq. 2
    load_imbalance: float     # Eq. 3 over sections (+Eq. 4 weighting)
    total_runtime: float

    def to_dict(self):
        return self.__dict__.copy()


def analyze(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
            mode: str) -> SectionReport:
    ops = build_op_graph(cfg, shape, mesh)
    secs = partition(ops, mode)
    n = mesh.num_devices
    runtimes = [s.runtime(n) for s in secs]
    alloc = metrics.weighted_allocation(
        [(rt, s.participation, 1.0) for rt, s in zip(runtimes, secs)])
    li = metrics.load_imbalance(
        [s.participation * n for s in secs],
        [s.throughput(n) for s in secs])
    return SectionReport(mode=mode, n_sections=len(secs), allocation=alloc,
                         load_imbalance=li,
                         total_runtime=float(sum(runtimes)))
