"""Tier-1 orchestrator: one call -> the paper's full intra-chip profile for
a (model x shape x mesh) cell, combining

* compiled-HLO metrics (when a dry-run artifact/HLO is available): FLOPs,
  bytes, collectives, MXU-busy fraction;
* structural metrics (always available): O0/O1/O3 section allocation &
  load-imbalance (Eq. 2-4), arithmetic intensity (Eq. 5), roofline terms.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.bench.record import BenchRecord
from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.core import metrics, sections
from repro.core.hlo_analysis import CostReport, analyze_hlo
from repro.core.roofline import (PEAK_FLOPS_BF16, model_flops_decode,
                                 model_flops_prefill, model_flops_train,
                                 roofline)


@dataclass
class Tier1Report:
    arch: str
    shape: str
    mesh: str
    sections: Dict[str, dict]            # O0/O1/O3 -> SectionReport dict
    arithmetic_intensity: float          # Eq. 5
    roofline: Optional[dict] = None      # from compiled HLO when available
    mxu_busy_fraction: Optional[float] = None
    extras: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "sections": self.sections,
            "arithmetic_intensity": self.arithmetic_intensity,
            "roofline": self.roofline,
            "mxu_busy_fraction": self.mxu_busy_fraction,
            **self.extras,
        }

    def to_records(self) -> List[BenchRecord]:
        """The profile as BenchRecord rows — the same interchange the
        benchmark harness emits, so Tier-1 profiles and measured sweeps
        flow through one reporting path."""
        cell = f"{self.arch}/{self.shape}"
        recs = []
        for mode, sec in self.sections.items():
            recs.append(BenchRecord(
                name=f"tier1/{cell}/{mode}", scenario="tier1/sections",
                group="tier1", arch=self.arch, shape=self.shape,
                mesh=self.mesh, knobs={"mode": mode},
                paper_ref="Table I / Fig. 6-8",
                derived={"allocation": sec["allocation"],
                         "LI": sec["load_imbalance"],
                         "n_sections": sec["n_sections"],
                         "runtime_s": sec["total_runtime"]}))
        derived: Dict[str, object] = {"AI": self.arithmetic_intensity}
        if self.roofline:
            derived.update(
                dom=self.roofline.get("dominant"),
                compute_s=self.roofline.get("compute_s"),
                memory_s=self.roofline.get("memory_s"),
                collective_s=self.roofline.get("collective_s"),
                mfu=self.roofline.get("mfu"))
        if self.mxu_busy_fraction is not None:
            derived["mxu_busy"] = self.mxu_busy_fraction
        recs.append(BenchRecord(
            name=f"tier1/{cell}/roofline", scenario="tier1/roofline",
            group="tier1", arch=self.arch, shape=self.shape, mesh=self.mesh,
            paper_ref="Fig. 10", derived=derived))
        return recs


def model_flops_for(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_act = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return model_flops_train(n_act, tokens)
    if shape.kind == "prefill":
        return model_flops_prefill(n_act, tokens)
    return model_flops_decode(n_act, shape.global_batch)


def profile(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
            hlo_text: Optional[str] = None,
            hlo_report: Optional[CostReport] = None) -> Tier1Report:
    sec = {m: sections.analyze(cfg, shape, mesh, m).to_dict()
           for m in ("O0", "O1", "O3")}
    act_bytes = metrics.activation_bytes_estimate(
        cfg.num_layers + cfg.encoder_layers, shape.global_batch,
        shape.seq_len, cfg.d_model)
    ai = metrics.arithmetic_intensity(
        cfg.active_param_count(), shape.global_batch, shape.seq_len,
        act_bytes)
    rl = None
    mxu_busy = None
    if hlo_report is None and hlo_text is not None:
        hlo_report = analyze_hlo(hlo_text)
    if hlo_report is not None:
        rlr = roofline(hlo_report, chips=mesh.num_devices,
                       model_flops=model_flops_for(cfg, shape))
        rl = rlr.to_dict()
        # MXU-busy fraction: time the matrix units have work vs roofline
        # step time — the TPU stand-in for the paper's compute-PE allocation.
        dot_time = hlo_report.dot_flops / PEAK_FLOPS_BF16
        mxu_busy = dot_time / max(rlr.step_time_s, 1e-12)
    return Tier1Report(
        arch=cfg.name, shape=shape.name,
        mesh="x".join(map(str, mesh.shape)), sections=sec,
        arithmetic_intensity=ai, roofline=rl, mxu_busy_fraction=mxu_busy)
