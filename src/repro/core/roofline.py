"""Three-term roofline model for TPU v5e (the deployment target).

    compute    = HLO_FLOPs   / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes   / (chips * 819e9  B/s HBM)
    collective = ICI_bytes   / (chips * 50e9   B/s/link)

HLO terms come from the per-device hlo_analysis report (so `chips` is
already divided out — per-device seconds ARE the roofline terms).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.hlo_analysis import CostReport

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s/link (per assignment: ~50 GB/s/link)
HBM_PER_CHIP = 16e9               # bytes


@dataclass
class RooflineReport:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float      # raw operand-bytes sum (per spec)
    collective_ici_bytes_per_device: float  # ring-model per-chip link traffic
    model_flops: float = 0.0                # 6*N*D analytic (global)
    chips: int = 1
    collective_breakdown: Dict[str, float] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs (global) — catches remat/redundancy."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else None

    @property
    def mfu(self) -> Optional[float]:
        """Model-flops utilization at the roofline-optimistic step time."""
        if not self.model_flops or not self.step_time_s:
            return None
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16
                                   * self.step_time_s)

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_ici_bytes_per_device":
                self.collective_ici_bytes_per_device,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
            "step_time_s": self.step_time_s,
            "collective_breakdown": self.collective_breakdown,
        }


def roofline(report: CostReport, *, chips: int,
             model_flops: float = 0.0) -> RooflineReport:
    ici = report.collective_ici_bytes
    return RooflineReport(
        compute_s=report.flops / PEAK_FLOPS_BF16,
        memory_s=report.bytes / HBM_BW,
        collective_s=ici / ICI_BW_PER_LINK,
        flops_per_device=report.flops,
        bytes_per_device=report.bytes,
        collective_bytes_per_device=report.collective_bytes,
        collective_ici_bytes_per_device=ici,
        model_flops=model_flops,
        chips=chips,
        collective_breakdown=report.collective_summary(),
    )


def model_flops_train(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: float, batch: float) -> float:
    return 2.0 * n_active_params * batch


def model_flops_prefill(n_active_params: float, tokens: float) -> float:
    return 2.0 * n_active_params * tokens
