"""Mesh advisor — Tier-2 deployment guidance, analytically.

§Perf showed the (data, model) split is the highest-leverage knob (qwen110:
MFU 0.198 -> 0.423 purely from the split). This module predicts that
BEFORE compiling anything: for each candidate split it estimates the three
roofline terms from the structural op graph + first-principles collective
models, checks the HBM budget, and ranks candidates by roofline step time.

Collective model per candidate (per device, per step):
* TP activation all-reduce: 2 x n_psum_sites x tokens_local x d x bytes
  x (m-1)/m       (fwd psum + bwd dgrad psum of column-parallel matmuls)
* ZeRO-3 weight all-gather: microbatches x fwd_bwd x param_bytes/model
  x (dp-1)/dp     (per-mb re-gather, sharded residue over model)
* gradient reduce-scatter: param_bytes/model x (dp-1)/dp

HBM model: params + opt state + gradient accumulator (all /devices) +
gathered-weight working set (params/(L x model) x 2 buffers) + remat stack
(L x tokens_local x d x 2B / layers_per_block).

Validated against the measured dry-run rankings in tests/test_advisor.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Mapping, Optional

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.core.roofline import HBM_BW, HBM_PER_CHIP, ICI_BW_PER_LINK, \
    PEAK_FLOPS_BF16


@dataclass
class MeshAdvice:
    mesh: MeshConfig
    microbatches: int
    compute_s: float
    memory_s: float
    collective_s: float
    hbm_gb: float
    fits: bool

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        return max(("compute", "memory", "collective"),
                   key=lambda k: getattr(self, k + "_s"))


def _opt_bytes_per_param(params: float) -> float:
    # mirrors launch/cells.py policy: int8 state + no master for >200B
    return 2 + (2.5 if params > 2e11 else 12 + 4)  # bf16 p + states (+grad)


def advise(cfg: ModelConfig, shape: ShapeConfig, n_devices: int = 256,
           *, candidates: Optional[List[int]] = None,
           seqs_per_device: int = 1,
           calibration: Optional[Mapping[str, float]] = None,
           ) -> List[MeshAdvice]:
    """Rank (data, model) splits of `n_devices` for a training shape.

    `calibration` switches the advisor from analytic peaks to rates a
    captured trace actually measured (``Trace.calibration()`` from
    ``repro.trace`` — duck-typed as a plain mapping so core never
    imports trace): `flops_per_s` / `hbm_bytes_per_s` /
    `ici_bytes_per_s` replace the hardware peaks, and
    `useful_flops_scale` inflates the analytic FLOP count by the
    measured HLO-vs-analytic ratio (remat and attention overhead the
    closed-form 6*P*tokens estimate misses). Missing keys keep their
    analytic defaults, so partial calibrations compose.
    """
    cal = dict(calibration or {})
    flops_rate = float(cal.get("flops_per_s", PEAK_FLOPS_BF16))
    hbm_rate = float(cal.get("hbm_bytes_per_s", HBM_BW))
    ici_rate = float(cal.get("ici_bytes_per_s", ICI_BW_PER_LINK))
    flops_scale = float(cal.get("useful_flops_scale", 1.0))
    P = float(cfg.param_count())
    P_act = float(cfg.active_param_count())
    tokens = shape.global_batch * shape.seq_len
    d = cfg.d_model
    L = cfg.num_layers + cfg.encoder_layers
    out: List[MeshAdvice] = []
    candidates = candidates or [1, 2, 4, 8, 16, 32, 64]
    for model in candidates:
        if n_devices % model:
            continue
        dp = n_devices // model
        if shape.global_batch % dp and dp > shape.global_batch:
            continue
        # weights must divide: approximate with d_ff/heads granularity
        if model > 1 and cfg.d_ff % model:
            continue
        mb_size = min(shape.global_batch, seqs_per_device * dp)
        n_mb = max(1, shape.global_batch // mb_size)
        tokens_local = tokens / dp
        fwd_bwd = 3.0 if shape.kind == "train" else 1.0

        compute = (fwd_bwd * 2.0 * P_act * tokens * flops_scale
                   / n_devices / flops_rate)
        # memory: weights read per mb + activations ~10 passes
        w_reads = n_mb * fwd_bwd * (P_act / model) * 2
        act_reads = fwd_bwd * 10 * tokens_local * d * 2
        memory = (w_reads + act_reads) / hbm_rate

        tp_sites = 4 if cfg.moe is None else 2   # psums/layer (fwd+bwd)
        coll = 0.0
        if model > 1:  # Megatron activation psums: per layer, per site
            coll += (tp_sites * L * tokens_local * d * 2
                     * 2 * (model - 1) / model)
        if dp > 1:  # ZeRO-3 per-microbatch weight gathers (fwd + bwd
            # recompute) + one grad reduce-scatter per step
            coll += n_mb * 2.5 * (P / model) * 2 * (dp - 1) / dp
            coll += (P / model) * 4 * (dp - 1) / dp
        collective = coll / ici_rate

        hbm = (P * _opt_bytes_per_param(P) / n_devices
               + (P / (L * model)) * 2 * 2          # gathered layer weights
               + L * (tokens_local / max(n_mb, 1)) * d * 2)
        out.append(MeshAdvice(
            mesh=MeshConfig(shape=(dp, model), axes=("data", "model")),
            microbatches=n_mb,
            compute_s=compute, memory_s=memory, collective_s=collective,
            hbm_gb=hbm / 1e9, fits=hbm <= HBM_PER_CHIP))
    out.sort(key=lambda a: (not a.fits, a.step_s))
    return out


def best_mesh(cfg: ModelConfig, shape: ShapeConfig,
              n_devices: int = 256) -> MeshAdvice:
    ranked = advise(cfg, shape, n_devices)
    fitting = [a for a in ranked if a.fits]
    return (fitting or ranked)[0]
