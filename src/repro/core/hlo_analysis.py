"""Optimized-HLO analyzer: FLOPs / bytes / collective traffic with
while-loop trip-count expansion.

Why this exists: ``compiled.cost_analysis()`` counts every ``while`` body
exactly ONCE (a scanned 80-layer model reports ~1/80th of its FLOPs) and
reports no per-collective breakdown at all. DABench-LLM's Tier-1 metrics
need both, so we parse ``compiled.as_text()`` (post-SPMD, per-device
module) directly:

* dots: 2 * prod(out_shape) * prod(lhs contracting dims)
* elementwise/reduce: prod(shape)
* fusions: flops recursively from the fused computation; bytes = operand +
  output sizes at the call site (XLA's own fusion accounting)
* while: (body + cond) * known_trip_count (from backend_config, with a
  condition-constant fallback), applied recursively for nested scans
* collectives: operand bytes, replica-group size and the enclosing loop
  multiplier per op, so the roofline collective term and the Tier-2
  communication analysis read straight off this report.

Everything is per-device (the module is the SPMD-partitioned one).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "tanh", "logistic",
    "rsqrt", "sqrt", "power", "log", "log-plus-one", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "cosine", "sine",
    "atan2", "remainder", "and", "or", "xor", "not", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "clamp", "select",
    "compare", "erf", "cbrt",
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all"}

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "while", "conditional", "call", "fusion", "custom-call"}


@dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def bytes(self) -> float:
        n = 1
        for d in self.dims:
            n *= d
        return n * _DTYPE_BYTES.get(self.dtype, 4)

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n


@dataclass
class Instr:
    name: str
    opcode: str
    shapes: List[Shape]             # output shapes (tuple flattened)
    operands: List[str]
    attrs: str

    @property
    def out_bytes(self) -> float:
        return sum(s.bytes for s in self.shapes)

    @property
    def out_elems(self) -> int:
        return sum(s.elems for s in self.shapes)


@dataclass
class CollectiveOp:
    opcode: str
    bytes: float                    # per-device operand bytes, x multiplier
    group_size: int
    count: float                    # executions (trip multiplier)
    name: str
    comp: str

    @property
    def ici_bytes(self) -> float:
        """Per-chip link traffic under a ring algorithm."""
        g = max(self.group_size, 1)
        if self.opcode == "all-reduce":
            return 2.0 * (g - 1) / g * self.bytes
        if self.opcode in ("all-gather", "reduce-scatter", "all-to-all",
                           "ragged-all-to-all"):
            return (g - 1) / g * self.bytes
        return self.bytes           # collective-permute and friends


@dataclass
class CostReport:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes: float = 0.0
    collectives: List[CollectiveOp] = field(default_factory=list)
    flops_by_op: Dict[str, float] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    warnings: List[str] = field(default_factory=list)

    @property
    def collective_bytes(self) -> float:
        return sum(c.bytes * c.count for c in self.collectives)

    @property
    def collective_ici_bytes(self) -> float:
        return sum(c.ici_bytes * c.count for c in self.collectives)

    def collective_summary(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for c in self.collectives:
            out[c.opcode] += c.bytes * c.count
        return dict(out)

    def collective_ici_summary(self) -> Dict[str, float]:
        """Per-opcode ring link traffic (ici_bytes x count) — the
        collective-lane breakdown trace capture decomposes against."""
        out: Dict[str, float] = defaultdict(float)
        for c in self.collectives:
            out[c.opcode] += c.ici_bytes * c.count
        return dict(out)


# ---------------------------------------------------------------- parsing
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _parse_shapes(text: str) -> List[Shape]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append(Shape(m.group(1), dims))
    return out


def _split_top_level(s: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def parse_module(text: str) -> Tuple[Dict[str, List[Instr]], str]:
    """Returns ({computation_name: [Instr]}, entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if stripped == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # type part: tuple or single shape
        if rest.startswith("("):
            depth = 0
            for i, ch in enumerate(rest):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            type_str, rest2 = rest[:i + 1], rest[i + 1:].strip()
        else:
            sm = _SHAPE_RE.match(rest)
            if not sm:
                continue
            type_str, rest2 = sm.group(0), rest[sm.end():].strip()
        om = re.match(r"([\w\-]+)\(", rest2)
        if not om:
            continue
        opcode = om.group(1)
        # operands: up to matching close paren
        depth, start = 0, om.end() - 1
        for i in range(start, len(rest2)):
            depth += rest2[i] == "("
            depth -= rest2[i] == ")"
            if depth == 0:
                break
        operand_str = rest2[start + 1:i]
        attrs = rest2[i + 1:]
        operands = []
        for o in _split_top_level(operand_str):
            o = re.sub(r"/\*.*?\*/", "", o).strip()  # strip /*index=N*/
            if o.startswith("%"):
                operands.append(o.lstrip("%"))
        comps[cur].append(Instr(name=name, opcode=opcode,
                                shapes=_parse_shapes(type_str),
                                operands=operands, attrs=attrs))
    return comps, entry


# ---------------------------------------------------------------- costing
class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self.symtab: Dict[str, Dict[str, Instr]] = {
            c: {i.name: i for i in instrs} for c, instrs in self.comps.items()
        }
        self._global_sym: Dict[str, Instr] = {}
        for instrs in self.comps.values():
            for i in instrs:
                self._global_sym.setdefault(i.name, i)
        self._comp_cache: Dict[str, CostReport] = {}

    # -- helpers ---------------------------------------------------------
    def _operand_shapes(self, comp: str, instr: Instr) -> List[Shape]:
        out = []
        for o in instr.operands:
            src = self.symtab.get(comp, {}).get(o) or self._global_sym.get(o)
            if src is not None:
                out.extend(src.shapes)
        return out

    def _called(self, attrs: str, key: str) -> List[str]:
        out = []
        for m in re.finditer(key + r"=%?([\w.\-]+)", attrs):
            out.append(m.group(1))
        m = re.search(key + r"=\{([^}]*)\}", attrs)
        if m:
            out.extend(x.strip().lstrip("%")
                       for x in m.group(1).split(",") if x.strip())
        return out

    def _dot_flops(self, comp: str, instr: Instr) -> float:
        out_elems = instr.out_elems
        lhs_contract = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                 instr.attrs)
        k = 1
        if lhs_contract and instr.operands:
            src = (self.symtab.get(comp, {}).get(instr.operands[0])
                   or self._global_sym.get(instr.operands[0]))
            if src and src.shapes:
                dims = src.shapes[0].dims
                for d in lhs_contract.group(1).split(","):
                    if d:
                        k *= dims[int(d)]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: str, instr: Instr) -> float:
        # rough: 2 * out_elems * kernel_elems / out_features
        ops = self._operand_shapes(comp, instr)
        kernel = ops[1].elems if len(ops) > 1 else 1
        return 2.0 * instr.out_elems * max(kernel, 1) ** 0.5  # heuristic

    _MOVEMENT_OPS = {"parameter", "constant", "bitcast", "copy", "convert",
                     "transpose", "broadcast", "reshape", "tuple",
                     "get-tuple-element", "dynamic-slice",
                     "dynamic-update-slice", "slice", "concatenate", "pad",
                     "iota", "reverse"}

    def _fusion_io_bytes(self, comp: str, instr: Instr) -> float:
        """Bytes a fusion actually touches. Fusion parameters that are only
        dynamic-sliced inside contribute the SLICE bytes (XLA reads just the
        slice of a loop-carried stack, not the whole stack); a
        dynamic-update-slice root aliases its target in place, so it
        contributes the update bytes, not the full stack. Fusions that are
        pure data movement + dtype converts (XLA:CPU materializes bf16<->f32
        conversions a TPU would fold into neighbouring kernels) are charged a
        single pass at the narrower width."""
        called = self._called(instr.attrs, "calls")
        body = self.comps.get(called[0], []) if called else []
        if not body:
            return (sum(s.bytes for s in self._operand_shapes(comp, instr))
                    + instr.out_bytes)
        if all(bi.opcode in self._MOVEMENT_OPS for bi in body) and any(
                bi.opcode == "convert" for bi in body):
            in_bytes = sum(s.bytes for s in self._operand_shapes(comp, instr))
            return min(in_bytes, instr.out_bytes)
        by_name = {i.name: i for i in body}
        uses: Dict[str, List[Instr]] = defaultdict(list)
        for bi in body:
            for o in bi.operands:
                uses[o].append(bi)
        # parameters are named param_N[.suffix]; N is the operand index
        def pidx(i: Instr, default: int) -> int:
            m = re.match(r"param_?(\d+)", i.name)
            return int(m.group(1)) if m else default
        param_instrs = [bi for bi in body if bi.opcode == "parameter"]
        param_instrs.sort(key=lambda i: pidx(i, 10 ** 6))
        operand_shapes = self._operand_shapes(comp, instr)

        total = 0.0
        for idx, op_shape in enumerate(operand_shapes):
            pinstr = param_instrs[idx] if idx < len(param_instrs) else None
            if pinstr is not None:
                puses = uses.get(pinstr.name, [])
                if puses and all(u.opcode == "dynamic-slice" and
                                 u.operands and u.operands[0] == pinstr.name
                                 for u in puses):
                    total += sum(u.out_bytes for u in puses)
                    continue
                if puses and all(u.opcode == "dynamic-update-slice" and
                                 u.operands and u.operands[0] == pinstr.name
                                 for u in puses):
                    continue  # DUS target: aliased in place, not read
            total += op_shape.bytes
        # output: root dus aliases in place
        root = body[-1]
        seen = set()
        while root.opcode in ("bitcast", "copy") and root.operands and \
                root.operands[0] in by_name and root.name not in seen:
            seen.add(root.name)
            root = by_name[root.operands[0]]
        if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            upd = by_name.get(root.operands[1])
            total += upd.out_bytes if upd is not None else instr.out_bytes
        else:
            total += instr.out_bytes
        return total

    def _group_size(self, instr: Instr) -> int:
        m = _GROUPS_RE.search(instr.attrs)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(instr.attrs)
        if m:
            return len([x for x in m.group(1).split(",") if x.strip()])
        return 0

    # -- main ------------------------------------------------------------
    def analyze_computation(self, comp: str) -> CostReport:
        if comp in self._comp_cache:
            return self._comp_cache[comp]
        report = CostReport()
        # placeholder to break recursion cycles
        self._comp_cache[comp] = report
        for instr in self.comps.get(comp, []):
            op = instr.opcode
            if op == "while":
                bodies = self._called(instr.attrs, "body")
                conds = self._called(instr.attrs, "condition")
                trip = None
                m = _TRIP_RE.search(instr.attrs)
                if m:
                    trip = float(m.group(1))
                if trip is None:
                    trip = 1.0
                    report.warnings.append(
                        f"while {instr.name}: no known_trip_count, using 1")
                sub = CostReport()
                for b in bodies + conds:
                    self._merge(sub, self.analyze_computation(b), 1.0)
                self._merge(report, sub, trip)
                continue
            if op in ("call", "async-start"):
                for b in self._called(instr.attrs, "to_apply") + \
                        self._called(instr.attrs, "calls"):
                    self._merge(report, self.analyze_computation(b), 1.0)
                continue
            if op == "conditional":
                branches = self._called(instr.attrs, "branch_computations") \
                    or (self._called(instr.attrs, "true_computation")
                        + self._called(instr.attrs, "false_computation"))
                subs = [self.analyze_computation(b) for b in branches
                        if b in self.comps]
                if subs:   # worst case branch
                    worst = max(subs, key=lambda r: r.flops + r.bytes)
                    self._merge(report, worst, 1.0)
                continue
            if op == "fusion":
                for b in self._called(instr.attrs, "calls"):
                    sub = self.analyze_computation(b)
                    report.flops += sub.flops
                    report.dot_flops += sub.dot_flops
                    for k, v in sub.flops_by_op.items():
                        report.flops_by_op[k] = report.flops_by_op.get(k, 0) + v
                    report.collectives.extend(sub.collectives)
                io_bytes = self._fusion_io_bytes(comp, instr)
                report.bytes += io_bytes
                report.bytes_by_op["fusion"] = \
                    report.bytes_by_op.get("fusion", 0) + io_bytes
                continue
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                in_bytes = sum(s.bytes for s in
                               self._operand_shapes(comp, instr))
                if not in_bytes:  # e.g. result-typed ops; fall back to output
                    in_bytes = instr.out_bytes
                report.collectives.append(CollectiveOp(
                    opcode=base, bytes=in_bytes,
                    group_size=self._group_size(instr), count=1.0,
                    name=instr.name, comp=comp))
                report.bytes += in_bytes + instr.out_bytes
                continue
            # flops
            if op == "dot":
                f = self._dot_flops(comp, instr)
                report.flops += f
                report.dot_flops += f
                report.flops_by_op["dot"] = report.flops_by_op.get("dot", 0) + f
            elif op == "convolution":
                f = self._conv_flops(comp, instr)
                report.flops += f
                report.dot_flops += f
                report.flops_by_op["convolution"] = \
                    report.flops_by_op.get("convolution", 0) + f
            elif op in _ELEMENTWISE:
                report.flops += instr.out_elems
                report.flops_by_op["elementwise"] = \
                    report.flops_by_op.get("elementwise", 0) + instr.out_elems
            elif op in ("reduce", "reduce-window"):
                ops_in = self._operand_shapes(comp, instr)
                n = ops_in[0].elems if ops_in else instr.out_elems
                report.flops += n
                report.flops_by_op["reduce"] = \
                    report.flops_by_op.get("reduce", 0) + n
            # bytes
            if op in _SKIP_BYTES and op not in ("fusion", "custom-call"):
                continue
            if op == "custom-call":
                b = (sum(s.bytes for s in self._operand_shapes(comp, instr))
                     + instr.out_bytes)
                report.bytes += b
                report.bytes_by_op["custom-call"] = \
                    report.bytes_by_op.get("custom-call", 0) + b
                continue
            if op in ("dynamic-slice",):
                b = 2.0 * instr.out_bytes
            elif op == "dynamic-update-slice":
                upd = self._operand_shapes(comp, instr)
                b = 2.0 * (upd[1].bytes if len(upd) > 1 else instr.out_bytes)
            else:
                b = (sum(s.bytes for s in self._operand_shapes(comp, instr))
                     + instr.out_bytes)
            report.bytes += b
            key = op if op in ("dot", "copy", "scatter", "gather", "sort") \
                else "other"
            report.bytes_by_op[key] = report.bytes_by_op.get(key, 0) + b
        self._comp_cache[comp] = report
        return report

    @staticmethod
    def _merge(dst: CostReport, src: CostReport, mult: float):
        dst.flops += src.flops * mult
        dst.dot_flops += src.dot_flops * mult
        dst.bytes += src.bytes * mult
        for k, v in src.flops_by_op.items():
            dst.flops_by_op[k] = dst.flops_by_op.get(k, 0) + v * mult
        for k, v in src.bytes_by_op.items():
            dst.bytes_by_op[k] = dst.bytes_by_op.get(k, 0) + v * mult
        for c in src.collectives:
            dst.collectives.append(CollectiveOp(
                opcode=c.opcode, bytes=c.bytes, group_size=c.group_size,
                count=c.count * mult, name=c.name, comp=c.comp))
        dst.warnings.extend(src.warnings)


def analyze_hlo(text: str) -> CostReport:
    """Per-device cost report for an optimized HLO module."""
    a = HloAnalyzer(text)
    return a.analyze_computation(a.entry)


def top_consumers(analyzer: "HloAnalyzer", n: int = 20,
                  by: str = "bytes") -> List[Tuple[float, str, str, str]]:
    """Largest per-instruction contributors (with loop multipliers applied),
    using the same accounting as analyze_computation. Returns
    [(value, opcode, computation, instr_name)]. The §Perf hillclimb loop
    reads this to find what to attack next."""
    out: List[Tuple[float, str, str, str]] = []

    def walk(comp: str, mult: float):
        for i in analyzer.comps.get(comp, []):
            if i.opcode == "while":
                m = _TRIP_RE.search(i.attrs)
                t = float(m.group(1)) if m else 1.0
                for b in (analyzer._called(i.attrs, "body")
                          + analyzer._called(i.attrs, "condition")):
                    walk(b, mult * t)
            elif i.opcode == "fusion":
                if by == "bytes":
                    v = analyzer._fusion_io_bytes(comp, i) * mult
                else:
                    v = sum(analyzer.analyze_computation(b).flops
                            for b in analyzer._called(i.attrs, "calls")) * mult
                out.append((v, "fusion", comp, i.name))
            elif i.opcode == "dot":
                v = (analyzer._dot_flops(comp, i) if by == "flops" else
                     sum(s.bytes for s in analyzer._operand_shapes(comp, i))
                     + i.out_bytes) * mult
                out.append((v, "dot", comp, i.name))
            elif i.opcode not in _SKIP_BYTES and by == "bytes":
                v = (sum(s.bytes for s in analyzer._operand_shapes(comp, i))
                     + i.out_bytes) * mult
                out.append((v, i.opcode, comp, i.name))

    walk(analyzer.entry, 1.0)
    out.sort(reverse=True)
    return out[:n]
