"""Rendering helpers: turn dry-run JSON records and Tier-1/Tier-2 reports
into the markdown tables EXPERIMENTS.md and the benchmark CSVs use."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable, List, Optional


def md_table(headers: List[str], rows: Iterable[Iterable]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def fmt_gb(x: Optional[float]) -> str:
    return "-" if x is None else f"{x:.2f}"


def load_dryrun_records(results_dir: Path, mesh: str = "16x16") -> list:
    recs = []
    for f in sorted(results_dir.glob(f"*_{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def roofline_table(recs: list) -> str:
    headers = ["arch", "shape", "mesh", "compute", "memory", "collective",
               "dominant", "MFU", "useful", "adj peak GB"]
    rows = []
    for r in recs:
        rl = r.get("roofline", {})
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            fmt_s(rl.get("compute_s")), fmt_s(rl.get("memory_s")),
            fmt_s(rl.get("collective_s")), rl.get("dominant", "-"),
            f"{rl.get('mfu') or 0:.3f}",
            f"{rl.get('useful_flops_ratio') or 0:.2f}",
            fmt_gb(r.get("memory", {}).get("tpu_adjusted_peak_gb")),
        ])
    return md_table(headers, rows)
