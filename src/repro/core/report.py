"""Rendering helpers: turn BenchRecord JSONL, dry-run JSON records, and
Tier-1/Tier-2 reports into the markdown tables EXPERIMENTS.md and the
benchmark CSVs use. Benchmark results arrive as structured
:class:`~repro.bench.record.BenchRecord` rows — derived metrics are read
from the record's dict, never re-parsed from strings."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional

from repro.bench.record import BenchRecord, read_jsonl


def md_table(headers: List[str], rows: Iterable[Iterable]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def fmt_gb(x: Optional[float]) -> str:
    return "-" if x is None else f"{x:.2f}"


def load_dryrun_records(results_dir: Path, mesh: str = "16x16") -> list:
    recs = []
    for f in sorted(results_dir.glob(f"*_{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


# ----------------------------------------------------- BenchRecord tables
def load_bench_records(path: str | Path) -> List[BenchRecord]:
    """Load harness results (``results/bench/*.jsonl``); [] if absent."""
    path = Path(path)
    return read_jsonl(path) if path.exists() else []


def group_records(recs: Iterable[BenchRecord]
                  ) -> Dict[str, List[BenchRecord]]:
    """Bucket records by scenario family, preserving record order."""
    out: Dict[str, List[BenchRecord]] = {}
    for r in recs:
        out.setdefault(r.group or r.name.split("/", 1)[0], []).append(r)
    return out


def derived_keys(recs: Iterable[BenchRecord]) -> List[str]:
    """Union of derived-metric names, in first-seen order."""
    keys: List[str] = []
    for r in recs:
        for k in r.derived:
            if k not in keys:
                keys.append(k)
    return keys


def _fmt_cell(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def bench_table(recs: List[BenchRecord],
                columns: Optional[List[str]] = None) -> str:
    """Markdown table straight from BenchRecords: one row per record,
    one column per derived metric (``columns`` narrows/orders them)."""
    cols = columns if columns is not None else derived_keys(recs)
    headers = ["name", "us/call"] + cols
    rows = []
    for r in recs:
        row = [r.name if r.status == "ok" else f"{r.name} (!)",
               f"{r.us_per_call:.1f}" if r.us_per_call else "-"]
        row += [_fmt_cell(r.derived.get(k)) for k in cols]
        rows.append(row)
    return md_table(headers, rows)


def bench_summary(recs: List[BenchRecord]) -> str:
    """One markdown section per scenario group."""
    parts = []
    for group, rows in group_records(recs).items():
        ref = next((r.paper_ref for r in rows if r.paper_ref), "")
        title = f"### {group}" + (f" — {ref}" if ref else "")
        parts.append(f"{title}\n\n{bench_table(rows)}")
    return "\n\n".join(parts)


def roofline_table(recs: list) -> str:
    headers = ["arch", "shape", "mesh", "compute", "memory", "collective",
               "dominant", "MFU", "useful", "adj peak GB"]
    rows = []
    for r in recs:
        rl = r.get("roofline", {})
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            fmt_s(rl.get("compute_s")), fmt_s(rl.get("memory_s")),
            fmt_s(rl.get("collective_s")), rl.get("dominant", "-"),
            f"{rl.get('mfu') or 0:.3f}",
            f"{rl.get('useful_flops_ratio') or 0:.2f}",
            fmt_gb(r.get("memory", {}).get("tpu_adjusted_peak_gb")),
        ])
    return md_table(headers, rows)
