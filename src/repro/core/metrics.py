"""DABench-LLM Tier-1 metrics — faithful implementations of the paper's
equations (§IV.B):

  Eq.1  U = R_used / R_all                       (resource allocation ratio)
  Eq.2  U = Σ L_i (R_i/R_all) / Σ L_i            (runtime-weighted, sections)
  Eq.3  LI = (1/ΣR_i) Σ (T_min/T_i) R_i          (load imbalance; 1 = balanced)
  Eq.4  LI_total = Σ L_i LI_i / Σ L_i            (runtime-weighted, sections)
  Eq.5  AI = 6 P B S / (4 P + activation_mem)    (arithmetic intensity, train)

plus the TPU adaptations documented in DESIGN.md §2 (MXU tile-padding
efficiency and mesh-device participation stand in for the vendors'
PE/PCU/PMU counts).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np


# ----------------------------------------------------------------- Eq. 1/2
def allocation_ratio(r_used: float, r_all: float) -> float:
    return r_used / r_all if r_all else 0.0


def weighted_allocation(sections: Sequence[tuple]) -> float:
    """sections: [(runtime_L_i, r_used_i, r_all_i)] -> Eq. 2."""
    num = sum(L * (r / ra if ra else 0.0) for L, r, ra in sections)
    den = sum(L for L, _, _ in sections)
    return num / den if den else 0.0


# ----------------------------------------------------------------- Eq. 3/4
def load_imbalance(resources: Sequence[float],
                   throughputs: Sequence[float]) -> float:
    """Eq. 3. 1.0 = perfectly balanced; ->0 = one task starves the rest."""
    r = np.asarray(resources, dtype=np.float64)
    t = np.asarray(throughputs, dtype=np.float64)
    if r.size == 0 or r.sum() == 0:
        return 1.0
    t_min = t.min()
    if t_min <= 0:
        return 0.0
    return float((t_min / t * r).sum() / r.sum())


def weighted_load_imbalance(sections: Sequence[tuple]) -> float:
    """sections: [(runtime_L_i, LI_i)] -> Eq. 4."""
    num = sum(L * li for L, li in sections)
    den = sum(L for L, _ in sections)
    return num / den if den else 1.0


# ------------------------------------------------------------------- Eq. 5
def arithmetic_intensity(params: float, batch: float, seq: float,
                         activation_bytes: float,
                         param_bytes_per: float = 4.0) -> float:
    """Paper Eq. 5 (training): 6PBS flops over (4P + activations) bytes."""
    denom = param_bytes_per * params + activation_bytes
    return 6.0 * params * batch * seq / denom if denom else 0.0


def activation_bytes_estimate(num_layers: int, batch: float, seq: float,
                              d_model: int, bytes_per: float = 2.0,
                              tensors_per_layer: float = 8.0) -> float:
    """Rough per-step activation traffic used by Eq. 5's denominator."""
    return num_layers * tensors_per_layer * batch * seq * d_model * bytes_per


# ---------------------------------------------------------------- samples
def percentile(sorted_samples: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of an already-sorted sample list —
    the one implementation behind bench TimingStats and the serving
    latency summaries. Empty input -> 0.0."""
    if not sorted_samples:
        return 0.0
    pos = (len(sorted_samples) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(sorted_samples) - 1)
    return sorted_samples[lo] + (sorted_samples[hi] - sorted_samples[lo]) \
        * (pos - lo)


# ------------------------------------------------- TPU-adapted allocation
MXU_TILE = (8, 128)          # sublane x lane granularity for one MXU pass


def mxu_tile_efficiency(m: int, n: int, k: int) -> float:
    """Fraction of MXU work that is useful for an (m,k)x(k,n) matmul after
    padding every dim to hardware tiles — the TPU analogue of 'PEs assigned
    but idle'."""
    def pad(x, t):
        return -(-x // t) * t
    useful = m * n * k
    padded = pad(m, MXU_TILE[0]) * pad(n, MXU_TILE[1]) * pad(k, MXU_TILE[1])
    return useful / padded if padded else 0.0


@dataclass
class TaskStat:
    """One paper 'task' (kernel/section): resources + throughput."""
    name: str
    resources: float          # devices (x unit share) assigned
    throughput: float         # work/s
    runtime: float = 0.0


def li_over_tasks(tasks: Iterable[TaskStat]) -> float:
    tasks = list(tasks)
    return load_imbalance([t.resources for t in tasks],
                          [t.throughput for t in tasks])


def slot_load_balance(slot_tokens) -> float:
    """Eq. 3 specialization for serving KV slots: each slot is one unit
    of resource, throughput_i = tokens served by slot i. 1.0 = every slot
    carried equal work; ->0 = a slot sat (mostly) idle while others
    served — the request-level analogue of the paper's 'one starved task
    bounds the system' load-balance reading."""
    tokens = np.asarray(slot_tokens, dtype=np.float64)
    if tokens.size == 0:
        return 1.0
    return load_imbalance(np.ones_like(tokens), tokens)


def expert_load_imbalance(expert_load: np.ndarray) -> float:
    """Eq. 3 specialization for MoE expert loads (tokens per expert):
    resources are equal (one expert = one unit), throughput proportional to
    assigned tokens. An idle expert pins LI to ~0, matching the paper's
    'slowest task bounds the system' reading only when inverted — here MORE
    loaded experts are the bottleneck, so throughput_i = 1/load_i."""
    load = np.asarray(expert_load, dtype=np.float64)
    load = np.where(load <= 0, np.nan, load)
    if np.all(np.isnan(load)):
        return 1.0
    inv = 1.0 / load
    inv = np.where(np.isnan(inv), np.nanmax(inv), inv)
    return load_imbalance(np.ones_like(inv), inv)
