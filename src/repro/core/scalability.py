"""Tier-2: inter-chip scalability + deployment optimization (§IV.C / §VI).

Two complementary modes, mirroring the paper's methodology:

* ``scaling_table``  — analytic: roofline terms of one cell across mesh
  splits (DP-heavy ... TP-heavy, optional PP stages), classifying each the
  way the paper classifies WSE/RDU/IPU scaling (which term saturates first).
* ``measure_*``      — empirical on THIS host (CPU, small mesh, reduced
  configs): wall-clock throughput vs batch size / precision / mesh split,
  validating the paper's Tier-2 claims (batch-size scaling, precision
  sensitivity, PP bottleneck = most-loaded stage).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig


@dataclass
class ScalePoint:
    name: str
    devices: int
    throughput: float            # tokens/s (measured) or 1/step_s (analytic)
    step_time_s: float
    extras: dict


def measure_step(fn: Callable, args: tuple, *, iters: int = 5,
                 warmup: int = 2) -> float:
    """Median wall-clock seconds for a jitted step on this host."""
    # block each warmup call (matching bench/runner.timeit_us): blocking
    # only the last one lets queued warmup work leak into the first timed
    # iteration and skews the median low
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure_batch_sweep(step_builder: Callable[[int], tuple],
                        batch_sizes: Sequence[int]) -> List[ScalePoint]:
    """Paper Fig. 12: throughput vs batch size. step_builder(b) returns
    (fn, args, tokens_per_step)."""
    out = []
    for b in batch_sizes:
        fn, args, tokens = step_builder(b)
        s = measure_step(fn, args)
        out.append(ScalePoint(name=f"batch{b}", devices=jax.device_count(),
                              throughput=tokens / s, step_time_s=s,
                              extras={"batch": b}))
    return out


def measure_precision_sweep(step_builder: Callable[[str], tuple],
                            dtypes: Sequence[str] = ("float32", "bfloat16"),
                            ) -> List[ScalePoint]:
    """Paper Table IV: throughput per numeric format."""
    out = []
    for dt in dtypes:
        fn, args, tokens = step_builder(dt)
        s = measure_step(fn, args)
        out.append(ScalePoint(name=dt, devices=jax.device_count(),
                              throughput=tokens / s, step_time_s=s,
                              extras={"dtype": dt}))
    return out


def pp_bottleneck_model(stage_layers: Sequence[int],
                        per_layer_time: float, n_microbatches: int) -> float:
    """Paper Fig. 11(c): GPipe step time is governed by the most-loaded
    stage: (M + S - 1) * max_stage_time."""
    S = len(stage_layers)
    tmax = max(stage_layers) * per_layer_time
    return (n_microbatches + S - 1) * tmax


def pp_throughput_ratio(stage_layers: Sequence[int],
                        n_microbatches: int) -> float:
    """Relative throughput of a PP split vs a perfectly balanced one."""
    S = len(stage_layers)
    balanced = sum(stage_layers) / S
    return balanced / max(stage_layers) * (n_microbatches /
                                           (n_microbatches + S - 1))
