"""Tier-2: inter-chip scalability + deployment optimization (§IV.C / §VI).

Two complementary modes, mirroring the paper's methodology:

* ``scaling_table``  — analytic: roofline terms of one cell across mesh
  splits (DP-heavy ... TP-heavy, optional PP stages), classifying each the
  way the paper classifies WSE/RDU/IPU scaling (which term saturates first).
* ``measure_*``      — empirical on THIS host (CPU, small mesh, reduced
  configs): wall-clock throughput vs batch size / precision / mesh split,
  validating the paper's Tier-2 claims (batch-size scaling, precision
  sensitivity, PP bottleneck = most-loaded stage).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Sequence

import numpy as np

import jax


@dataclass
class ScalePoint:
    name: str
    devices: int
    throughput: float            # tokens/s (measured) or 1/step_s (analytic)
    step_time_s: float
    extras: dict


def measure_step(fn: Callable, args: tuple, *, iters: int = 5,
                 warmup: int = 2) -> float:
    """Median wall-clock seconds for a jitted step on this host."""
    # block each warmup call (matching bench/runner.timeit_us): blocking
    # only the last one lets queued warmup work leak into the first timed
    # iteration and skews the median low
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure_batch_sweep(step_builder: Callable[[int], tuple],
                        batch_sizes: Sequence[int]) -> List[ScalePoint]:
    """Paper Fig. 12: throughput vs batch size. step_builder(b) returns
    (fn, args, tokens_per_step)."""
    out = []
    for b in batch_sizes:
        fn, args, tokens = step_builder(b)
        s = measure_step(fn, args)
        out.append(ScalePoint(name=f"batch{b}", devices=jax.device_count(),
                              throughput=tokens / s, step_time_s=s,
                              extras={"batch": b}))
    return out


def measure_precision_sweep(step_builder: Callable[[str], tuple],
                            dtypes: Sequence[str] = ("float32", "bfloat16"),
                            ) -> List[ScalePoint]:
    """Paper Table IV: throughput per numeric format."""
    out = []
    for dt in dtypes:
        fn, args, tokens = step_builder(dt)
        s = measure_step(fn, args)
        out.append(ScalePoint(name=dt, devices=jax.device_count(),
                              throughput=tokens / s, step_time_s=s,
                              extras={"dtype": dt}))
    return out


def pp_bottleneck_model(stage_layers: Sequence[int],
                        per_layer_time: float, n_microbatches: int) -> float:
    """Paper Fig. 11(c): GPipe step time is governed by the most-loaded
    stage: (M + S - 1) * max_stage_time."""
    S = len(stage_layers)
    tmax = max(stage_layers) * per_layer_time
    return (n_microbatches + S - 1) * tmax


def pp_throughput_ratio(stage_layers: Sequence[int],
                        n_microbatches: int) -> float:
    """Relative throughput of a PP split vs a perfectly balanced one."""
    S = len(stage_layers)
    balanced = sum(stage_layers) / S
    return balanced / max(stage_layers) * (n_microbatches /
                                           (n_microbatches + S - 1))


# --------------------------------------------------------------------------
# Measured mesh-split matrix (Tier-2): metrics for DP/TP/PP sweeps run on
# subprocess-simulated 1/2/4/8-device host meshes (benchmarks/
# bench_scaling_matrix.py). On a simulated mesh every "device" shares the
# same host cores, so the *ideal* strong-scaling outcome is constant
# wall-clock throughput across splits; the deficit from 1.0 is partition +
# collective overhead, which is exactly the signal the paper's Fig. 11 /
# Table III classification needs (which term saturates first).
# --------------------------------------------------------------------------

def scaling_efficiency(throughput_n: float, throughput_1: float) -> float:
    """Measured throughput at an N-way split over the 1-device throughput
    of the SAME global problem. 1.0 = free partitioning; on real hardware
    multiply by N for classic strong-scaling speedup."""
    return throughput_n / throughput_1 if throughput_1 > 0 else 0.0


def collective_time_fraction(step_n_s: float, step_1_s: float) -> float:
    """Upper-bound fraction of an N-way step spent off the critical
    compute path (collectives + partition overhead): on a shared-core
    simulated mesh total compute is invariant across splits, so any time
    beyond the 1-device step is overhead. Clamped to [0, 1)."""
    if step_n_s <= 0:
        return 0.0
    return max(0.0, 1.0 - step_1_s / step_n_s)


def even_shard_sizes(total: int, shards: int) -> List[int]:
    """Work units per shard when ``total`` items split over ``shards``
    (first ``total % shards`` shards take the extra unit; shards beyond
    ``total`` sit idle with 0)."""
    base, rem = divmod(total, shards)
    return [base + (1 if i < rem else 0) for i in range(shards)]


def shard_balance(work_per_shard: Sequence[float]) -> float:
    """Eq. 3 over per-shard work: resources are one unit per shard,
    throughput_i proportional to assigned work. An idle shard pins the
    index to 0 — the paper's 'one starved task bounds the system'."""
    from repro.core.metrics import load_imbalance

    work = np.asarray(work_per_shard, dtype=np.float64)
    if work.size == 0:
        return 1.0
    return load_imbalance(np.ones_like(work), work)


def pp_stage_balance(stage_layers: Sequence[int]) -> float:
    """Eq. 3 over pipeline stages: stage i's throughput is 1/layers_i, so
    the index reduces to mean(layers)/max(layers) — 1.0 for an even split,
    degrading as one stage hoards layers."""
    from repro.core.metrics import load_imbalance

    layers = np.asarray(stage_layers, dtype=np.float64)
    if layers.size == 0 or layers.min() <= 0:
        return 0.0
    return load_imbalance(np.ones_like(layers), 1.0 / layers)


@dataclass
class PPModelCheck:
    """Measured GPipe step time vs the most-loaded-stage model (Fig. 11c)."""

    measured_s: float
    predicted_s: float
    per_layer_s: float          # calibrated from the balanced split

    @property
    def ratio(self) -> float:
        return self.measured_s / self.predicted_s if self.predicted_s else 0.0

    def within(self, lo: float = 0.45, hi: float = 2.2) -> bool:
        """Tolerance band for CPU-simulated meshes: dispatch overhead and
        host jitter shift absolute times, but a split whose measured step
        escapes this band is not obeying max-stage scaling at all."""
        return lo <= self.ratio <= hi


def pp_model_check(measured_s: float, stage_layers: Sequence[int],
                   n_microbatches: int, per_layer_s: float) -> PPModelCheck:
    """Check one measured PP split against ``pp_bottleneck_model`` using a
    per-layer time calibrated from a balanced reference split."""
    predicted = pp_bottleneck_model(stage_layers, per_layer_s,
                                    n_microbatches)
    return PPModelCheck(measured_s=measured_s, predicted_s=predicted,
                        per_layer_s=per_layer_s)


def pp_calibrate_per_layer(measured_s: float,
                           stage_layers: Sequence[int],
                           n_microbatches: int) -> float:
    """Invert the bottleneck model on a reference split to recover the
    effective per-layer time (schedule overhead included)."""
    S = len(stage_layers)
    denom = (n_microbatches + S - 1) * max(stage_layers)
    return measured_s / denom if denom else 0.0
