"""Decoder stack composition: layer init/apply/decode for every assigned
family (dense, moe, vlm, audio enc-dec, hybrid attn+ssm, attention-free ssm),
scanned over a stacked-parameter leading layer axis so 80-layer models
compile as one HLO while-loop body.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig
from repro.parallel.compat import shard_map
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    apply_mrope,
    apply_rope,
    mlp_init,
    norm_init,
)


@dataclass(frozen=True)
class Runtime:
    """Execution knobs threaded through apply functions."""
    attention_backend: str = "dense"     # dense | chunked | pallas
    ssm_backend: str = "chunked"         # chunked | recurrent | pallas
    chunk: int = 512
    act_spec: Optional[PartitionSpec] = None   # (batch, seq, d_model)
    remat: bool = False
    # decode: lse-combining attention over a sequence-sharded KV cache
    decode_partitioned: bool = False
    mesh_batch_axes: tuple = ()          # axes the batch shards over
    dp_size: int = 1                     # product of mesh_batch_axes sizes
    moe_shardmap: bool = False           # expert-parallel shard_map dispatch
    ep_axes: tuple = ("model",)          # mesh axes experts shard over
    # §Perf: pin mixer/ffn outputs to the activation sharding BEFORE the
    # residual add, forcing the TP psum to run in bf16 instead of being
    # deferred into the f32 norm region (halves all-reduce bytes).
    pin_mixer_output: bool = False
    # §Perf: two-level factorized intra-chunk linear attention (no (c,c,K)
    # pairwise tensor) — see ssm.chunked_linear_attention.
    ssm_factored: bool = False
    # §Perf: remat in k-layer blocks (stack /k, recompute x k)
    layers_per_block: int = 1
    # §Perf: compute norms locally per device via shard_map. XLA otherwise
    # shards the f32 norm region over `model` and pays activation-sized f32
    # all-reduces to recombine cotangents in backward (measured: ~97% of
    # qwen1.5-110b's collective bytes).
    norm_local: bool = False
    # Pallas tile overrides for backend='pallas'. None = auto: resolved
    # per (kernel, shape, dtype, backend) from the tuned-config cache
    # (repro.kernels.tuning, written by `benchmarks.run --tune`), falling
    # back to the kernel defaults on a cache miss.
    attn_block_q: Optional[int] = None
    attn_block_k: Optional[int] = None
    ssm_chunk: Optional[int] = None
    # Paged decode attention (backend='pallas'): pages gathered per grid
    # step. None = auto (tuned cache, see repro.kernels.tuning).
    paged_pages_per_block: Optional[int] = None


def _constrain(x, rt: Runtime):
    if rt.act_spec is not None and x.ndim == 3:
        from repro.parallel.sharding import maybe_constrain
        return maybe_constrain(x, rt.act_spec)
    return x


def _norm(p_n, x, cfg: ModelConfig, rt: Runtime):
    """apply_norm, optionally forced device-local (rt.norm_local)."""
    from repro.parallel.sharding import have_ambient_mesh
    if not (rt.norm_local and rt.act_spec is not None
            and have_ambient_mesh() and x.ndim == 3):
        return apply_norm(p_n, x, cfg.norm)
    from jax.sharding import PartitionSpec as P
    pspecs = jax.tree.map(lambda _: P(None), p_n)
    return shard_map(
        lambda pn, xx: apply_norm(pn, xx, cfg.norm),
        in_specs=(pspecs, rt.act_spec), out_specs=rt.act_spec,
        check_vma=False)(p_n, x)


def _rope_q_k(cfg: ModelConfig, q, k, positions):
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    # 'sinusoidal' handled at embedding; 'none' is a no-op
    return q, k


# ===================================================================== init
def layer_init(key, cfg: ModelConfig, dtype, *, cross: bool = False,
               bidirectional: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {"norm1": norm_init(cfg.d_model, cfg.norm),
               "norm2": norm_init(cfg.d_model, cfg.norm)}
    if cfg.family == "ssm":                       # rwkv6 block
        p["time_mix"] = ssm_mod.rwkv6_init(ks[0], cfg, dtype)
        p["channel_mix"] = ssm_mod.rwkv6_channel_mix_init(ks[1], cfg, dtype)
        return p
    p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.ssd_init(ks[1], cfg, dtype)
    if cross:
        p["norm_cross"] = norm_init(cfg.d_model, cfg.norm)
        p["cross_attn"] = attn_mod.attn_init(ks[2], cfg, dtype)
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(ks[3], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.activation,
                            dtype)
    return p


def stack_init(key, cfg: ModelConfig, num_layers: int, dtype, *,
               cross: bool = False, bidirectional: bool = False):
    keys = jax.random.split(key, num_layers)
    return jax.vmap(
        lambda k: layer_init(k, cfg, dtype, cross=cross,
                             bidirectional=bidirectional))(keys)


# ================================================================= forward
def _ring_from_prefill(k, span):
    """Arrange the last `span` prefill K/V rows into ring-buffer slot order
    (token at position p lives at slot p % span)."""
    B, S = k.shape[:2]
    take = min(S, span)
    k_last = k[:, S - take:]
    if take < span:
        k_last = jnp.pad(k_last, ((0, 0), (0, span - take)) +
                         ((0, 0),) * (k.ndim - 2))
    slots = (jnp.arange(span) + (S - take)) % span
    ring = jnp.zeros((B, span) + k.shape[2:], k.dtype)
    return ring.at[:, slots].set(k_last[:, :span])


def layer_apply(p, x, cfg: ModelConfig, rt: Runtime, positions,
                enc_out=None, *, causal: bool = True,
                return_cache: bool = False, cache_span: int = 0):
    """Full-sequence layer forward. Returns (x, aux_dict, cache_entry).

    cache_entry is None unless return_cache (prefill path), in which case it
    matches the per-layer structure of cache_init.
    """
    aux = {}
    if cfg.family == "ssm":
        h, (state, last_tok) = ssm_mod.rwkv6_time_mix(
            p["time_mix"], _norm(p["norm1"], x, cfg, rt), cfg,
            backend=rt.ssm_backend, factored=rt.ssm_factored,
            chunk=rt.ssm_chunk)
        x = _constrain(x + h, rt)
        h, last_tok2 = ssm_mod.rwkv6_channel_mix(
            p["channel_mix"], _norm(p["norm2"], x, cfg, rt))
        x = _constrain(x + h, rt)
        return x, aux, {"wkv_state": state, "shift1": last_tok,
                        "shift2": last_tok2}

    # ---- mixer: attention (+ parallel ssd heads for hybrid) ----
    h_in = _norm(p["norm1"], x, cfg, rt)
    q, k, v = attn_mod.project_qkv(p["attn"], h_in, h_in, cfg)
    q, k = _rope_q_k(cfg, q, k, positions)
    window = cfg.window if cfg.attention_kind == "sliding" else 0
    o = attn_mod.attention(q, k, v, backend=rt.attention_backend,
                           causal=causal, window=window, chunk=rt.chunk,
                           block_q=rt.attn_block_q, block_k=rt.attn_block_k)
    h = o.reshape(*x.shape[:-1], -1) @ p["attn"]["wo"]
    cache_entry = {}
    if return_cache:
        if cfg.attention_kind == "sliding":
            span = min(cache_span, window) if window else cache_span
            cache_entry["k"] = _ring_from_prefill(k, span)
            cache_entry["v"] = _ring_from_prefill(v, span)
        else:
            pad = cache_span - k.shape[1]
            zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
            cache_entry["k"] = jnp.pad(k, zpad)
            cache_entry["v"] = jnp.pad(v, zpad)
    if cfg.family == "hybrid":
        h_ssm, ssd_state = ssm_mod.ssd_mix(p["ssm"], h_in, cfg,
                                           backend=rt.ssm_backend,
                                           factored=rt.ssm_factored,
                                           chunk=rt.ssm_chunk)
        h = (h + h_ssm) * 0.5
        if return_cache:
            cache_entry["ssd_state"] = ssd_state
    if rt.pin_mixer_output:
        h = _constrain(h, rt)   # force the TP psum in bf16 (§Perf)
    x = _constrain(x + h, rt)

    # ---- cross attention (whisper decoder) ----
    if enc_out is not None:
        h_in = _norm(p["norm_cross"], x, cfg, rt)
        q, ck, cv = attn_mod.project_qkv(p["cross_attn"], h_in, enc_out, cfg)
        o = attn_mod.attention(q, ck, cv, backend=rt.attention_backend,
                               causal=False, chunk=rt.chunk,
                               block_q=rt.attn_block_q,
                               block_k=rt.attn_block_k)
        x = _constrain(
            x + o.reshape(*x.shape[:-1], -1) @ p["cross_attn"]["wo"], rt)
        if return_cache:
            cache_entry["ck"], cache_entry["cv"] = ck, cv

    # ---- mlp / moe ----
    h_in = _norm(p["norm2"], x, cfg, rt)
    if cfg.moe is not None:
        h, moe_aux = moe_mod.moe_ffn(p["moe"], h_in, cfg, rt)
        aux.update(moe_aux)
    else:
        h = apply_mlp(p["mlp"], h_in, cfg.activation)
    if rt.pin_mixer_output:
        h = _constrain(h, rt)   # force the TP psum in bf16 (§Perf)
    x = _constrain(x + h, rt)
    return x, aux, (cache_entry if return_cache else None)


def stack_apply(stacked, x, cfg: ModelConfig, rt: Runtime, positions,
                enc_out=None, *, causal: bool = True):
    """Scan the layer stack. Returns (x, aux) with aux reduced over layers.

    rt.layers_per_block > 1 (§Perf): remat in k-layer blocks — the saved
    activation stack shrinks k-fold (only block inputs are kept) at the
    price of recomputing k layers per backward block."""

    def one_layer(carry, p_layer):
        y, aux, _ = layer_apply(p_layer, carry, cfg, rt, positions, enc_out,
                                causal=causal)
        out_aux = {"aux_loss": aux.get("aux_loss", jnp.zeros(())),
                   "expert_load": aux.get("expert_load")}
        if out_aux["expert_load"] is None:
            out_aux.pop("expert_load")
        return y, out_aux

    k = max(1, rt.layers_per_block)
    L = jax.tree.leaves(stacked)[0].shape[0]
    if k > 1 and L % k == 0:
        blocked = jax.tree.map(
            lambda a: a.reshape(L // k, k, *a.shape[1:]), stacked)

        def body(carry, p_block):
            y, aux = jax.lax.scan(one_layer, carry, p_block)
            # aux_loss: (k,) -> scalar; expert_load: (k, E) kept, outer scan
            # stacks to (L/k, k, E) and we flatten to (L, E) at the end.
            return y, jax.tree.map(
                lambda a: a.sum(0) if a.ndim == 1 else a, aux)

        xs = blocked
    else:
        body, xs = one_layer, stacked

    fn = jax.checkpoint(body) if rt.remat else body
    x, aux_stack = jax.lax.scan(fn, x, xs)
    aux = {"aux_loss": aux_stack["aux_loss"].sum()}
    if "expert_load" in aux_stack:
        el = aux_stack["expert_load"]
        aux["expert_load"] = el.reshape(-1, el.shape[-1])   # (L, E)
    return x, aux


def stack_prefill(stacked, x, cfg: ModelConfig, rt: Runtime, positions,
                  enc_out=None, *, cache_span: int):
    """Forward that also collects the stacked decode cache (prefill)."""

    def body(carry, p_layer):
        y, _, cache = layer_apply(p_layer, carry, cfg, rt, positions,
                                  enc_out, causal=True, return_cache=True,
                                  cache_span=cache_span)
        return y, cache

    x, caches = jax.lax.scan(body, x, stacked)
    return x, caches


# ================================================================= caches
def cache_init(cfg: ModelConfig, num_layers: int, batch: int, max_len: int,
               dtype) -> dict:
    """Stacked (L, ...) decode cache for one stack."""
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    L = num_layers
    c: dict = {}
    if cfg.attention_kind != "none":
        span = min(max_len, cfg.window) if cfg.attention_kind == "sliding" \
            else max_len
        c["k"] = jnp.zeros((L, batch, span, nkv, hd), dtype)
        c["v"] = jnp.zeros((L, batch, span, nkv, hd), dtype)
    if cfg.family == "hybrid":
        hs, N = cfg.ssm.head_size, cfg.ssm.state_size
        H = cfg.d_model // hs
        c["ssd_state"] = jnp.zeros((L, batch, H, N, hs), jnp.float32)
    if cfg.family == "ssm":
        hs = cfg.ssm.head_size
        H = cfg.d_model // hs
        c["wkv_state"] = jnp.zeros((L, batch, H, hs, hs), jnp.float32)
        c["shift1"] = jnp.zeros((L, batch, cfg.d_model), dtype)
        c["shift2"] = jnp.zeros((L, batch, cfg.d_model), dtype)
    return c


def paged_cache_init(cfg: ModelConfig, num_layers: int, num_pages: int,
                     page_size: int, dtype) -> dict:
    """Stacked (L, P, page_size, Hkv, D) paged KV pools. The pool is
    global — requests own *pages* via block tables, not slots — so there
    is no batch axis. Page 0 is the reserved null page (see
    :mod:`repro.serving.pages`)."""
    if (cfg.family in ("ssm", "hybrid") or cfg.attention_kind != "full"
            or cfg.is_enc_dec):
        raise ValueError(
            "paged KV serving supports full-attention decoder-only "
            f"models; got family={cfg.family!r}, "
            f"attention_kind={cfg.attention_kind!r}, "
            f"enc_dec={cfg.is_enc_dec}")
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    shape = (num_layers, num_pages, page_size, nkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ================================================================== decode
def layer_decode(p, x, cache, pos, cfg: ModelConfig, rt: Runtime,
                 cross_cache=None):
    """Single-token step. x: (B,1,d); cache: this layer's entry (no L axis).
    Returns (x, new_cache).

    pos is a scalar (lockstep batch: every row writes the same cache slot)
    or a (B,) vector (continuous batching: each row carries its own
    position, writes its own slot, and masks its own valid cache length).
    """
    new_cache = dict(cache)
    pos = jnp.asarray(pos)
    per_row = pos.ndim > 0
    if cfg.family == "ssm":
        h_in = apply_norm(p["norm1"], x, cfg.norm)
        B, _, d = x.shape
        hs = cfg.ssm.head_size
        H = d // hs
        prev = cache["shift1"][:, None]
        mix = p["time_mix"]["mix"].astype(x.dtype)
        xs = [h_in + (prev - h_in) * mix[i] for i in range(5)]
        xr, xk, xv, xg, xw = xs
        tm = p["time_mix"]
        r = (xr @ tm["wr"]).reshape(B, H, hs)
        k = (xk @ tm["wk"]).reshape(B, H, hs)
        v = (xv @ tm["wv"]).reshape(B, H, hs)
        g = jax.nn.silu(xg @ tm["wg"])[:, 0]
        ld = -jnp.exp(tm["w0"] + jnp.tanh(xw @ tm["wa"]) @ tm["wb"])
        ld = jnp.clip(ld, -12.0, -1e-4).reshape(B, H, hs)
        state, o = ssm_mod.linear_attention_step(
            cache["wkv_state"], r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), ld.astype(jnp.float32), tm["u"])
        of = o.astype(jnp.float32)
        mean = of.mean(-1, keepdims=True)
        var = ((of - mean) ** 2).mean(-1, keepdims=True)
        of = ((of - mean) * jax.lax.rsqrt(var + 1e-5) * tm["ln_scale"]
              + tm["ln_bias"])
        h = (of.reshape(B, d).astype(x.dtype) * g) @ tm["wo"]
        x = x + h[:, None]
        new_cache["wkv_state"] = state
        new_cache["shift1"] = h_in[:, 0]
        # channel mix
        h_in = apply_norm(p["norm2"], x, cfg.norm)
        cmix = p["channel_mix"]["mix"].astype(x.dtype)
        prev = cache["shift2"][:, None]
        xk_ = h_in + (prev - h_in) * cmix[0]
        xr_ = h_in + (prev - h_in) * cmix[1]
        cm = p["channel_mix"]
        kk = jnp.square(jax.nn.relu(xk_ @ cm["wk"]))
        x = x + jax.nn.sigmoid(xr_ @ cm["wr"]) * (kk @ cm["wv"])
        new_cache["shift2"] = h_in[:, 0]
        return x, new_cache

    h_in = apply_norm(p["norm1"], x, cfg.norm)
    q, k, v = attn_mod.project_qkv(p["attn"], h_in, h_in, cfg)
    pos_b = jnp.broadcast_to(pos.reshape(-1, 1), (x.shape[0], 1))
    q, k = _rope_q_k(cfg, q, k, pos_b if cfg.rope != "mrope" else
                     jnp.broadcast_to(pos_b[:, None], (x.shape[0], 3, 1)))
    span = cache["k"].shape[1]
    slot = pos % span if cfg.attention_kind == "sliding" else pos
    if per_row:
        bidx = jnp.arange(x.shape[0])
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                      axis=1)
    cache_len = jnp.minimum(pos + 1, span)
    if rt.decode_partitioned and cfg.attention_kind == "full":
        from repro.parallel.collectives import partitioned_decode_attention
        o = partitioned_decode_attention(q, k_cache, v_cache, cache_len,
                                         batch_axes=rt.mesh_batch_axes)
    else:
        o = attn_mod.decode_attention_simple(q, k_cache, v_cache, cache_len)
    h = o.reshape(*x.shape[:-1], -1) @ p["attn"]["wo"]
    new_cache["k"], new_cache["v"] = k_cache, v_cache
    if cfg.family == "hybrid":
        # one-step ssd
        sp = p["ssm"]
        B, _, dm = x.shape
        hs, N = cfg.ssm.head_size, cfg.ssm.state_size
        H = sp["wx"].shape[1] // hs
        xin = (h_in @ sp["wx"]).reshape(B, H, hs)
        z = jax.nn.silu(h_in @ sp["wz"])[:, 0]
        Bm = (h_in @ sp["wB"]).reshape(B, H, N)
        Cm = (h_in @ sp["wC"]).reshape(B, H, N)
        dt = jax.nn.softplus((h_in @ sp["wdt"]).astype(jnp.float32)[:, 0]
                             + sp["dt_bias"])
        ld = jnp.broadcast_to(
            jnp.clip((-dt * jnp.exp(sp["A_log"]))[..., None], -12.0, -1e-6),
            (B, H, N))
        state, o_s = ssm_mod.linear_attention_step(
            cache["ssd_state"], Cm.astype(jnp.float32),
            (Bm * dt[..., None].astype(Bm.dtype)).astype(jnp.float32),
            xin.astype(jnp.float32), ld)
        o_s = o_s + sp["D"][:, None] * xin.astype(jnp.float32)
        h_ssm = (o_s.reshape(B, H * hs).astype(x.dtype) * z) @ sp["wo"]
        h = (h + h_ssm[:, None]) * 0.5
        new_cache["ssd_state"] = state
    x = x + h

    if cross_cache is not None:
        h_in = apply_norm(p["norm_cross"], x, cfg.norm)
        hd = cfg.resolved_head_dim
        q = (h_in @ p["cross_attn"]["wq"])
        if cfg.qkv_bias:
            q = q + p["cross_attn"]["bq"]
        q = q.reshape(x.shape[0], 1, cfg.num_heads, hd)
        enc_len = jnp.int32(cross_cache["ck"].shape[1])
        if rt.decode_partitioned:
            from repro.parallel.collectives import \
                partitioned_decode_attention
            o = partitioned_decode_attention(
                q, cross_cache["ck"], cross_cache["cv"], enc_len,
                batch_axes=rt.mesh_batch_axes)
        else:
            o = attn_mod.decode_attention_simple(
                q, cross_cache["ck"], cross_cache["cv"], enc_len)
        x = x + o.reshape(*x.shape[:-1], -1) @ p["cross_attn"]["wo"]

    h_in = apply_norm(p["norm2"], x, cfg.norm)
    if cfg.moe is not None:
        h, _ = moe_mod.moe_ffn(p["moe"], h_in, cfg, rt)
    else:
        h = apply_mlp(p["mlp"], h_in, cfg.activation)
    return x + h, new_cache


def _paged_attend(q, k_pool, v_pool, block_tables, lengths, rt: Runtime):
    """Backend switch for block-table attention: the Pallas kernel (with
    its in-kernel page gather) for backend='pallas', the gather-then-
    decode_attention_simple reference everywhere else."""
    if rt.attention_backend == "pallas":
        from repro.kernels import ops as kops
        return kops.paged_decode_attention(
            q, k_pool, v_pool, block_tables, lengths,
            pages_per_block=rt.paged_pages_per_block)
    return attn_mod.paged_decode_attention_ref(q, k_pool, v_pool,
                                               block_tables, lengths)


def layer_decode_paged(p, x, cache, pos, block_tables, cfg: ModelConfig,
                       rt: Runtime):
    """Single-token step against the paged KV pool. x: (B,1,d); cache:
    this layer's {"k","v"} pools (P, page_size, Hkv, D) — no batch axis;
    pos: (B,) per-row positions; block_tables: (B, n_pages) physical page
    ids in logical order (retired rows all-null). Each row writes its new
    K/V at (table[pos // page_size], pos % page_size) — rows own disjoint
    pages, so the scatter never races."""
    pos = jnp.asarray(pos)
    h_in = apply_norm(p["norm1"], x, cfg.norm)
    q, k, v = attn_mod.project_qkv(p["attn"], h_in, h_in, cfg)
    pos_b = jnp.broadcast_to(pos.reshape(-1, 1), (x.shape[0], 1))
    q, k = _rope_q_k(cfg, q, k, pos_b if cfg.rope != "mrope" else
                     jnp.broadcast_to(pos_b[:, None], (x.shape[0], 3, 1)))
    ps = cache["k"].shape[1]
    bidx = jnp.arange(x.shape[0])
    pages = block_tables[bidx, pos // ps]
    offs = pos % ps
    k_pool = cache["k"].at[pages, offs].set(k[:, 0])
    v_pool = cache["v"].at[pages, offs].set(v[:, 0])
    o = _paged_attend(q, k_pool, v_pool, block_tables, pos + 1, rt)
    x = x + o.reshape(*x.shape[:-1], -1) @ p["attn"]["wo"]
    h_in = apply_norm(p["norm2"], x, cfg.norm)
    if cfg.moe is not None:
        h, _ = moe_mod.moe_ffn(p["moe"], h_in, cfg, rt)
    else:
        h = apply_mlp(p["mlp"], h_in, cfg.activation)
    return x + h, {"k": k_pool, "v": v_pool}


def stack_decode_paged(stacked, x, caches, pos, block_tables,
                       cfg: ModelConfig, rt: Runtime):
    """Scan paged decode over layers; block tables are shared across
    layers (one logical address space, L physical pools)."""

    def body(carry, xs):
        p_layer, cache = xs
        y, new_cache = layer_decode_paged(p_layer, carry, cache, pos,
                                          block_tables, cfg, rt)
        return y, new_cache

    return jax.lax.scan(body, x, (stacked, caches))


def layer_prefill_chunk(p, x, cache, block_tables, positions,
                        cfg: ModelConfig, rt: Runtime):
    """Chunked-prefill layer step: write this chunk's K/V into the paged
    pool, then attend causally over the *gathered* logical history (pages
    written by earlier chunks plus this one). x: (B, C, d); positions:
    (C,) absolute token positions of the chunk.

    The chunk is small and prefill is compute-bound, so the gather runs
    outside any kernel and the scores go through ``dense_attention`` with
    ``q_offset`` — the same masked-softmax math as the one-shot prefill,
    summed in the same (logical-position) order."""
    h_in = apply_norm(p["norm1"], x, cfg.norm)
    q, k, v = attn_mod.project_qkv(p["attn"], h_in, h_in, cfg)
    q, k = _rope_q_k(cfg, q, k, positions[None] if cfg.rope != "mrope"
                     else jnp.broadcast_to(positions[None, None],
                                           (1, 3, positions.shape[0])))
    B, C = x.shape[0], x.shape[1]
    ps = cache["k"].shape[1]
    npag = block_tables.shape[1]
    pages = jnp.take(block_tables, positions // ps, axis=1)     # (B, C)
    offs = jnp.broadcast_to((positions % ps)[None], (B, C))
    k_pool = cache["k"].at[pages, offs].set(k)
    v_pool = cache["v"].at[pages, offs].set(v)
    k_all = k_pool[block_tables].reshape(B, npag * ps, *k.shape[2:])
    v_all = v_pool[block_tables].reshape(B, npag * ps, *v.shape[2:])
    o = attn_mod.dense_attention(q, k_all, v_all, causal=True,
                                 q_offset=positions[0])
    x = x + o.reshape(*x.shape[:-1], -1) @ p["attn"]["wo"]
    h_in = apply_norm(p["norm2"], x, cfg.norm)
    if cfg.moe is not None:
        h, _ = moe_mod.moe_ffn(p["moe"], h_in, cfg, rt)
    else:
        h = apply_mlp(p["mlp"], h_in, cfg.activation)
    return x + h, {"k": k_pool, "v": v_pool}


def stack_prefill_chunk(stacked, x, caches, block_tables, positions,
                        cfg: ModelConfig, rt: Runtime):
    """Scan one prompt chunk through the layer stack, threading the paged
    pools as scan xs/ys."""

    def body(carry, xs):
        p_layer, cache = xs
        y, new_cache = layer_prefill_chunk(p_layer, carry, cache,
                                           block_tables, positions, cfg,
                                           rt)
        return y, new_cache

    return jax.lax.scan(body, x, (stacked, caches))


def stack_decode(stacked, x, caches, pos, cfg: ModelConfig, rt: Runtime,
                 cross_caches=None):
    """Scan decode over layers, threading per-layer caches as scan xs/ys."""

    def body(carry, xs):
        if cross_caches is not None:
            p_layer, cache, ccache = xs
        else:
            p_layer, cache = xs
            ccache = None
        y, new_cache = layer_decode(p_layer, carry, cache, pos, cfg, rt,
                                    cross_cache=ccache)
        return y, new_cache

    xs = (stacked, caches, cross_caches) if cross_caches is not None \
        else (stacked, caches)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches
