"""Modality-frontend stubs + input specs.

Per the assignment, [audio]/[vlm] entries specify the transformer BACKBONE
only: the conv/mel (whisper) and patch-embedding (qwen2-vl, llama4 early
fusion) frontends are stubs. input_spec() therefore hands the backbone
precomputed frame/patch embeddings.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def train_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """{name: (shape, dtype)} for one training batch."""
    spec: dict = {}
    if cfg.frontend == "vision_stub":
        spec["embeds"] = ((batch, seq, cfg.d_model), jnp.bfloat16)
        if cfg.rope == "mrope":
            spec["positions"] = ((batch, 3, seq), jnp.int32)
    elif cfg.frontend == "audio_stub":
        spec["audio_embeds"] = ((batch, seq, cfg.d_model), jnp.bfloat16)
        spec["tokens"] = ((batch, seq), jnp.int32)
    else:
        spec["tokens"] = ((batch, seq), jnp.int32)
    spec["labels"] = ((batch, seq), jnp.int32)
    return spec


def prefill_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> dict:
    spec = train_batch_spec(cfg, batch, seq)
    spec.pop("labels")
    return spec


def synth_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                kind: str = "train") -> dict:
    """Deterministic synthetic batch matching the spec (smoke/bench use)."""
    rng = np.random.default_rng(seed)
    spec = (train_batch_spec if kind == "train" else prefill_batch_spec)(
        cfg, batch, seq)
    out = {}
    for name, (shape, dtype) in spec.items():
        if dtype == jnp.int32:
            hi = cfg.vocab_size if name in ("tokens", "labels") else seq
            out[name] = jnp.asarray(
                rng.integers(0, hi, size=shape), jnp.int32)
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(shape) * 0.02, dtype)
    return out
