"""Linear-attention / SSM layers: RWKV6 (Finch) and Mamba-2-style SSD.

Both are instances of one primitive — a decayed linear-attention recurrence

    S_t = diag(exp(ld_t)) . S_{t-1} + k_t (x) v_t          (state: K x V)
    o_t = q_t @ S_{t-1} + (q_t . u . k_t) v_t              (rwkv6: bonus u)
    o_t = q_t @ S_t                                        (ssd)

implemented three ways:
* ``recurrent_scan``   — exact per-token scan (oracle + long-context decode)
* ``chunked``          — chunk-parallel form: cross-chunk state recurrence +
                         intra-chunk pairwise-decay attention. All decay
                         factors are exp(<=0) so it is numerically safe at
                         any sequence length. This is the training/prefill
                         path and the CPU-lowerable stand-in for the Pallas
                         wkv kernel.
* ``pallas``           — repro.kernels.rwkv6 (TPU target).

Shapes: q,k,ld: (B,T,H,K); v: (B,T,H,V); state: (B,H,K,V).
For SSD the decay is scalar per head (K=state_size holds k; ld broadcasts).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ------------------------------------------------------------- primitive
def linear_attention_step(state, q, k, v, ld, u=None):
    """One recurrence step. q,k,ld:(B,H,K) v:(B,H,V) state:(B,H,K,V)."""
    kv = k[..., :, None] * v[..., None, :]                 # (B,H,K,V)
    if u is None:  # ssd: include current token after decay
        state = jnp.exp(ld)[..., None] * state + kv
        o = jnp.einsum("bhk,bhkv->bhv", q, state)
    else:          # rwkv6: bonus weight on current token
        o = jnp.einsum("bhk,bhkv->bhv", q, state) + jnp.einsum(
            "bhk,bhkv->bhv", q * u, kv)
        state = jnp.exp(ld)[..., None] * state + kv
    return state, o


def recurrent_linear_attention(q, k, v, ld, u=None, initial_state=None):
    B, T, H, K = q.shape
    V = v.shape[-1]
    state0 = (initial_state if initial_state is not None
              else jnp.zeros((B, H, K, V), jnp.float32))

    def step(s, xs):
        qi, ki, vi, ldi = xs
        s, o = linear_attention_step(s, qi, ki, vi, ldi, u)
        return s, o

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (q, k, v, ld))
    state, o = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(o, 0, 1), state


def chunked_linear_attention(q, k, v, ld, u=None, initial_state=None,
                             chunk: int = 64, factored: bool = False,
                             sub: int = 16):
    """Chunk-parallel decayed linear attention (see module docstring).

    All heavy per-chunk work (f32 upcast, pairwise-decay intra-chunk
    attention, state recurrence) happens inside a scan over chunks, so peak
    memory is O(B*c^2*H*K) regardless of T and the inputs stay in their
    compute dtype (bf16) outside the loop.

    factored=True (§Perf): two-level intra-chunk scheme. Cross-sub-chunk
    terms factor around the sub-chunk boundary b (s < b <= t):
        A[t,s] = (q_t exp(w_t - p_b)) . (k_s exp(p_b - p_s))
    — both factors exp(<=0), so they are plain safe matmuls; only the
    (sub x sub) diagonal blocks need the pairwise (r,r,K) tensor. This
    removes the O(c^2 K) exp tensor (the memory-term hot spot on rwkv6)
    at identical math.
    """
    B, T, H, K = q.shape
    V = v.shape[-1]
    dtype = q.dtype
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        z3 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, z3) for a in (q, k, v))
        ld = jnp.pad(ld, z3)  # ld=0 on padding -> decay 1, state unchanged;
        # padded k rows are zero so they add nothing to the state.
    n = q.shape[1] // c
    f32 = jnp.float32
    qc = jnp.moveaxis(q.reshape(B, n, c, H, K), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, n, c, H, K), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n, c, H, V), 1, 0)
    ldc = jnp.moveaxis(ld.reshape(B, n, c, H, K), 1, 0)

    tgrid = jnp.arange(c)
    mask = (tgrid[:, None] >= tgrid[None, :]) if u is None else (
        tgrid[:, None] > tgrid[None, :])

    state0 = (initial_state.astype(f32) if initial_state is not None
              else jnp.zeros((B, H, K, V), f32))

    r = min(sub, c)
    nsub = c // r if c % r == 0 else 0
    sgrid = jnp.arange(r)
    smask = (sgrid[:, None] >= sgrid[None, :]) if u is None else (
        sgrid[:, None] > sgrid[None, :])

    def _intra_pairwise(qi, ki, vi, w_exp, p_inc):
        diff = w_exp[:, :, None] - p_inc[:, None, :]       # (B,c,c,H,K)
        diff = jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)
        A = jnp.einsum("bthk,bshk,btshk->bhts", qi, ki, jnp.exp(diff))
        return jnp.einsum("bhts,bshv->bthv", A, vi)

    def _intra_factored(qi, ki, vi, w_exp, p_inc):
        """Two-level scheme: sub-chunk state recurrence (exact, over nsub
        steps) + (r,r,K) pairwise diagonals only."""
        Bl, _, Hl, Kl = qi.shape
        Vl = vi.shape[-1]
        qs = qi.reshape(Bl, nsub, r, Hl, Kl)
        ks = ki.reshape(Bl, nsub, r, Hl, Kl)
        vs = vi.reshape(Bl, nsub, r, Hl, Vl)
        we = w_exp.reshape(Bl, nsub, r, Hl, Kl)
        pi = p_inc.reshape(Bl, nsub, r, Hl, Kl)
        # diagonal blocks: pairwise over r only
        dd = we[:, :, :, None] - pi[:, :, None, :]          # (B,n,r,r,H,K)
        dd = jnp.where(smask[None, None, :, :, None, None], dd, -jnp.inf)
        Ad = jnp.einsum("bnthk,bnshk,bntshk->bnhts", qs, ks, jnp.exp(dd))
        o = jnp.einsum("bnhts,bnshv->bnthv", Ad, vs)
        # cross-sub-chunk via an inner state recurrence (factored matmuls)
        p_end = pi[:, :, -1]                                # (B,n,H,K)
        p_end_prev = jnp.concatenate(
            [jnp.zeros_like(p_end[:, :1]), p_end[:, :-1]], 1)
        pe_delta = p_end - p_end_prev                       # within-sub decay
        k_dec = ks * jnp.exp(p_end[:, :, None] - pi)        # exp(<=0)
        kv_sub = jnp.einsum("bnrhk,bnrhv->bnhkv", k_dec, vs)
        q_dec = qs * jnp.exp(we - p_end_prev[:, :, None])
        # prefix state over sub-chunks (within this chunk, S0 = 0); the
        # state is always referenced to the END of the previous sub-chunk.
        def sub_step(Ssub, xs_):
            qd, pd, kvs = xs_
            o_s = jnp.einsum("brhk,bhkv->brhv", qd, Ssub)
            Ssub = jnp.exp(pd)[..., None] * Ssub + kvs
            return Ssub, o_s
        xs_ = (jnp.moveaxis(q_dec, 1, 0), jnp.moveaxis(pe_delta, 1, 0),
               jnp.moveaxis(kv_sub, 1, 0))
        _, o_cross = jax.lax.scan(
            sub_step, jnp.zeros((Bl, Hl, Kl, Vl), f32), xs_)
        o = o + jnp.moveaxis(o_cross, 0, 1)
        return o.reshape(Bl, c, Hl, Vl)

    intra = _intra_factored if (factored and nsub) else _intra_pairwise

    def chunk_step(S, xs):
        qi, ki, vi, ldi = (a.astype(f32) for a in xs)      # (B,c,H,*)
        p_inc = jnp.cumsum(ldi, axis=1)
        p_exc = p_inc - ldi
        w_exp = p_inc if u is None else p_exc
        o = intra(qi, ki, vi, w_exp, p_inc)
        if u is not None:                                   # bonus diagonal
            diag = jnp.einsum("bthk,hk,bthk->bth", qi, u.astype(f32), ki)
            o = o + diag[..., None] * vi
        # cross-chunk state contribution
        o = o + jnp.einsum("bthk,bhkv->bthv", qi * jnp.exp(w_exp), S)
        # state recurrence to chunk end
        p_last = p_inc[:, -1]                               # (B,H,K)
        k_dec = ki * jnp.exp(p_last[:, None] - p_inc)
        S = jnp.exp(p_last)[..., None] * S + jnp.einsum(
            "bthk,bthv->bhkv", k_dec, vi)
        return S, o.astype(dtype)

    state, o = jax.lax.scan(chunk_step, state0, (qc, kc, vc, ldc))
    o = jnp.moveaxis(o, 0, 1).reshape(B, n * c, H, V)[:, :T]
    return o, state


def linear_attention(q, k, v, ld, u=None, initial_state=None, *,
                     backend: str = "chunked", chunk: int = None,
                     factored: bool = False):
    """chunk None = auto: the pallas backend resolves its tile from the
    tuned-config cache (repro.kernels.tuning); chunked falls back to 64."""
    if backend == "recurrent":
        return recurrent_linear_attention(q, k, v, ld, u, initial_state)
    if backend == "chunked":
        return chunked_linear_attention(q, k, v, ld, u, initial_state,
                                        chunk=chunk if chunk else 64,
                                        factored=factored)
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.wkv6(q, k, v, ld, u, initial_state, chunk=chunk)
    raise ValueError(backend)


# ------------------------------------------------------------- RWKV6 layer
def rwkv6_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.ssm.head_size
    H = d // hs
    ks = jax.random.split(key, 10)
    s = 1.0 / np.sqrt(d)
    lora = max(32, d // 32)
    return {
        "mix": jnp.full((5, d), 0.5, jnp.float32),      # r,k,v,g,w token-shift mixes
        "wr": (jax.random.normal(ks[0], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),        # base log-log decay
        "wa": (jax.random.normal(ks[5], (d, lora)) * s).astype(dtype),
        "wb": (jax.random.normal(ks[6], (lora, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[7], (H, hs)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((H, hs), jnp.float32),     # per-head groupnorm
        "ln_bias": jnp.zeros((H, hs), jnp.float32),
    }


def _token_shift(x, prev):
    """prev: (B, d) last token of previous segment (zeros at start)."""
    shifted = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return shifted


def rwkv6_time_mix(p, x, cfg: ModelConfig, *, backend: str,
                   state=None, shift_prev=None, factored: bool = False,
                   chunk: int = None):
    """x: (B,T,d). Returns (out, (wkv_state, last_token)).

    chunk None = auto: pallas resolves the tuned tile, other backends use
    cfg.ssm.chunk_size; an explicit value overrides both."""
    B, T, d = x.shape
    hs = cfg.ssm.head_size
    H = d // hs
    prev = shift_prev if shift_prev is not None else jnp.zeros((B, d), x.dtype)
    xx = _token_shift(x, prev)
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (xx - x) * mix[i] for i in range(5))
    r = (xr @ p["wr"]).reshape(B, T, H, hs)
    k = (xk @ p["wk"]).reshape(B, T, H, hs)
    v = (xv @ p["wv"]).reshape(B, T, H, hs)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent per-channel log decay (LoRA), always negative
    ld = -jnp.exp(p["w0"] + jnp.tanh(xw @ p["wa"]) @ p["wb"])
    ld = jnp.clip(ld, -12.0, -1e-4).reshape(B, T, H, hs)
    if chunk is None and backend != "pallas":
        chunk = cfg.ssm.chunk_size
    o, new_state = linear_attention(r, k, v, ld, u=p["u"],
                                    initial_state=state, backend=backend,
                                    chunk=chunk, factored=factored)
    # per-head group norm
    of = o.astype(jnp.float32)
    mean = of.mean(-1, keepdims=True)
    var = ((of - mean) ** 2).mean(-1, keepdims=True)
    of = (of - mean) * jax.lax.rsqrt(var + 1e-5) * p["ln_scale"] + p["ln_bias"]
    out = (of.reshape(B, T, d).astype(x.dtype) * g) @ p["wo"]
    return out, (new_state, x[:, -1])


def rwkv6_channel_mix_init(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix": jnp.full((2, d), 0.5, jnp.float32),
        "wk": (jax.random.normal(ks[0], (d, f)) / np.sqrt(d)).astype(dtype),
        "wv": (jax.random.normal(ks[1], (f, d)) / np.sqrt(f)).astype(dtype),
        "wr": (jax.random.normal(ks[2], (d, d)) / np.sqrt(d)).astype(dtype),
    }


def rwkv6_channel_mix(p, x, *, shift_prev=None):
    B, T, d = x.shape
    prev = shift_prev if shift_prev is not None else jnp.zeros((B, d), x.dtype)
    xx = _token_shift(x, prev)
    mix = p["mix"].astype(x.dtype)
    xk = x + (xx - x) * mix[0]
    xr = x + (xx - x) * mix[1]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"]), x[:, -1]


# ------------------------------------------------------------- SSD (hymba)
def ssd_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.ssm.head_size * max(1, cfg.d_model // cfg.ssm.head_size)
    dm = cfg.d_model
    H = d // cfg.ssm.head_size
    N = cfg.ssm.state_size
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(dm)
    return {
        "wx": (jax.random.normal(ks[0], (dm, d)) * s).astype(dtype),
        "wz": (jax.random.normal(ks[1], (dm, d)) * s).astype(dtype),
        "wB": (jax.random.normal(ks[2], (dm, H * N)) * s).astype(dtype),
        "wC": (jax.random.normal(ks[3], (dm, H * N)) * s).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (dm, H)) * s).astype(dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "wo": (jax.random.normal(ks[5], (d, dm)) / np.sqrt(d)).astype(dtype),
    }


def ssd_mix(p, x, cfg: ModelConfig, *, backend: str, state=None,
            factored: bool = False, chunk: int = None):
    """Mamba-2-style SSD head mix. x:(B,T,dm) -> (out, state).
    chunk: see rwkv6_time_mix."""
    B, T, dm = x.shape
    hs = cfg.ssm.head_size
    N = cfg.ssm.state_size
    H = p["wx"].shape[1] // hs
    xin = (x @ p["wx"]).reshape(B, T, H, hs)
    z = jax.nn.silu(x @ p["wz"])
    Bm = (x @ p["wB"]).reshape(B, T, H, N)
    Cm = (x @ p["wC"]).reshape(B, T, H, N)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    ld = (-dt * jnp.exp(p["A_log"]))[..., None]           # (B,T,H,1) scalar/head
    ld = jnp.broadcast_to(jnp.clip(ld, -12.0, -1e-6), (B, T, H, N))
    k = Bm * dt[..., None].astype(Bm.dtype)               # discretized input
    if chunk is None and backend != "pallas":
        chunk = cfg.ssm.chunk_size
    o, new_state = linear_attention(Cm, k, xin, ld, u=None,
                                    initial_state=state, backend=backend,
                                    chunk=chunk, factored=factored)
    o = o + p["D"][:, None] * xin.astype(jnp.float32)
    out = (o.reshape(B, T, H * hs).astype(x.dtype) * z) @ p["wo"]
    return out, new_state
