"""Attention: GQA projections + three interchangeable score backends.

Backends
--------
* ``dense``   — materializes the (S, S) score matrix. Smoke tests only.
* ``chunked`` — blockwise online-softmax attention in pure jnp. Outer python
  loop over query chunks (static), inner ``lax.scan`` over kv chunks, so only
  the causal lower triangle of blocks is ever computed and peak memory is
  O(chunk^2) — this is the CPU-lowerable stand-in for the Pallas kernel and
  the backend the multi-pod dry-run compiles.
* ``pallas``  — the TPU flash-attention kernel from ``repro.kernels``.

All backends take q:(B,S,Hq,D), k/v:(B,Sk,Hkv,D) with Hq a multiple of Hkv
(grouped-query attention) and never materialize repeated KV heads.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, nq * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (nq * hd, d)) / np.sqrt(nq * hd)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def project_qkv(p, xq, xkv, cfg: ModelConfig):
    """Returns q:(B,S,Hq,D), k,v:(B,Sk,Hkv,D)."""
    hd = cfg.resolved_head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*q.shape[:-1], cfg.num_heads, hd)
    k = k.reshape(*k.shape[:-1], cfg.num_kv_heads, hd)
    v = v.reshape(*v.shape[:-1], cfg.num_kv_heads, hd)
    return q, k, v


# ------------------------------------------------------------------ dense
def dense_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0) -> jnp.ndarray:
    """Reference/smoke backend. Handles GQA by reshaping q into groups."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(D)
    scores = scores.astype(jnp.float32)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, Sq, Hq, D)


# ------------------------------------------------------------------ chunked
def _block_mask(qpos, kpos, causal, window):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 1024) -> jnp.ndarray:
    """Blockwise flash-style attention; computes only blocks that can
    contain unmasked entries."""
    B, S, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    c = min(chunk, S, Sk)
    # pad to multiple of c
    pad_q = (-S) % c
    pad_k = (-Sk) % c
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // c, k.shape[1] // c
    qg = q.reshape(B, nq, c, Hkv, g, D)
    kc = k.reshape(B, nk, c, Hkv, D)
    vc = v.reshape(B, nk, c, Hkv, D)
    scale = 1.0 / np.sqrt(D)

    outs = []
    for i in range(nq):  # static outer loop -> only needed blocks compiled
        qi = qg[:, i] * scale                       # (B,c,Hkv,g,D)
        jlo = 0
        jhi = min(i + 1, nk) if causal else nk
        if window:
            jlo = max(0, (i * c - window + 1) // c)  # chunk of earliest visible kpos
        qpos = jnp.arange(c) + i * c

        def kv_step(carry, xs):
            acc, m_run, l_run = carry
            kj, vj, j = xs
            kpos = j * c + jnp.arange(c)
            s = jnp.einsum("bchgd,bkhd->bhgck", qi, kj).astype(jnp.float32)
            mask = _block_mask(qpos, kpos, causal, window)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgck,bkhd->bhgcd", p.astype(qi.dtype), vj).astype(jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, Hkv, g, c, D), jnp.float32)
        m0 = jnp.full((B, Hkv, g, c), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, c), jnp.float32)
        js = jnp.arange(jlo, jhi)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kc[:, jlo:jhi].swapaxes(0, 1), vc[:, jlo:jhi].swapaxes(0, 1), js))
        oi = acc / jnp.maximum(l_run[..., None], 1e-30)
        outs.append(oi.transpose(0, 3, 1, 2, 4).reshape(B, c, Hq, D))
    out = jnp.concatenate(outs, axis=1)[:, :S]
    return out.astype(q.dtype)


# ------------------------------------------------------------------ decode
def decode_attention_simple(q, k_cache, v_cache, cache_len) -> jnp.ndarray:
    """One-token decode against a full cache. q:(B,1,Hq,D),
    caches:(B,Smax,Hkv,D); positions >= cache_len are masked. cache_len is
    a scalar (lockstep batch) or a (B,) vector (continuous batching: each
    slot carries its own valid length)."""
    B, _, Hq, D = q.shape
    _, Sk, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache) / np.sqrt(D)
    s = s.astype(jnp.float32)
    valid = jnp.arange(Sk)[None, :] < jnp.reshape(cache_len, (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache)
    return o.reshape(B, 1, Hq, D)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables,
                               lengths) -> jnp.ndarray:
    """Reference paged decode attention: gather the per-row pages into a
    contiguous logical cache and reuse :func:`decode_attention_simple`.
    q:(B,1,Hq,D); k_pages/v_pages:(P,ps,Hkv,D); block_tables:(B,npag)
    physical page ids in logical order; lengths:(B,) valid KV tokens.

    Gathered logical order == position order, so the masked positions and
    the softmax summation order match both the monolithic decode path and
    the Pallas kernel (which gathers inside the kernel instead)."""
    B = q.shape[0]
    P, ps, Hkv, D = k_pages.shape
    npag = block_tables.shape[1]
    k = k_pages[block_tables].reshape(B, npag * ps, Hkv, D)
    v = v_pages[block_tables].reshape(B, npag * ps, Hkv, D)
    return decode_attention_simple(q, k, v, lengths)


def attention(q, k, v, *, backend: str, causal: bool, window: int = 0,
              chunk: int = 1024, block_q: int = None,
              block_k: int = None) -> jnp.ndarray:
    """block_q/block_k only apply to the pallas backend; None = auto
    (resolved from the tuned-config cache, see repro.kernels.tuning)."""
    if backend == "dense":
        return dense_attention(q, k, v, causal=causal, window=window)
    if backend == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 chunk=chunk)
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    block_q=block_q, block_k=block_k)
    raise ValueError(f"unknown attention backend {backend!r}")
