from repro.models.model import Model, build
from repro.models.transformer import Runtime

__all__ = ["Model", "Runtime", "build"]
