"""Mixture-of-Experts FFN with sort-based (dropping) token dispatch.

Dispatch is gather/scatter-based, NOT the one-hot-einsum GShard form: the
einsum dispatch costs 2*T*d*E*C flops which, at 128 experts, exceeds the
expert FFN compute by >50x and would poison the roofline. Sorting tokens by
expert id and gathering into capacity buffers keeps dispatch compute
negligible, matching how MegaBlocks-style systems behave.

Expert weights are stacked (E, d, f) so the expert dimension can shard over
the ``model`` mesh axis (expert parallelism).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.parallel.compat import axis_size, shard_map


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.expert_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e.num_experts)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e.num_experts, d, f)) * s_in).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e.num_experts, d, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (e.num_experts, f, d)) * s_out).astype(dtype),
    }
    if e.dense_residual_ff:
        from repro.models.layers import mlp_init
        p["dense"] = mlp_init(ks[4], d, e.dense_residual_ff, cfg.activation, dtype)
    return p


def capacity(tokens: int, e: MoEConfig) -> int:
    c = int(np.ceil(tokens * e.top_k / e.num_experts * e.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU lane alignment


def _constrain(t, spec):
    from repro.parallel.sharding import maybe_constrain
    return maybe_constrain(t, spec)


def moe_ffn_shardmap(p, x, cfg: ModelConfig, rt):
    """Expert-parallel MoE via shard_map (the TPU-native dispatch).

    Device (i, j) — data shard i, model shard j — already holds data shard
    i's activations replicated over j, so dispatch is a LOCAL masked gather
    of the tokens routed to j's experts (capacity budgeted per data shard,
    as real EP systems do). Expert weights stream in with an explicit
    all-gather over the data axes (ZeRO-3), and outputs combine with one
    psum over `model`. No global scatter ever hits the SPMD partitioner —
    XLA's auto-dispatch replicated multi-GB (T*K, d) buffers on every
    device (measured: +6.5 GB/device on arctic-480b).
    """
    from jax.sharding import PartitionSpec as P
    e = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = e.num_experts, e.top_k
    dp = tuple(rt.mesh_batch_axes)
    dp_size = rt.dp_size
    T_loc = T // max(dp_size, 1)
    C_loc = max(8, -(-int(np.ceil(T_loc * K / E * e.capacity_factor)) // 8) * 8)

    xf = x.reshape(T, d)
    logits = (xf.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = (gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                         1e-9)).astype(x.dtype)

    ep_axes = tuple(getattr(rt, "ep_axes", ("model",)))

    def local_fn(xl, eidx, gates, w_in, w_gate, w_out):
        E_loc = w_in.shape[0]
        # combined expert-shard index over the (possibly multi-axis) EP axes
        j = jnp.int32(0)
        for a in ep_axes:
            j = j * axis_size(a) + jax.lax.axis_index(a)
        lo = j * E_loc
        if dp:  # ZeRO-3: stream the full expert weights for this model shard
            w_in = jax.lax.all_gather(w_in, dp, axis=1, tiled=True)
            w_gate = jax.lax.all_gather(w_gate, dp, axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, dp, axis=2, tiled=True)
        t_loc = xl.shape[0]
        flat_e = eidx.reshape(-1)
        flat_g = gates.reshape(-1)
        tok = jnp.repeat(jnp.arange(t_loc), K)
        rel = jnp.where((flat_e >= lo) & (flat_e < lo + E_loc),
                        flat_e - lo, E_loc)
        order = jnp.argsort(rel)
        se, sg, st = rel[order], flat_g[order], tok[order]
        seg_start = jnp.searchsorted(se, jnp.arange(E_loc), side="left")
        pos = jnp.arange(t_loc * K) - seg_start[jnp.minimum(se, E_loc - 1)]
        keep = (se < E_loc) & (pos < C_loc)
        dest = jnp.where(keep, se * C_loc + pos, E_loc * C_loc)
        buf = jnp.zeros((E_loc * C_loc + 1, d), xl.dtype)
        buf = buf.at[dest].set(jnp.where(keep[:, None], xl[st], 0))
        buf = buf[: E_loc * C_loc].reshape(E_loc, C_loc, d)
        h = jnp.einsum("ecd,edf->ecf", buf, w_in)
        if cfg.activation == "swiglu":
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * h
        else:
            h = jax.nn.gelu(h, approximate=True)
        ob = jnp.einsum("ecf,efd->ecd", h, w_out).reshape(E_loc * C_loc, d)
        y_rows = ob[jnp.where(keep, dest, 0)] * (sg * keep)[:, None].astype(xl.dtype)
        y = jnp.zeros((t_loc, d), xl.dtype).at[st].add(y_rows)
        y = jax.lax.psum(y, ep_axes)
        load = jnp.zeros((E_loc,)).at[jnp.minimum(se, E_loc - 1)].add(
            keep.astype(jnp.float32))
        if dp:
            load = jax.lax.psum(load, dp)
        return y, load

    dps = dp if dp else None
    eps = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    y, load = shard_map(
        local_fn,
        in_specs=(P(dps, None), P(dps, None), P(dps, None),
                  P(eps, dps, None), P(eps, dps, None),
                  P(eps, None, dps)),
        out_specs=(P(dps, None), P(eps)),
        check_vma=False,
    )(xf, expert_idx, gate_vals, p["w_in"], p["w_gate"], p["w_out"])
    y = y.reshape(B, S, d)

    if e.dense_residual_ff:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["dense"], x, cfg.activation)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    aux_loss = E * jnp.sum(me * ce) * e.router_aux_weight
    aux = {"aux_loss": aux_loss, "expert_load": load, "capacity": C_loc}
    return y, aux


def moe_ffn(p, x, cfg: ModelConfig, rt=None):
    if rt is not None and getattr(rt, "moe_shardmap", False):
        return moe_ffn_shardmap(p, x, cfg, rt)
    return _moe_ffn_dense(p, x, cfg, rt)


def _moe_ffn_dense(p, x, cfg: ModelConfig, rt=None):
    """x: (B, S, d) -> (out, aux) where aux has the router load stats used by
    the Tier-1 load-imbalance metric and the aux loss.

    Sharding: token-major tensors (T*K, d) shard rows over the batch axes;
    expert-capacity buffers (E, C, d) shard E over `model` (aligned with the
    expert weights) — without these constraints XLA replicates multi-GB
    dispatch buffers on every device."""
    from jax.sharding import PartitionSpec as P
    tok_spec = cap_spec = None
    if rt is not None and rt.act_spec is not None and rt.act_spec[0] is not None:
        tok_spec = P(rt.act_spec[0], None)
        cap_spec = P("model", None, None)
    e = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    E, K = e.num_experts, e.top_k
    C = capacity(T, e)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- flatten (token, k) pairs and sort by expert ----------------------
    flat_expert = expert_idx.reshape(-1)                       # (T*K,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_expert)
    se, sg, st = flat_expert[order], flat_gate[order], flat_token[order]
    # position within expert segment
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")  # (E,)
    pos = jnp.arange(T * K) - seg_start[se]
    keep = pos < C
    dest = jnp.where(keep, se * C + pos, E * C)                # overflow slot

    # ---- gather into capacity buffers -------------------------------------
    rows_in = _constrain(jnp.where(keep[:, None], xf[st], 0), tok_spec)
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[dest].set(rows_in)
    buf = _constrain(buf[: E * C].reshape(E, C, d), cap_spec)

    # ---- expert FFN (E sharded over model axis) ----------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    out_buf = _constrain(out_buf, cap_spec).reshape(E * C, d)

    # ---- combine back -------------------------------------------------------
    rows = jnp.where(keep, dest, 0)
    y_rows = out_buf[rows] * (sg * keep)[:, None].astype(x.dtype)
    y_rows = _constrain(y_rows, tok_spec)
    y = jnp.zeros((T, d), x.dtype).at[st].add(y_rows)
    y = y.reshape(B, S, d)

    if e.dense_residual_ff:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["dense"], x, cfg.activation)

    # ---- aux: load-balance loss (Switch) + per-expert load -----------------
    me = jnp.mean(probs, axis=0)                               # router prob mass
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], E)
    ce = jnp.mean(one_hot_top1, axis=0)                        # fraction routed
    aux_loss = E * jnp.sum(me * ce) * e.router_aux_weight
    expert_load = jnp.zeros((E,)).at[se].add(keep.astype(jnp.float32))
    aux = {"aux_loss": aux_loss, "expert_load": expert_load, "capacity": C}
    return y, aux
