"""Top-level model API: build(cfg, rt) -> Model with init/loss/prefill/decode.

Input contract per family (see DESIGN.md):
* dense/moe/ssm/hybrid : batch = {tokens:(B,S) i32, labels:(B,S) i32}
* vlm / early-fusion   : batch = {embeds:(B,S,d), positions:(B,3,S) i32,
                         labels:(B,S) i32}   (patch frontend stubbed)
* audio (whisper)      : batch = {audio_embeds:(B,S,d), tokens:(B,S) i32,
                         labels:(B,S) i32}   (conv/mel frontend stubbed)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import (
    embed_tokens,
    embedding_init,
    lm_logits,
    norm_init,
    apply_norm,
    sinusoidal_positions,
)
from repro.models.transformer import Runtime


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rt: Runtime
    init_params: Callable
    loss: Callable          # (params, batch) -> (loss, aux)
    prefill: Callable       # (params, batch, cache_span) -> (logits, caches)
    decode_step: Callable   # (params, caches, token_batch, pos) -> (logits, caches)
    cache_init: Callable    # (batch,max_len,dtype) -> zeroed caches
    # paged-KV serving triple (full-attention decoder-only models; the
    # builders raise for families without a paged path):
    # (params, caches, tokens, block_tables, start_pos) -> (logits, caches)
    prefill_chunk: Callable = None
    # (params, caches, token, pos, block_tables) -> (logits, caches)
    decode_step_paged: Callable = None
    # (num_pages, page_size, dtype) -> zeroed paged pools
    paged_cache_init: Callable = None


def build(cfg: ModelConfig, rt: Runtime, param_dtype=jnp.bfloat16) -> Model:
    compute_dtype = param_dtype

    # ----------------------------------------------------------- params
    def init_params(key):
        k_emb, k_dec, k_enc = jax.random.split(key, 3)
        p = {
            "embed": embedding_init(k_emb, cfg, param_dtype),
            "layers": tfm.stack_init(k_dec, cfg, cfg.num_layers, param_dtype,
                                     cross=cfg.is_enc_dec),
            "final_norm": norm_init(cfg.d_model, cfg.norm),
        }
        if cfg.is_enc_dec:
            p["enc_layers"] = tfm.stack_init(
                k_enc, cfg, cfg.encoder_layers, param_dtype)
            p["enc_norm"] = norm_init(cfg.d_model, cfg.norm)
        return p

    # ----------------------------------------------------------- helpers
    def _embed_inputs(params, batch):
        """Returns (x, positions) for the decoder stack."""
        if cfg.frontend == "vision_stub" and "embeds" in batch:
            x = batch["embeds"].astype(compute_dtype)
            if cfg.rope == "mrope":
                positions = batch["positions"]
            else:
                positions = jnp.arange(x.shape[1])[None]
            return x, positions
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens).astype(compute_dtype)
        S = x.shape[1]
        if cfg.rope == "sinusoidal":
            x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
            positions = jnp.arange(S)[None]
        elif cfg.rope == "mrope":
            positions = jnp.broadcast_to(
                jnp.arange(S)[None, None], (x.shape[0], 3, S))
        else:
            positions = jnp.arange(S)[None]
        return x, positions

    def _encode(params, batch):
        x = batch["audio_embeds"].astype(compute_dtype)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        x, _ = tfm.stack_apply(params["enc_layers"], x, cfg, rt,
                               jnp.arange(x.shape[1])[None], causal=False)
        return apply_norm(params["enc_norm"], x, cfg.norm)

    # ----------------------------------------------------------- loss
    def loss(params, batch):
        enc_out = _encode(params, batch) if cfg.is_enc_dec else None
        x, positions = _embed_inputs(params, batch)
        x, aux = tfm.stack_apply(params["layers"], x, cfg, rt, positions,
                                 enc_out=enc_out, causal=True)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_logits(params["embed"], x, cfg.tie_embeddings,
                           true_vocab=cfg.vocab_size)
        logits = logits.astype(jnp.float32)
        labels = batch["labels"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = (logz - gold).mean()
        total = nll + aux.get("aux_loss", 0.0)
        aux_out = {"nll": nll, **{k: v for k, v in aux.items()}}
        return total, aux_out

    # ----------------------------------------------------------- prefill
    def prefill(params, batch, cache_span: int):
        enc_out = _encode(params, batch) if cfg.is_enc_dec else None
        x, positions = _embed_inputs(params, batch)
        x, layer_caches = tfm.stack_prefill(params["layers"], x, cfg, rt,
                                            positions, enc_out=enc_out,
                                            cache_span=cache_span)
        caches = {"layers": layer_caches}
        if cfg.is_enc_dec:  # split cross-attention cache out of layer caches
            caches["cross"] = {"ck": layer_caches.pop("ck"),
                               "cv": layer_caches.pop("cv")}
        x_last = x[:, -1:]
        x_last = apply_norm(params["final_norm"], x_last, cfg.norm)
        logits = lm_logits(params["embed"], x_last, cfg.tie_embeddings,
                           true_vocab=cfg.vocab_size)
        return logits.astype(jnp.float32)[..., :cfg.vocab_size], caches

    # ----------------------------------------------------------- decode
    def _sinusoidal_at(pos):
        """Closed-form sinusoidal position embedding at runtime ``pos``
        (any 1-D position vector) -> (len(pos), d_model) f32."""
        d = cfg.d_model
        half_idx = jnp.arange(0, d, 2)
        pos_v = jnp.atleast_1d(jnp.asarray(pos, jnp.float32))
        ang = pos_v[:, None] / jnp.power(10000.0, half_idx / d)
        pe = jnp.zeros((pos_v.shape[0], d), jnp.float32)
        return pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))

    def decode_step(params, caches, token, pos):
        """token: (B,1) i32; pos: scalar i32 (next position to write) or a
        (B,) vector of per-row positions (continuous batching)."""
        x = embed_tokens(params["embed"], token).astype(compute_dtype)
        if cfg.rope == "sinusoidal":
            x = x + _sinusoidal_at(pos)[:, None].astype(x.dtype)
        cross = caches.get("cross")
        x, new_layer_caches = tfm.stack_decode(
            params["layers"], x, caches["layers"], pos, cfg, rt,
            cross_caches=cross)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_logits(params["embed"], x, cfg.tie_embeddings,
                           true_vocab=cfg.vocab_size)
        new_caches = dict(caches)
        new_caches["layers"] = new_layer_caches
        return logits.astype(jnp.float32)[..., :cfg.vocab_size], new_caches

    # ------------------------------------------------------ paged serving
    def prefill_chunk(params, caches, tokens, block_tables, start_pos):
        """One chunk of a chunked prefill. tokens: (B, C) i32 at absolute
        positions ``start_pos .. start_pos+C-1``; caches: paged pools from
        ``paged_cache_init``; block_tables: (B, n_pages). Returns the
        logits of the chunk's LAST position ((B, 1, V)) and the updated
        pools — feeding the prompt chunk-by-chunk fills pages
        incrementally and the final chunk's logits seed decoding, exactly
        like one-shot ``prefill``."""
        x = embed_tokens(params["embed"], tokens).astype(compute_dtype)
        C = tokens.shape[1]
        positions = start_pos + jnp.arange(C)
        if cfg.rope == "sinusoidal":
            x = x + _sinusoidal_at(positions)[None].astype(x.dtype)
        x, new_layer = tfm.stack_prefill_chunk(
            params["layers"], x, caches["layers"], block_tables, positions,
            cfg, rt)
        x_last = apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
        logits = lm_logits(params["embed"], x_last, cfg.tie_embeddings,
                           true_vocab=cfg.vocab_size)
        return logits.astype(jnp.float32)[..., :cfg.vocab_size], \
            {"layers": new_layer}

    def decode_step_paged(params, caches, token, pos, block_tables):
        """token: (B,1) i32; pos: (B,) next position per row;
        block_tables: (B, n_pages) physical page ids."""
        x = embed_tokens(params["embed"], token).astype(compute_dtype)
        if cfg.rope == "sinusoidal":
            x = x + _sinusoidal_at(pos)[:, None].astype(x.dtype)
        x, new_layer = tfm.stack_decode_paged(
            params["layers"], x, caches["layers"], pos, block_tables, cfg,
            rt)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_logits(params["embed"], x, cfg.tie_embeddings,
                           true_vocab=cfg.vocab_size)
        return logits.astype(jnp.float32)[..., :cfg.vocab_size], \
            {"layers": new_layer}

    def paged_cache_init(num_pages: int, page_size: int,
                         dtype=param_dtype):
        return {"layers": tfm.paged_cache_init(cfg, cfg.num_layers,
                                               num_pages, page_size,
                                               dtype)}

    # ----------------------------------------------------------- caches
    def cache_init(batch: int, max_len: int, dtype=param_dtype,
                   enc_len: int = 0):
        caches = {"layers": tfm.cache_init(cfg, cfg.num_layers, batch,
                                           max_len, dtype)}
        if cfg.is_enc_dec:
            enc_len = enc_len or max_len
            hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
            caches["cross"] = {
                "ck": jnp.zeros((cfg.num_layers, batch, enc_len, nkv, hd),
                                dtype),
                "cv": jnp.zeros((cfg.num_layers, batch, enc_len, nkv, hd),
                                dtype),
            }
        return caches

    return Model(cfg=cfg, rt=rt, init_params=init_params, loss=loss,
                 prefill=prefill, decode_step=decode_step,
                 cache_init=cache_init, prefill_chunk=prefill_chunk,
                 decode_step_paged=decode_step_paged,
                 paged_cache_init=paged_cache_init)
