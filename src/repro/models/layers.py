"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings.

Everything is functional: ``*_init(key, cfg) -> params`` and a matching
apply function. Params are plain nested dicts so they can be stacked along
a leading layer axis and scanned.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# M-RoPE head-dim half split into (temporal, height, width) sections,
# per Qwen2-VL (arXiv:2409.12191).
MROPE_SECTIONS = (16, 24, 24)


def _dtype(cfg_dtype: str):
    return jnp.dtype(cfg_dtype)


# ---------------------------------------------------------------- norms
def norm_init(d: int, kind: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-5):
    """Norm in f32, output in input dtype."""
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mean = xf.mean(-1, keepdims=True)
        var = ((xf - mean) ** 2).mean(-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): rotary angle sections come from three
    position streams (t, h, w).

    x: (B, S, H, D); positions3: (B, 3, S).
    """
    half = x.shape[-1] // 2
    if sum(MROPE_SECTIONS) == half:
        sections = MROPE_SECTIONS
    else:  # reduced configs: keep the (1/4, 3/8, 3/8) proportions
        s0 = half // 4
        s1 = (half - s0) // 2
        sections = (s0, s1, half - s0 - s1)
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    # angles per stream: (B, 3, S, half)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[:, i, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                     # (B, S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------- mlp
def mlp_init(key, d: int, f: int, activation: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(f)
    p = {
        "w_in": (jax.random.normal(k1, (d, f)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (f, d)) * scale_out).astype(dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * scale_in).astype(dtype)
    return p


def apply_mlp(p, x, activation: str):
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    elif activation == "gelu":
        h = jax.nn.gelu(x @ p["w_in"], approximate=True)
    elif activation == "rwkv":  # squared-relu channel-mix (no gate matrix here)
        h = jnp.square(jax.nn.relu(x @ p["w_in"]))
    else:
        raise ValueError(activation)
    return h @ p["w_out"]


# ---------------------------------------------------------------- embedding
VOCAB_PAD = 256  # pad vocab so it always divides the model axis (MaxText-style)


def padded_vocab(vocab_size: int) -> int:
    return -(-vocab_size // VOCAB_PAD) * VOCAB_PAD


def embedding_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    pv = padded_vocab(cfg.vocab_size)
    p = {"tok": (jax.random.normal(k1, (pv, cfg.d_model)) * 0.02)
         .astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(k2, (cfg.d_model, pv))
                     * 0.02).astype(dtype)
    return p


def embed_tokens(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(p, x, tie: bool, true_vocab: int = 0):
    """Logits over the PADDED vocab; padded entries masked to -inf when
    true_vocab is given."""
    logits = x @ p["tok"].T if tie else x @ p["head"]
    if true_vocab and logits.shape[-1] != true_vocab:
        mask = jnp.arange(logits.shape[-1]) < true_vocab
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits
