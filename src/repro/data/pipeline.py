"""Deterministic synthetic LM data pipeline.

Requirements this satisfies for the fault-tolerance story:
* fully deterministic as a function of (seed, step) — a restarted job
  resumes mid-stream with NO data-state in the checkpoint;
* shardable — each data-parallel host materializes only its batch slice;
* packed sequences with document boundaries (EOS-delimited), so the loss
  sees realistic token statistics rather than uniform noise;
* double-buffered prefetch thread so host data generation overlaps device
  compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _doc_tokens(rng: np.random.Generator, vocab: int, length: int,
                zipf_a: float = 1.3) -> np.ndarray:
    """Zipf-ish token stream (closer to text statistics than uniform)."""
    toks = rng.zipf(zipf_a, size=length).astype(np.int64)
    return (toks % max(vocab - 2, 1)) + 1        # reserve 0=EOS


# --------------------------------------------------------------- serving
def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0) -> np.ndarray:
    """Cumulative arrival times (seconds) of a Poisson process at
    ``rate_per_s`` requests/s — the offered-load model the Tier-2 serving
    sweeps drive. ``rate_per_s <= 0`` means a burst: everything at t=0."""
    if n <= 0:
        return np.zeros(0, np.float64)
    if rate_per_s <= 0:
        return np.zeros(n, np.float64)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))


def synth_requests(cfg: ModelConfig, n: int, prompt_len: int, *,
                   max_new_tokens=16, rate_per_s: float = 0.0,
                   seed: int = 0) -> list:
    """Deterministic synthetic request stream for the serving engines.

    Prompts reuse the Zipf document sampler (EOS id 0 never appears in a
    prompt). ``max_new_tokens`` may be an int or a sequence cycled across
    requests (mixed decode budgets are what separate continuous from
    static scheduling). Arrivals are Poisson at ``rate_per_s`` (<=0 for a
    burst at t=0).
    """
    from repro.serving.request import Request

    budgets = ([int(max_new_tokens)] if np.isscalar(max_new_tokens)
               else [int(b) for b in max_new_tokens])
    arrivals = poisson_arrivals(n, rate_per_s, seed=seed * 9176 + 1)
    out = []
    for i in range(n):
        rng = np.random.default_rng(seed * 1_000_003 + i)
        prompt = _doc_tokens(rng, cfg.vocab_size, prompt_len
                             ).astype(np.int32)
        out.append(Request(rid=i, prompt=prompt,
                           max_new_tokens=budgets[i % len(budgets)],
                           arrival_s=float(arrivals[i])))
    return out


def synth_sessions(cfg: ModelConfig, num_sessions: int, turns: int, *,
                   system_len: int = 32, turn_len: int = 16,
                   max_new_tokens: int = 16, rate_per_s: float = 0.0,
                   think_s: float = 0.0, stagger_s: float = 0.0,
                   seed: int = 0) -> list:
    """Deterministic multi-turn chat sessions for the prefix-cache story.

    Every session shares one ``system_len``-token system prompt; turn
    ``t`` of a session arrives with the *accumulated history* — system
    prompt plus user turns ``0..t`` (``turn_len`` fresh tokens each) —
    exactly the replay pattern a stateless chat API produces. Turn
    ``t``'s prompt therefore extends turn ``t-1``'s prompt, so a
    prefix-sharing engine re-prefills only the newest turn while a cold
    engine re-pays the whole history every time.

    Session starts are Poisson at ``rate_per_s`` (<=0: all at t=0),
    shifted by a deterministic ``stagger_s`` gap between consecutive
    sessions (the SimClock scenarios use the stagger instead of random
    arrivals so latency orderings stay schedule-determined); within a
    session, turn ``t`` arrives ``think_s`` seconds after turn ``t-1``
    (user think time). Request ids encode ``session * 100 + turn`` so
    reports can split warm/cold by turn. The returned list is sorted by
    arrival time, as the engines expect.
    """
    from repro.serving.request import Request

    rng = np.random.default_rng(seed * 48_271 + 11)
    system = _doc_tokens(rng, cfg.vocab_size, system_len).astype(np.int32)
    starts = poisson_arrivals(num_sessions, rate_per_s,
                              seed=seed * 9176 + 7)
    starts = starts + np.arange(num_sessions) * stagger_s
    out = []
    for s in range(num_sessions):
        srng = np.random.default_rng(seed * 1_000_003 + 31 * s + 17)
        history = system
        for t in range(turns):
            user = _doc_tokens(srng, cfg.vocab_size, turn_len
                               ).astype(np.int32)
            history = np.concatenate([history, user])
            out.append(Request(rid=s * 100 + t, prompt=history.copy(),
                               max_new_tokens=max_new_tokens,
                               arrival_s=float(starts[s]) + t * think_s))
    out.sort(key=lambda r: (r.arrival_s, r.rid))
    return out


@dataclass
class SyntheticLM:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    mean_doc_len: int = 512

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        """Deterministic batch for `step`; only rows of `shard` are built."""
        if self.batch % num_shards:
            raise ValueError(
                f"batch={self.batch} must divide evenly over "
                f"num_shards={num_shards}")
        rows_per = self.batch // num_shards
        out = np.empty((rows_per, self.seq + 1), np.int32)
        for r in range(rows_per):
            row_global = shard * rows_per + r
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 100_003 + row_global)
            buf = []
            while sum(len(b) for b in buf) < self.seq + 1:
                n = max(8, int(rng.exponential(self.mean_doc_len)))
                buf.append(_doc_tokens(rng, self.cfg.vocab_size, n))
                buf.append(np.zeros(1, np.int64))   # EOS
            row = np.concatenate(buf)[: self.seq + 1]
            out[r] = row
        batch = {
            "tokens": jnp.asarray(out[:, :-1]),
            "labels": jnp.asarray(out[:, 1:]),
        }
        if self.cfg.frontend == "vision_stub":
            rng = np.random.default_rng(self.seed * 7 + step)
            batch["embeds"] = jnp.asarray(
                rng.standard_normal((rows_per, self.seq, self.cfg.d_model))
                * 0.02, jnp.bfloat16)
            if self.cfg.rope == "mrope":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(self.seq)[None, None],
                    (rows_per, 3, self.seq)).astype(jnp.int32)
            batch.pop("tokens")
        elif self.cfg.frontend == "audio_stub":
            rng = np.random.default_rng(self.seed * 7 + step)
            batch["audio_embeds"] = jnp.asarray(
                rng.standard_normal((rows_per, self.seq, self.cfg.d_model))
                * 0.02, jnp.bfloat16)
        return batch

    def iterate(self, start_step: int = 0, prefetch: int = 2,
                shard: int = 0, num_shards: int = 1) -> Iterator[dict]:
        """Prefetching iterator (producer thread + bounded queue).

        ``shard``/``num_shards`` reach :meth:`batch_at`, so a
        data-parallel host materializes only its batch slice instead of
        the full global batch. Each step's batch is built exactly once —
        a full queue blocks the producer on ``put`` rather than
        recomputing the batch on every retry — and closing the generator
        joins the producer thread instead of leaving it spinning."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                item = self.batch_at(step, shard=shard,
                                     num_shards=num_shards)
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.1)
                        step += 1
                        break
                    except queue.Full:
                        continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
            # unblock a producer stuck on a full queue, then join it
            while not q.empty():
                try:
                    q.get_nowait()
                except queue.Empty:   # producer may race the drain
                    break
            t.join(timeout=5.0)
