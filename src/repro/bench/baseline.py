"""Blessed performance baselines for the benchmark harness.

A *baseline* is a previously blessed :class:`BenchRecord` that future runs
are diffed against (:mod:`repro.bench.compare`). Baselines are keyed by
(record name, backend, env fingerprint):

* one JSONL file per backend under ``results/baselines/<backend>.jsonl``
  (``REPRO_BASELINE_DIR`` or ``--baseline-dir`` relocates the directory);
* within a file, one record per measurement name — blessing merges by
  name, overwriting the stale entry and keeping everything else;
* each stored record carries its env fingerprint; the compare layer skips
  (never fails) a pair whose fingerprints disagree, so a baseline blessed
  on one host/toolchain can never fail a run on another.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

from repro.bench.record import BenchRecord, read_jsonl, write_jsonl

DEFAULT_BASELINE_DIR = Path("results") / "baselines"

# The env-fingerprint keys that must agree for two records to be
# comparable. A key missing on either side does not count as a mismatch
# (older records carry fewer keys).
FINGERPRINT_KEYS = (
    "python",
    "platform",
    "machine",
    "cpu",
    "jax",
    "backend",
    "device_count",
)


def baseline_dir(override: Optional[str] = None) -> Path:
    """Resolve the baseline directory: explicit arg > env var > default."""
    if override:
        return Path(override)
    return Path(os.environ.get("REPRO_BASELINE_DIR", str(DEFAULT_BASELINE_DIR)))


def record_backend(rec: BenchRecord) -> str:
    return str(rec.env.get("backend", "cpu"))


def baseline_path(directory: Path, backend: str) -> Path:
    return Path(directory) / f"{backend}.jsonl"


def fingerprint(env: Dict[str, Any]) -> Dict[str, Any]:
    """The comparable subset of an env fingerprint."""
    return {k: env[k] for k in FINGERPRINT_KEYS if k in env}


def fingerprint_compatible(a: Dict[str, Any], b: Dict[str, Any]) -> bool:
    """True unless a key present on both sides disagrees."""
    for k in FINGERPRINT_KEYS:
        if k in a and k in b and a[k] != b[k]:
            return False
    return True


def load_baselines(
    directory: Path,
    backend: str = "cpu",
) -> Dict[str, BenchRecord]:
    """name -> blessed record for one backend; {} if never blessed."""
    path = baseline_path(directory, backend)
    if not path.exists():
        return {}
    return {rec.name: rec for rec in read_jsonl(path)}


def blessable(records: Iterable[BenchRecord]) -> List[BenchRecord]:
    """The subset of records worth persisting as baselines: successful,
    actually timed measurements (analytic / error / zero-time records
    would only ever compare as skips)."""
    return [
        r
        for r in records
        if r.status == "ok" and (r.us_per_call > 0 or r.p50_us > 0)
    ]


def bless(
    records: Iterable[BenchRecord],
    directory: Path,
) -> Dict[str, Path]:
    """Persist ``records`` as blessed baselines, merging by name into the
    per-backend file (existing entries for other names are kept; entries
    for the same name are overwritten). Returns backend -> file written.
    """
    by_backend: Dict[str, List[BenchRecord]] = {}
    for rec in blessable(records):
        by_backend.setdefault(record_backend(rec), []).append(rec)
    written: Dict[str, Path] = {}
    for backend, recs in sorted(by_backend.items()):
        merged = load_baselines(directory, backend)
        for rec in recs:
            merged[rec.name] = rec
        path = baseline_path(Path(directory), backend)
        write_jsonl([merged[k] for k in sorted(merged)], path)
        written[backend] = path
    return written
