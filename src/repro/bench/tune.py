"""Kernel autotuner: sweep Pallas tiling configs, persist the winners.

DABench-LLM's core observation is that dataflow-accelerator performance
hinges on resource allocation and tile/block mapping, and that the
benchmark harness should *drive* those choices. This module closes the
loop: for each (kernel, shape-signature, dtype, backend) it

1. enumerates candidate tile configs (attention ``block_q``/``block_k``,
   wkv6 ``chunk``, rmsnorm ``block_rows``),
2. rejects candidates that violate MXU alignment or the VMEM-budget
   model computed from the block shapes (:func:`*_vmem_bytes`),
3. times the survivors with the harness timer (``timeit_us``; injectable
   for deterministic tests — the timed closure carries its config in
   ``fn.keywords`` so a fake timer can key on it),
4. picks the fastest config and persists it via
   :mod:`repro.kernels.tuning` to ``results/tuned/<backend>.json``.

When the default config is valid for the shape it is candidate 0, and
ties resolve to the earliest candidate, so a tuned config can never
regress the default on the swept shape — "tuned >= default" holds by
construction. A default the shape can't tile (or the budget rejects) is
skipped, not mislabeled: the result reports a neutral speedup of 1.0
with ``default_timed=False``.

Run it through the harness: ``python -m benchmarks.run --tune`` executes
the ``@scenario``-registered sweeps in :mod:`benchmarks.bench_tune`, so
tuned-vs-default deltas land in ``results/bench/latest.jsonl`` as
first-class :class:`~repro.bench.record.BenchRecord` rows.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.bench.runner import timeit_us
from repro.kernels import tuning

# ------------------------------------------------------------ VMEM model
VMEM_BYTES = 16 * 2 ** 20      # per-core VMEM on current TPUs
VMEM_SLACK = 0.9               # headroom for Mosaic spills/semaphores
MXU_LANE = 128                 # MXU tile edge: seq blocks align to this
SUBLANE = 8                    # min f32 sublane tile

_ATTN_BLOCKS = (128, 256, 512)
_WKV_CHUNKS = (16, 32, 64, 128, 256)
_NORM_ROWS = (64, 128, 256, 512, 1024)
_PAGED_PPB = (1, 2, 4, 8)


def attention_vmem_bytes(bq: int, bk: int, D: int, itemsize: int) -> int:
    """Blocks are double-buffered by the pipeline; scratch is f32."""
    blocks = (2 * bq * D + 2 * bk * D) * itemsize        # q, o, k, v
    scratch = (2 * bq + bq * D) * 4                      # m, l, acc
    lse = bq * 4
    return 2 * blocks + scratch + lse


def wkv6_vmem_bytes(c: int, K: int, V: int, itemsize: int) -> int:
    """Worst case materializes the (c, c, K) pairwise-decay tensor (the
    masked fallback path for large in-chunk decay ranges)."""
    blocks = (3 * c * K + c * V) * itemsize + c * V * itemsize
    state = 2 * K * V * 4                                # scratch + out
    pairwise = c * c * K * 4
    return 2 * blocks + state + pairwise


def rmsnorm_vmem_bytes(br: int, d: int, itemsize: int) -> int:
    blocks = 2 * br * d * itemsize + d * 4               # x, o, scale
    f32_tmp = br * d * 4
    return 2 * blocks + f32_tmp


def paged_vmem_bytes(ppb: int, ps: int, g: int, D: int,
                     itemsize: int) -> int:
    """q/o tiles plus ``pages_per_block`` double-buffered K and V page
    DMAs; online-softmax scratch is f32."""
    blocks = (2 * g * D + 2 * ppb * ps * D) * itemsize
    scratch = (2 * g + g * D) * 4                        # m, l, acc
    return 2 * blocks + scratch


def _budget(vmem_budget: Optional[int]) -> int:
    return int(VMEM_BYTES * VMEM_SLACK) if vmem_budget is None \
        else int(vmem_budget)


def _seq_blocks(seq: int) -> List[int]:
    """MXU-aligned block sizes that tile ``seq`` exactly: multiples of
    128 dividing seq, or seq itself when it is smaller than one tile."""
    cand = [b for b in _ATTN_BLOCKS if b <= seq and seq % b == 0]
    if not cand and seq:
        cand = [seq]
    return cand


# ------------------------------------------------------------ candidates
# Each *_candidates returns (valid candidates, rejected-by-vmem count,
# default config). The default is candidate 0 when it is itself valid for
# the shape AND fits the budget; otherwise it is None — the sweep then has
# no default baseline (speedup reports 1.0 rather than mislabeling some
# other candidate's time as "default").
def attention_candidates(Sq: int, Sk: int, D: int, itemsize: int,
                         vmem_budget: Optional[int] = None
                         ) -> Tuple[List[Dict[str, int]], int,
                                    Optional[Dict[str, int]]]:
    budget = _budget(vmem_budget)
    d0 = tuning.DEFAULTS["flash_attention_fwd"]
    default = {"block_q": min(d0["block_q"], Sq),
               "block_k": min(d0["block_k"], Sk)}
    out, rejected = [], 0
    for bq in _seq_blocks(Sq):
        for bk in _seq_blocks(Sk):
            cfg = {"block_q": bq, "block_k": bk}
            if attention_vmem_bytes(bq, bk, D, itemsize) > budget:
                rejected += 1
                continue
            if cfg != default:
                out.append(cfg)
    default_ok = (Sq % default["block_q"] == 0
                  and Sk % default["block_k"] == 0
                  and attention_vmem_bytes(default["block_q"],
                                           default["block_k"], D,
                                           itemsize) <= budget)
    if default_ok:
        out.insert(0, default)
    return out, rejected, (default if default_ok else None)


def wkv6_candidates(T: int, K: int, V: int, itemsize: int,
                    vmem_budget: Optional[int] = None
                    ) -> Tuple[List[Dict[str, int]], int,
                               Optional[Dict[str, int]]]:
    budget = _budget(vmem_budget)
    default_c = min(tuning.DEFAULTS["wkv6_fwd"]["chunk"], T)
    out, rejected = [], 0
    for c in _WKV_CHUNKS:
        if c > T or T % c or c % SUBLANE:
            continue
        if wkv6_vmem_bytes(c, K, V, itemsize) > budget:
            rejected += 1
            continue
        if c != default_c:
            out.append({"chunk": c})
    default_ok = (T % default_c == 0
                  and wkv6_vmem_bytes(default_c, K, V, itemsize) <= budget)
    if default_ok:
        out.insert(0, {"chunk": default_c})
    return out, rejected, ({"chunk": default_c} if default_ok else None)


def rmsnorm_candidates(rows: int, d: int, itemsize: int,
                       vmem_budget: Optional[int] = None
                       ) -> Tuple[List[Dict[str, int]], int,
                                  Optional[Dict[str, int]]]:
    budget = _budget(vmem_budget)
    default_r = min(tuning.DEFAULTS["rmsnorm_fwd"]["block_rows"], rows)
    out, rejected = [], 0
    for br in _NORM_ROWS:
        if br > rows or br % SUBLANE:
            continue
        if rmsnorm_vmem_bytes(br, d, itemsize) > budget:
            rejected += 1
            continue
        if br != default_r:
            out.append({"block_rows": br})
    # the kernel pads rows, so the default only needs to fit the budget
    default_ok = rmsnorm_vmem_bytes(default_r, d, itemsize) <= budget
    if default_ok:
        out.insert(0, {"block_rows": default_r})
    return out, rejected, ({"block_rows": default_r} if default_ok
                           else None)


def paged_candidates(n_pages: int, ps: int, g: int, D: int, itemsize: int,
                     vmem_budget: Optional[int] = None
                     ) -> Tuple[List[Dict[str, int]], int,
                                Optional[Dict[str, int]]]:
    budget = _budget(vmem_budget)
    default_p = min(tuning.DEFAULTS["paged_attention_fwd"]
                    ["pages_per_block"], n_pages)
    out, rejected = [], 0
    for ppb in _PAGED_PPB:
        if ppb > n_pages:
            continue
        if paged_vmem_bytes(ppb, ps, g, D, itemsize) > budget:
            rejected += 1
            continue
        if ppb != default_p:
            out.append({"pages_per_block": ppb})
    default_ok = paged_vmem_bytes(default_p, ps, g, D, itemsize) <= budget
    if default_ok:
        out.insert(0, {"pages_per_block": default_p})
    return out, rejected, ({"pages_per_block": default_p} if default_ok
                           else None)


# ----------------------------------------------------------------- sweep
@dataclass
class TuneResult:
    """Winner of one (kernel, shape-signature) sweep."""

    kernel: str
    signature: str
    config: Dict[str, int]
    us: float                      # winner's measured time
    default_us: float              # default config's time
    # False when the default config was invalid for the shape or rejected
    # by the VMEM budget — default_us then equals us (neutral speedup 1.0)
    default_timed: bool = True
    n_candidates: int = 0
    rejected_vmem: int = 0
    timings: Dict[str, float] = field(default_factory=dict)

    @property
    def speedup(self) -> float:
        return self.default_us / self.us if self.us else 1.0

    def entry(self) -> Tuple[str, Dict[str, Any]]:
        return tuning.entry_key(self.kernel, self.signature), {
            "config": self.config, "us": float(self.us),
            "default_us": float(self.default_us)}


def _cfg_label(cfg: Dict[str, int]) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(cfg.items()))


def _sweep(kernel: str, sig: str, candidates: Sequence[Dict[str, int]],
           rejected: int, default_cfg: Optional[Dict[str, int]],
           make_fn: Callable[..., Callable], args: tuple,
           timer: Callable, iters: int, warmup: int) -> TuneResult:
    """Time every candidate; earliest-fastest wins (default is first)."""
    if not candidates:
        raise ValueError(f"no valid tile candidates for {kernel} ({sig})")
    timings: Dict[str, float] = {}
    best_cfg: Optional[Dict[str, int]] = None
    best_us: Any = float("inf")
    for cfg in candidates:
        fn = make_fn(**cfg)
        # keep the timer's raw value: a TimingStats mean carries per-iter
        # percentiles that ride into the winner's BenchRecord
        us = timer(fn, *args, iters=iters, warmup=warmup)
        timings[_cfg_label(cfg)] = float(us)
        if us < best_us:
            best_cfg, best_us = cfg, us
    if default_cfg is not None:
        default_us, default_timed = timings[_cfg_label(default_cfg)], True
    else:
        # no usable default for this shape: report a neutral baseline
        default_us, default_timed = float(best_us), False
    return TuneResult(kernel=kernel, signature=sig, config=dict(best_cfg),
                      us=best_us, default_us=default_us,
                      default_timed=default_timed,
                      n_candidates=len(candidates), rejected_vmem=rejected,
                      timings=timings)


def tune_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                         timer: Callable = timeit_us, iters: int = 2,
                         warmup: int = 1,
                         vmem_budget: Optional[int] = None) -> TuneResult:
    from repro.kernels import ops

    _, Sq, _, D = q.shape
    Sk = k.shape[1]
    sig = tuning.attention_signature(q.shape, k.shape, q.dtype,
                                     causal=causal, window=window)
    cands, rej, dflt = attention_candidates(Sq, Sk, D, q.dtype.itemsize,
                                            vmem_budget)

    def make_fn(block_q: int, block_k: int):
        return functools.partial(ops.flash_attention, causal=causal,
                                 window=window, block_q=block_q,
                                 block_k=block_k)

    return _sweep("flash_attention_fwd", sig, cands, rej, dflt, make_fn,
                  (q, k, v), timer, iters, warmup)


@functools.lru_cache(maxsize=None)
def _bwd_jitted(causal, window, block_q, block_k):
    import jax

    from repro.kernels import ops
    from repro.kernels.flash_attention import flash_attention_bwd

    return jax.jit(functools.partial(
        flash_attention_bwd, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=ops.INTERPRET))


def _bwd_call(q, k, v, o, lse, do, *, causal, window, block_q, block_k):
    """Jit-per-config bwd entry; kwargs stay visible to fake timers."""
    return _bwd_jitted(causal, window, block_q, block_k)(q, k, v, o, lse,
                                                         do)


def tune_flash_attention_bwd(q, k, v, *, causal: bool = True,
                             window: int = 0, timer: Callable = timeit_us,
                             iters: int = 2, warmup: int = 1,
                             vmem_budget: Optional[int] = None
                             ) -> TuneResult:
    """Tunes dq/dkv block shapes against a fixed forward residual set."""
    import jax

    from repro.kernels import ops
    from repro.kernels.flash_attention import flash_attention_fwd

    _, Sq, _, D = q.shape
    Sk = k.shape[1]
    sig = tuning.attention_signature(q.shape, k.shape, q.dtype,
                                     causal=causal, window=window)
    cands, rej, dflt = attention_candidates(Sq, Sk, D, q.dtype.itemsize,
                                            vmem_budget)
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 interpret=ops.INTERPRET, return_lse=True)
    do = jax.numpy.ones_like(o)

    def make_fn(block_q: int, block_k: int):
        return functools.partial(_bwd_call, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k)

    return _sweep("flash_attention_bwd", sig, cands, rej, dflt, make_fn,
                  (q, k, v, o, lse, do), timer, iters, warmup)


def tune_wkv6(q, k, v, ld, u=None, *, timer: Callable = timeit_us,
              iters: int = 2, warmup: int = 1,
              vmem_budget: Optional[int] = None) -> TuneResult:
    from repro.kernels import ops

    _, T, _, K = q.shape
    V = v.shape[-1]
    sig = tuning.wkv6_signature(q.shape, V, q.dtype, use_u=u is not None)
    cands, rej, dflt = wkv6_candidates(T, K, V, q.dtype.itemsize,
                                       vmem_budget)

    def make_fn(chunk: int):
        return functools.partial(ops.wkv6, chunk=chunk)

    return _sweep("wkv6_fwd", sig, cands, rej, dflt, make_fn,
                  (q, k, v, ld, u), timer, iters, warmup)


def tune_rmsnorm(x, scale, *, timer: Callable = timeit_us, iters: int = 3,
                 warmup: int = 1,
                 vmem_budget: Optional[int] = None) -> TuneResult:
    from repro.kernels import ops

    d = x.shape[-1]
    rows = int(np.prod(x.shape[:-1]))
    sig = tuning.rmsnorm_signature(rows, d, x.dtype)
    cands, rej, dflt = rmsnorm_candidates(rows, d, x.dtype.itemsize,
                                          vmem_budget)

    def make_fn(block_rows: int):
        return functools.partial(ops.rmsnorm, block_rows=block_rows)

    return _sweep("rmsnorm_fwd", sig, cands, rej, dflt, make_fn,
                  (x, scale), timer, iters, warmup)


def tune_paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                         timer: Callable = timeit_us, iters: int = 2,
                         warmup: int = 1,
                         vmem_budget: Optional[int] = None) -> TuneResult:
    """Sweep the paged decode-attention ``pages_per_block``."""
    from repro.kernels import ops

    B, _, Hq, D = q.shape
    _, ps, Hkv, _ = k_pages.shape
    npag = block_tables.shape[1]
    sig = tuning.paged_attention_signature(q.shape, k_pages.shape, npag,
                                           q.dtype)
    cands, rej, dflt = paged_candidates(npag, ps, Hq // Hkv, D,
                                        q.dtype.itemsize, vmem_budget)

    def make_fn(pages_per_block: int):
        return functools.partial(ops.paged_decode_attention,
                                 pages_per_block=pages_per_block)

    return _sweep("paged_attention_fwd", sig, cands, rej, dflt, make_fn,
                  (q, k_pages, v_pages, block_tables, lengths), timer,
                  iters, warmup)


def save(results: Sequence[TuneResult],
         backend: Optional[str] = None):
    """Persist winners to the tuned-config cache; returns the path."""
    entries = dict(r.entry() for r in results)
    return tuning.save_entries(entries, backend)
