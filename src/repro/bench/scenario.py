"""Scenario registry: the paper's sweeps declared as data.

A *scenario* is a named, taggable experiment (llm-d-benchmark's
``<scenario, harness, workload>`` triple): a function that measures one
workload and yields :class:`~repro.bench.record.BenchRecord` rows, plus a
list of :class:`Workload` cells (arch x ShapeConfig x MeshConfig x knobs)
declared as data so the Table I–IV / Fig. 6–12 sweeps are visible in one
place instead of being loops buried inside each ``bench_*`` module.

Register with the decorator::

    @scenario("allocation/layers", tags=("tier1", "table1"),
              paper_ref="Table I / Fig. 6",
              workloads=[Workload(label=f"layers{L}", arch="granite-3-8b",
                                  knobs={"num_layers": L})
                         for L in (6, 12, 24, 48)])
    def allocation_layers(wl: Workload):
        ...
        yield BenchRecord(name=f"allocation/{wl.label}/O3", ...)

The runner (:mod:`repro.bench.runner`) owns timing, fail-soft error
capture, and result sinks; scenario functions only measure and yield.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Mapping,
                    Optional, Sequence, Tuple)

from repro.configs import MeshConfig, ShapeConfig

from repro.bench.record import BenchRecord

# Default cell for scenarios that don't sweep shape/mesh: the reduced
# "bench" shape on the paper's 16x16 production mesh.
BENCH_SHAPE = ShapeConfig("bench", "train", 1024, 64)
BENCH_MESH = MeshConfig()


def mesh_str(mesh: Optional[MeshConfig]) -> str:
    return "x".join(map(str, mesh.shape)) if mesh is not None else ""


@dataclass(frozen=True)
class Workload:
    """One cell of a sweep: what to run, not how to time it."""

    label: str = ""                       # short suffix for record names
    arch: str = ""
    shape: Optional[ShapeConfig] = None
    mesh: Optional[MeshConfig] = None
    knobs: Mapping[str, Any] = field(default_factory=dict)


ScenarioFn = Callable[[Workload], Iterable[BenchRecord]]


@dataclass
class Scenario:
    name: str                             # unique id, e.g. "allocation/layers"
    fn: ScenarioFn
    group: str                            # family, e.g. "allocation"
    tags: Tuple[str, ...] = ()
    paper_ref: str = ""
    description: str = ""
    workloads: Tuple[Workload, ...] = (Workload(),)

    def __post_init__(self) -> None:
        if not self.workloads:
            self.workloads = (Workload(),)


REGISTRY: Dict[str, Scenario] = {}


def register(scen: Scenario) -> Scenario:
    if scen.name in REGISTRY:
        raise ValueError(f"scenario {scen.name!r} already registered")
    REGISTRY[scen.name] = scen
    return scen


def unregister(name: str) -> None:
    REGISTRY.pop(name, None)


def scenario(name: str, *, group: str = "", tags: Sequence[str] = (),
             paper_ref: str = "", description: str = "",
             workloads: Sequence[Workload] = ()) -> Callable[[ScenarioFn],
                                                             ScenarioFn]:
    """Decorator: register ``fn`` as a named scenario."""

    def deco(fn: ScenarioFn) -> ScenarioFn:
        register(Scenario(
            name=name, fn=fn,
            group=group or name.split("/", 1)[0],
            tags=tuple(tags), paper_ref=paper_ref,
            description=description or (fn.__doc__ or "").strip(),
            workloads=tuple(workloads) or (Workload(),)))
        return fn

    return deco


def names() -> List[str]:
    return sorted(REGISTRY)


def groups() -> List[str]:
    return sorted({s.group for s in REGISTRY.values()})


def only_matches(term: str, scen: Scenario) -> bool:
    """One ``--only`` term against one scenario. A term that is the *exact*
    name of a registered scenario selects only that scenario (so CI retries
    rerun one flaky scenario, not its whole group); any other term keeps
    the historical substring semantics over name and group."""
    if term in REGISTRY:
        return scen.name == term
    return term in scen.name or term in scen.group


def select(only: Optional[str] = None,
           tags: Optional[Sequence[str]] = None) -> Iterator[Scenario]:
    """Scenarios matching an ``--only`` filter and/or any of ``tags``, in
    registration order (which follows module order in benchmarks.run).
    ``only`` is a comma-separated list of terms, each resolved by
    :func:`only_matches` (exact scenario name > substring)."""
    want = set(tags or ())
    terms = [t for t in (only or "").split(",") if t]
    for scen in REGISTRY.values():
        if terms and not any(only_matches(t, scen) for t in terms):
            continue
        if want and not want.intersection(scen.tags):
            continue
        yield scen
