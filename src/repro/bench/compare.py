"""Noise-aware baseline comparison: the regression gate behind
``python -m benchmarks.run --compare``.

Each fresh :class:`BenchRecord` is diffed against the blessed baseline of
the same name (:mod:`repro.bench.baseline`). A record only *regresses*
when two independent signals agree:

1. **p50 ratio** — the fresh median is more than ``rel_tol`` slower than
   the blessed median (falling back to the mean when percentiles were not
   measured);
2. **sign test** — under the null hypothesis "nothing changed", each
   fresh per-iteration sample lands above the blessed median with
   probability 1/2; the one-sided binomial tail over the fresh
   ``samples_us`` must reach ``alpha``, or every sample must sit above
   the old median (unanimity leaves no contrary evidence to call noise,
   even when n is too small for significance). With the default 5 bench
   iterations that means *every* sample must sit above the old median —
   a single noisy spike inflating the mean can never fail the gate (it
   reports ``noisy`` instead). Records without samples fall back to the
   ratio alone.

Comparisons are *skipped* (never failed) when the env fingerprints
disagree, when the baseline is missing (``new``), or when the measurement
is below ``min_us`` (pure timer noise).

Every compare appends one point to ``results/trajectory.jsonl`` — the
per-commit performance trajectory the CI matrix uploads as an artifact.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

from repro.bench.baseline import fingerprint, fingerprint_compatible
from repro.bench.record import BenchRecord

DEFAULT_TRAJECTORY = Path("results") / "trajectory.jsonl"

# verdicts, ordered worst-first for reporting
REGRESSION = "regression"
NOISY = "noisy"  # ratio breached but the sign test says noise
OK = "ok"
FASTER = "faster"
NEW = "new"  # no baseline for this name yet
SKIPPED = "skipped"  # fingerprint mismatch / untimed / error record

_ORDER = (REGRESSION, NOISY, FASTER, OK, NEW, SKIPPED)


@dataclass(frozen=True)
class Thresholds:
    """Knobs of the noise-aware gate (see module docstring)."""

    rel_tol: float = 0.25  # p50 must be >25% slower to regress
    alpha: float = 0.05  # one-sided sign-test significance
    min_us: float = 50.0  # ignore sub-50us baselines: timer noise
    min_samples: int = 4  # fewer samples -> ratio-only verdict
    # sample-less records have no sign-test veto, so single-shot jitter
    # (routinely 25-50% on shared hosts) must not read as regression:
    # demand a much larger breach before failing on the ratio alone
    ratio_only_tol: float = 0.6


def sign_test_p(n_above: int, n: int) -> float:
    """One-sided binomial tail P[X >= n_above], X ~ Bin(n, 1/2)."""
    if n <= 0:
        return 1.0
    total = sum(math.comb(n, k) for k in range(n_above, n + 1))
    return total / float(2**n)


def _rep_us(rec: BenchRecord) -> float:
    """The representative latency: median when measured, else the mean."""
    return rec.p50_us if rec.p50_us > 0 else rec.us_per_call


@dataclass
class CompareResult:
    name: str
    status: str
    ratio: float = 0.0  # fresh / baseline representative latency
    base_us: float = 0.0
    fresh_us: float = 0.0
    detail: str = ""

    def line(self) -> str:
        r = f"{self.ratio:.3f}x" if self.ratio else "-"
        tail = f"  ({self.detail})" if self.detail else ""
        return (
            f"{self.status:10s} {self.name:44s} "
            f"{self.base_us:12.1f} -> {self.fresh_us:12.1f}  {r}{tail}"
        )


def compare_record(
    fresh: BenchRecord,
    base: Optional[BenchRecord],
    thr: Thresholds = Thresholds(),
) -> CompareResult:
    """Diff one fresh record against its blessed baseline."""
    res = CompareResult(name=fresh.name, status=OK)
    if fresh.status != "ok":
        res.status, res.detail = SKIPPED, "fresh record is an error record"
        return res
    if base is None:
        res.status = NEW
        return res
    if not fingerprint_compatible(fingerprint(fresh.env), fingerprint(base.env)):
        res.status = SKIPPED
        res.detail = "env fingerprint mismatch"
        return res
    base_us, fresh_us = _rep_us(base), _rep_us(fresh)
    res.base_us, res.fresh_us = base_us, fresh_us
    if base_us <= 0 or fresh_us <= 0:
        res.status, res.detail = SKIPPED, "untimed measurement"
        return res
    if base_us < thr.min_us:
        res.status = SKIPPED
        res.detail = f"baseline below min_us={thr.min_us:g}"
        return res
    res.ratio = fresh_us / base_us
    if res.ratio < 1.0 / (1.0 + thr.rel_tol):
        res.status = FASTER
        return res
    if res.ratio <= 1.0 + thr.rel_tol:
        return res
    samples = fresh.samples_us
    if len(samples) < thr.min_samples:
        if res.ratio > 1.0 + thr.ratio_only_tol:
            res.status = REGRESSION
            res.detail = f"ratio-only verdict ({len(samples)} samples)"
        else:
            res.status = NOISY
            res.detail = (
                f"ratio breach without samples (needs "
                f">{1.0 + thr.ratio_only_tol:g}x, got {res.ratio:.2f}x)"
            )
        return res
    n_above = sum(1 for s in samples if s > base_us)
    n = len(samples)
    p = sign_test_p(n_above, n)
    # unanimity clause: when EVERY sample sits above the old median there
    # is no contrary evidence to call noise, so a breached ratio regresses
    # even when n is too small for p <= alpha (4 samples: p = 1/16)
    if p <= thr.alpha or n_above == n:
        res.status = REGRESSION
        res.detail = f"sign test {n_above}/{n} above, p={p:.4f}"
    else:
        res.status = NOISY
        res.detail = f"sign test {n_above}/{n} above, p={p:.4f}"
    return res


@dataclass
class CompareReport:
    results: List[CompareResult] = field(default_factory=list)
    thresholds: Thresholds = Thresholds()

    def by_status(self, status: str) -> List[CompareResult]:
        return [r for r in self.results if r.status == status]

    @property
    def regressions(self) -> List[CompareResult]:
        return self.by_status(REGRESSION)

    @property
    def ok(self) -> bool:
        return not self.regressions

    def counts(self) -> Dict[str, int]:
        return {s: len(self.by_status(s)) for s in _ORDER}

    def geomean_ratio(self) -> float:
        """Geometric mean of fresh/base over actually compared records."""
        ratios = [
            r.ratio
            for r in self.results
            if r.ratio > 0 and r.status in (OK, FASTER, REGRESSION, NOISY)
        ]
        if not ratios:
            return 0.0
        return math.exp(sum(math.log(x) for x in ratios) / len(ratios))

    def lines(self, verbose: bool = False) -> List[str]:
        """Human-readable report: all non-ok verdicts, plus a summary."""
        shown = [
            r
            for r in sorted(self.results, key=lambda r: _ORDER.index(r.status))
            if verbose or r.status in (REGRESSION, NOISY, FASTER)
        ]
        out = [r.line() for r in shown]
        c = self.counts()
        gm = self.geomean_ratio()
        out.append(
            "compare: "
            + " ".join(f"{k}={v}" for k, v in c.items() if v)
            + (f" geomean_ratio={gm:.3f}" if gm else "")
        )
        return out

    def trajectory_point(
        self, extra: Optional[Dict[str, Any]] = None
    ) -> Dict[str, Any]:
        c = self.counts()
        point: Dict[str, Any] = {
            "t": round(time.time(), 3),
            "compared": sum(c[s] for s in (OK, FASTER, REGRESSION, NOISY)),
            "regressions": [r.name for r in self.regressions],
            "geomean_ratio": round(self.geomean_ratio(), 4),
            "counts": {k: v for k, v in c.items() if v},
        }
        if extra:
            point.update(extra)
        return point


def compare_records(
    fresh: Iterable[BenchRecord],
    baselines: Dict[str, BenchRecord],
    thr: Thresholds = Thresholds(),
) -> CompareReport:
    """Diff every fresh record against the baseline of the same name."""
    report = CompareReport(thresholds=thr)
    for rec in fresh:
        report.results.append(compare_record(rec, baselines.get(rec.name), thr))
    return report


def append_trajectory(
    point: Dict[str, Any],
    path: Path = DEFAULT_TRAJECTORY,
) -> Path:
    """Append one compare outcome to the trajectory JSONL."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("a") as fh:
        fh.write(json.dumps(point, sort_keys=True) + "\n")
    return path


def read_trajectory(path: Path = DEFAULT_TRAJECTORY) -> List[Dict[str, Any]]:
    path = Path(path)
    if not path.exists():
        return []
    lines = path.read_text().splitlines()
    return [json.loads(ln) for ln in lines if ln.strip()]
