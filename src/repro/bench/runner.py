"""One runner for every benchmark: timing, selection, fail-soft errors,
and result sinks.

Owns the measurement loop that used to be copy-pasted across the seven
``benchmarks/bench_*`` modules:

* :func:`timeit_us` — the warmup + iters wall-clock timer (absorbed from
  ``benchmarks/common.py``);
* :func:`run_with_devices` — subprocess execution with N fake host
  devices for the inter-chip scalability scenarios;
* :class:`BenchRunner` — iterates registered scenarios workload-by-
  workload, stamps each yielded record with scenario provenance and the
  environment fingerprint, captures per-workload failures as error
  records instead of aborting the sweep, and fans records out to sinks
  (legacy CSV on stdout, JSONL under ``results/bench/``, in-memory).
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import threading
import time
import traceback
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, TextIO, Tuple

from repro.bench.record import CSV_HEADER, BenchRecord, env_fingerprint
from repro.bench.scenario import REGISTRY, Scenario, Workload, mesh_str, select

REPO = Path(__file__).resolve().parents[3]
SRC = REPO / "src"


# ------------------------------------------------------------------ timing
from repro.core.metrics import percentile as _percentile  # noqa: E402


class TimingStats(float):
    """Mean us-per-call that also carries the per-iteration samples.

    Drops in anywhere a plain float mean was expected; ``p50_us``/
    ``p95_us``/``samples`` ride along so tuning decisions and records
    aren't skewed by warmup jitter hiding inside a mean.
    """

    samples: Tuple[float, ...]
    p50_us: float
    p95_us: float

    def __new__(cls, samples: Sequence[float]) -> "TimingStats":
        samples = tuple(samples)
        obj = super().__new__(cls, sum(samples) / len(samples))
        obj.samples = samples
        s = sorted(samples)
        obj.p50_us = _percentile(s, 50.0)
        obj.p95_us = _percentile(s, 95.0)
        return obj


def timeit_us(fn, *args, iters: int = 5, warmup: int = 2) -> TimingStats:
    """Wall-clock microseconds per call after ``warmup`` calls: a
    :class:`TimingStats` float (the mean) carrying per-iter samples."""
    import jax

    iters = max(1, iters)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return TimingStats(samples)


# cap on per-record raw samples so JSONL lines stay bounded even for
# serving runs with hundreds of decode-step samples
MAX_RECORD_SAMPLES = 64


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 900) -> str:
    """Run a python snippet in a subprocess with N fake host devices.
    (The parent process must keep seeing 1 device — see launch/dryrun.py.)

    The child env comes from :func:`repro.launch.mesh.host_device_env`,
    which rewrites only the device-count flag inside ``XLA_FLAGS`` — any
    other flags the caller (e.g. a CI matrix cell) set are preserved.
    """
    from repro.launch.mesh import host_device_env

    env = host_device_env(n_devices)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-4000:]}")
    return proc.stdout


# ------------------------------------------------------------------- sinks
class ListSink:
    """Collect records in memory (``sink.records``)."""

    def __init__(self) -> None:
        self.records: List[BenchRecord] = []

    def emit(self, rec: BenchRecord) -> None:
        self.records.append(rec)

    def close(self) -> None:
        pass


class CsvStdoutSink:
    """The legacy ``name,us_per_call,derived`` CSV stream."""

    def __init__(self, stream: Optional[TextIO] = None,
                 header: bool = True) -> None:
        self.stream = stream or sys.stdout
        if header:
            print(CSV_HEADER, file=self.stream, flush=True)

    def emit(self, rec: BenchRecord) -> None:
        print(rec.csv_line(), file=self.stream, flush=True)

    def close(self) -> None:
        pass


class JsonlSink:
    """Stream records to a JSONL file, atomically: lines go to a ``.tmp``
    sibling (flushed per record, so a live run is inspectable) and replace
    the target on close — a crashed or killed run never truncates the
    previous result set."""

    def __init__(self, path: str | Path) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        self._fh = self._tmp.open("w")

    def emit(self, rec: BenchRecord) -> None:
        self._fh.write(rec.to_json_line() + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh.closed:
            return
        self._fh.close()
        os.replace(self._tmp, self.path)


# ------------------------------------------------------------------ runner
class ScenarioTimeout(Exception):
    """A workload exceeded the per-scenario wall-clock budget."""


class _workload_deadline:
    """SIGALRM-based wall-clock budget around one workload execution.

    A hung scenario (deadlocked collective, runaway decode loop) would
    otherwise stall the whole sweep; this turns it into a ``status:
    "timeout"`` record so the remaining scenarios still run. No-op when
    budget <= 0, off the main thread, or on platforms without SIGALRM —
    in those cases the workload simply runs unbounded as before.
    """

    def __init__(self, budget_s: float) -> None:
        self.budget_s = budget_s
        self.armed = False

    def __enter__(self) -> "_workload_deadline":
        if (self.budget_s > 0 and hasattr(signal, "SIGALRM")
                and threading.current_thread() is threading.main_thread()):
            self._prev = signal.signal(signal.SIGALRM, self._fire)
            signal.setitimer(signal.ITIMER_REAL, self.budget_s)
            self.armed = True
        return self

    def __exit__(self, *exc) -> None:
        if self.armed:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
            signal.signal(signal.SIGALRM, self._prev)

    def _fire(self, signum, frame) -> None:
        raise ScenarioTimeout(
            f"workload exceeded {self.budget_s:.0f}s budget")


@dataclass
class RunSummary:
    records: List[BenchRecord] = field(default_factory=list)
    failures: List[Tuple[str, str]] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures


class BenchRunner:
    """Execute scenarios and fan records out to sinks."""

    #: default per-workload wall-clock budget (seconds); 0 disables
    DEFAULT_TIMEOUT_S = 600.0

    def __init__(self, sinks: Sequence[Any] = (),
                 env: Optional[Dict[str, Any]] = None,
                 timeout_s: Optional[float] = None) -> None:
        self.sinks = list(sinks)
        self.env = env_fingerprint() if env is None else env
        if timeout_s is None:
            timeout_s = float(os.environ.get(
                "REPRO_SCENARIO_TIMEOUT_S", self.DEFAULT_TIMEOUT_S))
        self.timeout_s = timeout_s

    # stamp scenario/workload provenance onto a record the fn yielded
    def _finalize(self, rec: BenchRecord, scen: Scenario,
                  wl: Workload) -> BenchRecord:
        rec.scenario = rec.scenario or scen.name
        rec.group = rec.group or scen.group
        rec.tags = rec.tags or scen.tags
        rec.paper_ref = rec.paper_ref or scen.paper_ref
        rec.arch = rec.arch or wl.arch
        if not rec.shape and wl.shape is not None:
            rec.shape = wl.shape.name
        rec.mesh = rec.mesh or mesh_str(wl.mesh)
        merged = dict(wl.knobs)
        merged.update(rec.knobs)
        rec.knobs = merged
        rec.env = rec.env or self.env
        # a TimingStats mean carries per-iter percentiles and the raw
        # samples the compare layer's sign test needs: stamp + strip
        us = rec.us_per_call
        if not rec.p50_us and hasattr(us, "p50_us"):
            rec.p50_us = float(us.p50_us)
            rec.p95_us = float(us.p95_us)
        if not rec.samples_us and hasattr(us, "samples"):
            # cap by striding over the WHOLE chronological sequence (not a
            # head slice): the compare sign test must see late-run samples
            # or a degradation tail could hide behind a fast warm start
            samples = us.samples
            if len(samples) > MAX_RECORD_SAMPLES:
                step = (len(samples) - 1) / (MAX_RECORD_SAMPLES - 1)
                samples = [samples[round(i * step)]
                           for i in range(MAX_RECORD_SAMPLES)]
            rec.samples_us = [round(float(s), 3) for s in samples]
        rec.us_per_call = float(us)
        return rec

    def _emit(self, rec: BenchRecord, out: RunSummary) -> None:
        out.records.append(rec)
        for sink in self.sinks:
            sink.emit(rec)

    def run(self, scenarios: Optional[Iterable[Scenario]] = None
            ) -> RunSummary:
        out = RunSummary()
        scens = list(scenarios) if scenarios is not None \
            else list(REGISTRY.values())
        for scen in scens:
            for wl in scen.workloads:
                label = f"/{wl.label}" if wl.label else ""
                try:
                    with _workload_deadline(self.timeout_s):
                        for rec in scen.fn(wl):
                            self._emit(self._finalize(rec, scen, wl), out)
                except ScenarioTimeout as e:  # hung: record, keep sweeping
                    out.failures.append(
                        (f"{scen.name}{label}", str(e)[:200]))
                    rec = BenchRecord(
                        name=f"{scen.name}{label}/TIMEOUT",
                        status="timeout", error=str(e)[:500],
                        derived={"timeout_s": self.timeout_s})
                    self._emit(self._finalize(rec, scen, wl), out)
                except Exception as e:  # fail-soft: record, keep sweeping
                    traceback.print_exc(file=sys.stderr)
                    out.failures.append(
                        (f"{scen.name}{label}", str(e)[:200]))
                    err = BenchRecord(
                        name=f"{scen.name}{label}/FAILED", status="error",
                        error="".join(traceback.format_exception_only(
                            type(e), e)).strip()[:500],
                        derived={"error": repr(e)[:200]})
                    self._emit(self._finalize(err, scen, wl), out)
        self.close()
        return out

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()


def run_benchmarks(only: Optional[str] = None,
                   tags: Optional[Sequence[str]] = None,
                   sinks: Sequence[Any] = ()) -> RunSummary:
    """Select from the global registry and run — the one-call entrypoint
    ``python -m benchmarks.run`` uses."""
    return BenchRunner(sinks=sinks).run(select(only=only, tags=tags))
