"""BenchRecord: the one result type every benchmark emits.

A record carries the full provenance of a measurement — which registered
scenario produced it, the (arch x shape x mesh) cell, the knob values the
sweep varied, the measured ``us_per_call``, and *structured* derived
metrics (a real dict, not a ``key=value`` string) — plus an environment
fingerprint so results from different hosts/toolchains are comparable.

Serialization targets:

* JSONL (``to_json_line``/``from_json_line`` + ``write_jsonl``/``read_jsonl``)
  — the machine-readable interchange the reporting layer consumes;
* legacy CSV (``csv_line``) — the ``name,us_per_call,derived`` stdout
  format ``python -m benchmarks.run`` has always printed.
"""
from __future__ import annotations

import dataclasses
import json
import platform
import sys
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Iterable, List, Tuple

SCHEMA_VERSION = 2  # v2: per-iteration samples_us (empty = not measured)


def _cpu_model() -> str:
    """Best-effort CPU model string — CI fleets mix otherwise-identical
    x86_64 runners whose clocks differ enough to fake a regression, so
    the compare layer treats cross-model pairs as incomparable."""
    try:
        with open("/proc/cpuinfo") as fh:
            for line in fh:
                if line.startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return platform.processor() or ""


def env_fingerprint() -> Dict[str, Any]:
    """Best-effort description of the machine/toolchain producing records."""
    env: Dict[str, Any] = {
        "python": platform.python_version(),
        "platform": sys.platform,
        "machine": platform.machine(),
    }
    cpu = _cpu_model()
    if cpu:
        env["cpu"] = cpu
    try:  # jax is a hard dep of the benchmarks but not of this module
        import jax

        env["jax"] = jax.__version__
        env["backend"] = jax.default_backend()
        env["device_count"] = jax.device_count()
    except Exception:
        pass
    return env


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


@dataclass
class BenchRecord:
    """One measurement from one scenario workload."""

    name: str                       # full measurement id, e.g. "allocation/layers12/O3"
    scenario: str = ""              # registered scenario id, e.g. "allocation/layers"
    group: str = ""                 # scenario family, e.g. "allocation"
    arch: str = ""
    shape: str = ""
    mesh: str = ""                  # "16x16"-style
    knobs: Dict[str, Any] = field(default_factory=dict)
    us_per_call: float = 0.0
    # per-iteration percentiles (0.0 = not measured). Serialized in JSONL;
    # the legacy CSV keeps its mean-only `name,us_per_call,derived` shape.
    p50_us: float = 0.0
    p95_us: float = 0.0
    # raw per-iteration samples (microseconds, possibly capped by the
    # runner). The baseline/regression layer (repro.bench.compare) runs
    # its sign test over these; empty = not measured. JSONL only.
    samples_us: List[float] = field(default_factory=list)
    # serving scenarios: median time-to-first-token (0.0 = not a serving
    # measurement). JSONL only, like the percentiles.
    ttft_us: float = 0.0
    derived: Dict[str, Any] = field(default_factory=dict)
    tags: Tuple[str, ...] = ()
    paper_ref: str = ""             # "Table I / Fig. 6" etc.
    status: str = "ok"              # ok | error
    error: str = ""
    env: Dict[str, Any] = field(default_factory=dict)
    version: int = SCHEMA_VERSION

    # ------------------------------------------------------------- dict/json
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["tags"] = list(self.tags)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchRecord":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        kw["tags"] = tuple(kw.get("tags", ()))
        return cls(**kw)

    def to_json_line(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json_line(cls, line: str) -> "BenchRecord":
        return cls.from_dict(json.loads(line))

    # ---------------------------------------------------------- legacy CSV
    def derived_str(self) -> str:
        """Render derived metrics as the legacy ``k=v;k2=v2`` string."""
        return ";".join(f"{k}={_fmt(v)}" for k, v in self.derived.items())

    def csv_line(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived_str()}"


CSV_HEADER = "name,us_per_call,derived"


def write_jsonl(records: Iterable[BenchRecord], path: str | Path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as fh:
        for rec in records:
            fh.write(rec.to_json_line() + "\n")
    return path


def read_jsonl(path: str | Path) -> List[BenchRecord]:
    out = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            out.append(BenchRecord.from_json_line(line))
    return out
