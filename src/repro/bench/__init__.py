"""repro.bench — the standardized benchmark harness.

Three layers:

* :mod:`repro.bench.record` — :class:`BenchRecord`, the typed result every
  benchmark emits (JSONL + legacy-CSV serializable);
* :mod:`repro.bench.scenario` — the registry of named, tagged scenarios
  whose (arch x shape x mesh x knobs) sweeps are declared as
  :class:`Workload` data;
* :mod:`repro.bench.runner` — the single runner owning timing, fail-soft
  error capture, and result sinks;
* :mod:`repro.bench.tune` — the kernel autotuner (import the submodule
  directly; kept out of the package namespace so registration-time
  imports stay jax-free);
* :mod:`repro.bench.baseline` / :mod:`repro.bench.compare` — blessed
  per-(backend, env-fingerprint) baselines under ``results/baselines/``
  and the noise-aware regression gate behind ``benchmarks.run --compare``.
"""
from repro.bench.baseline import (bless, fingerprint,  # noqa: F401
                                  fingerprint_compatible, load_baselines)
from repro.bench.compare import (CompareReport, CompareResult,  # noqa: F401
                                 Thresholds, append_trajectory,
                                 compare_record, compare_records,
                                 read_trajectory)
from repro.bench.record import (CSV_HEADER, BenchRecord, env_fingerprint,
                                read_jsonl, write_jsonl)
from repro.bench.runner import (BenchRunner, CsvStdoutSink, JsonlSink,
                                ListSink, RunSummary, ScenarioTimeout,
                                TimingStats, run_benchmarks,
                                run_with_devices, timeit_us)
from repro.bench.scenario import (BENCH_MESH, BENCH_SHAPE, REGISTRY,
                                  Scenario, Workload, groups, mesh_str,
                                  names, only_matches, register, scenario,
                                  select, unregister)

__all__ = [
    "BENCH_MESH", "BENCH_SHAPE", "BenchRecord", "BenchRunner", "CSV_HEADER",
    "CompareReport", "CompareResult", "CsvStdoutSink", "JsonlSink",
    "ListSink", "REGISTRY", "RunSummary", "Scenario", "ScenarioTimeout",
    "Thresholds",
    "TimingStats", "Workload", "append_trajectory", "bless",
    "compare_record", "compare_records", "env_fingerprint", "fingerprint",
    "fingerprint_compatible", "groups", "load_baselines", "mesh_str",
    "names", "only_matches", "read_jsonl", "read_trajectory", "register",
    "run_benchmarks",
    "run_with_devices", "scenario", "select", "timeit_us", "unregister",
    "write_jsonl",
]
