"""Sharded checkpointing: atomic, restart-safe, mesh-shape-portable.

Layout: <dir>/step_<N>/
    meta.msgpack            {step, tree structure, leaf manifest}
    leaf_<i>.npy            one array per leaf (np.save)
    _COMMITTED              written last -> a partial save is never visible

Design points for the 1000-node posture:
* atomic publish via the _COMMITTED marker + temp-dir rename;
* async save (background thread) so training never blocks on IO;
* restore_latest() skips uncommitted/corrupt steps (crash mid-save is fine);
* arrays are saved from the addressable host view and restored with
  jax.device_put against ANY target sharding -> elastic re-mesh = restore
  with the new mesh's shardings (see runtime/elastic.py).
"""
from __future__ import annotations

import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import msgpack
import numpy as np

import jax


def _flatten(tree) -> Tuple[list, Any]:
    from repro.optim.adamw import Q8  # noqa: F401 (registers the pytree)
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | os.PathLike, step: int, tree,
         *, blocking: bool = True) -> threading.Thread | None:
    """Save a pytree of (possibly sharded) jax arrays."""
    host_leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]
    treedef = jax.tree.structure(tree)

    def _write():
        base = Path(ckpt_dir)
        tmp = base / f".tmp_step_{step}"
        final = base / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = []
        for i, leaf in enumerate(host_leaves):
            np.save(tmp / f"leaf_{i}.npy", leaf)
            manifest.append({"i": i, "shape": list(leaf.shape),
                             "dtype": str(leaf.dtype)})
        meta = {"step": step, "n_leaves": len(host_leaves),
                "treedef": str(treedef), "manifest": manifest}
        (tmp / "meta.msgpack").write_bytes(msgpack.packb(meta))
        (tmp / "_COMMITTED").write_bytes(b"ok")
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def available_steps(ckpt_dir: str | os.PathLike) -> list[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return []
    steps = []
    for d in base.iterdir():
        if d.name.startswith("step_") and (d / "_COMMITTED").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def restore(ckpt_dir: str | os.PathLike, step: int, target_tree,
            shardings=None):
    """Restore into the structure of target_tree; if `shardings` (matching
    pytree of jax.sharding.Sharding) is given, leaves are placed sharded —
    this is how elastic re-meshing re-lays-out a checkpoint."""
    base = Path(ckpt_dir) / f"step_{step}"
    if not (base / "_COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {base}")
    leaves, treedef = jax.tree.flatten(target_tree)
    out = []
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        if shardings is not None else [None] * len(leaves))
    for i, (tgt, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(base / f"leaf_{i}.npy")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(tgt.dtype)
                                      if hasattr(tgt, "dtype") else arr))
    return jax.tree.unflatten(treedef, out)


def restore_latest(ckpt_dir, target_tree, shardings=None
                   ) -> Tuple[Optional[int], Any]:
    """(step, tree) from the newest committed checkpoint, else (None, target).
    Corrupt newest checkpoints are skipped — crash-during-save safe."""
    for step in reversed(available_steps(ckpt_dir)):
        try:
            return step, restore(ckpt_dir, step, target_tree, shardings)
        except Exception:
            continue
    return None, target_tree
