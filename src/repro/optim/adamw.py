"""Pure-JAX AdamW with f32 master weights, global-norm clipping and a
warmup-cosine schedule. Optimizer state shards exactly like the params
(ZeRO — see parallel/sharding.py), so the update is fully local.

Memory policy knobs (needed to fit the 400-480B MoE archs on v5e-256,
where f32 AdamW state alone is 22 GB/chip):
* ``state_dtype``  — 'float32' | 'bfloat16' | 'int8': m/v storage. int8 is
  blockwise-quantized (16-elem blocks along the last dim with f32 scales,
  ~1.25 B/elem; blocks never straddle a shard boundary), in the spirit of
  8-bit Adam [arXiv:2110.02861].
* ``use_master``   — keep an f32 master copy (True) or update the bf16
  params directly with f32 round-trip math (False).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

_QBLOCK = 16  # along the last dim: small enough to stay inside any shard


class Q8(NamedTuple):
    """Blockwise-int8 tensor: q keeps the source shape (and sharding);
    scale has shape[:-1] + (last/_QBLOCK,)."""
    q: jnp.ndarray
    scale: jnp.ndarray


def quantizable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] % _QBLOCK == 0


def _q8_encode(x: jnp.ndarray):
    if not quantizable(x.shape):
        return x.astype(jnp.float32)
    blocks = x.astype(jnp.float32).reshape(*x.shape[:-1], -1, _QBLOCK)
    scale = jnp.maximum(jnp.abs(blocks).max(axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    return Q8(q=q.astype(jnp.int8).reshape(x.shape),
              scale=scale.astype(jnp.float32))


def _q8_decode(enc) -> jnp.ndarray:
    if not isinstance(enc, Q8):
        return enc.astype(jnp.float32)
    blocks = enc.q.astype(jnp.float32).reshape(
        *enc.q.shape[:-1], -1, _QBLOCK)
    return (blocks * enc.scale[..., None]).reshape(enc.q.shape)


def _is_q8(leaf) -> bool:
    return isinstance(leaf, Q8)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    master: dict          # f32 master copy of params ({} if use_master=False)
    m: dict
    v: dict


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


@dataclass(frozen=True)
class AdamW:
    lr_fn: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # float32 | bfloat16 | int8
    use_master: bool = True

    def _enc(self, x32: jnp.ndarray):
        if self.state_dtype == "int8":
            return _q8_encode(x32)
        return x32.astype(jnp.dtype(self.state_dtype))

    def _dec(self, enc) -> jnp.ndarray:
        if self.state_dtype == "int8":
            return _q8_decode(enc)
        return enc.astype(jnp.float32)

    def init(self, params) -> AdamWState:
        def zeros():   # fresh buffers each call: m/v/master must not alias
            return jax.tree.map(
                lambda x: self._enc(jnp.zeros(x.shape, jnp.float32)), params)
        # copy=True: an f32 param must not alias its master (both get donated)
        master = jax.tree.map(
            lambda x: jnp.array(x, dtype=jnp.float32, copy=True), params) \
            if self.use_master else {}
        return AdamWState(step=jnp.zeros((), jnp.int32), master=master,
                          m=zeros(), v=zeros())

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr_fn(step)
        gnorm = global_norm(grads)
        clip = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-8)) \
            if self.grad_clip else 1.0

        def upd(g, m_enc, v_enc, master):
            g = g.astype(jnp.float32) * clip
            m = self.b1 * self._dec(m_enc) + (1 - self.b1) * g
            v = self.b2 * self._dec(v_enc) + (1 - self.b2) * jnp.square(g)
            mhat = m / (1 - self.b1 ** step.astype(jnp.float32))
            vhat = v / (1 - self.b2 ** step.astype(jnp.float32))
            wd = self.weight_decay if master.ndim >= 2 else 0.0
            master = master - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                                    + wd * master)
            return self._enc(m), self._enc(v), master

        is_leaf = _is_q8
        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state.m, is_leaf=is_leaf)
        flat_v = jax.tree.leaves(state.v, is_leaf=is_leaf)
        if self.use_master:
            flat_ma = jax.tree.leaves(state.master)
        else:
            flat_ma = [p.astype(jnp.float32) for p in jax.tree.leaves(params)]
        outs = [upd(g, m, v, ma)
                for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
        new_m = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        new_master_flat = [o[2] for o in outs]
        new_params = jax.tree.unflatten(treedef, [
            ma.astype(p.dtype)
            for ma, p in zip(new_master_flat, jax.tree.leaves(params))])
        new_state = AdamWState(
            step=step,
            master=(jax.tree.unflatten(treedef, new_master_flat)
                    if self.use_master else {}),
            m=new_m, v=new_v)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics
