"""Fault-tolerant training loop.

Features (the large-scale-runnability story, exercised in tests/examples):
* auto-resume from the newest committed checkpoint;
* periodic async checkpointing (training never blocks on IO);
* bounded step-retry on transient failures (a thrown step is re-executed
  from the last good (params, opt_state) — on real fleets this is where a
  SlurmRequeue/BarrierTimeout lands);
* straggler watchdog: per-step wall-time EWMA + sigma; steps slower than
  mean + k*sigma are logged and counted (on multi-host this feeds the
  replace-the-slow-host decision);
* loss-spike guard (skip-update on non-finite loss).
"""
from __future__ import annotations

import logging
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.checkpoint import checkpoint as ckpt

log = logging.getLogger("repro.train")


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor; flags steps slower than mean + k*sigma."""
    k: float = 3.0
    alpha: float = 0.1
    mean: float = 0.0
    var: float = 0.0
    steps: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.steps += 1
        if self.steps == 1:
            self.mean = dt
            return False
        sigma = math.sqrt(max(self.var, 1e-12))
        is_straggler = self.steps > 5 and dt > self.mean + self.k * sigma
        if is_straggler:
            self.flagged.append((step, dt))
            log.warning("straggler step %d: %.3fs (mean %.3fs + %g sigma)",
                        step, dt, self.mean, self.k)
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


@dataclass
class TrainLoopResult:
    final_step: int
    losses: list
    resumed_from: Optional[int]
    retries: int
    stragglers: int
    checkpoints: list


def run(train_step: Callable, params, opt_state, data_iter_fn: Callable,
        *, total_steps: int, ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50, max_retries: int = 3,
        shardings=None, watchdog: Optional[StragglerWatchdog] = None,
        fail_injector: Optional[Callable[[int], None]] = None
        ) -> TrainLoopResult:
    """data_iter_fn(step) -> batch (deterministic => restart-safe).
    fail_injector(step) may raise to simulate node failures (tests)."""
    watchdog = watchdog or StragglerWatchdog()
    resumed_from = None
    start = 0
    if ckpt_dir:
        step0, restored = ckpt.restore_latest(
            ckpt_dir, {"params": params, "opt": opt_state}, shardings)
        if step0 is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = step0 + 1
            resumed_from = step0
            log.info("resumed from checkpoint step %d", step0)

    losses: list = []
    saves: list = []
    pending_save = None
    retries = 0
    step = start
    while step < total_steps:
        batch = data_iter_fn(step)
        t0 = time.perf_counter()
        try:
            if fail_injector is not None:
                fail_injector(step)
            new_params, new_opt, metrics = train_step(params, opt_state,
                                                      batch)
            loss = float(metrics["loss"])
        except Exception as e:             # transient failure -> retry
            retries += 1
            log.warning("step %d failed (%s); retry %d/%d", step, e,
                        retries, max_retries)
            if retries > max_retries:
                raise
            continue
        if not np.isfinite(loss):          # loss spike -> skip the update
            log.warning("non-finite loss at step %d; skipping update", step)
            step += 1
            continue
        params, opt_state = new_params, new_opt
        losses.append(loss)
        watchdog.observe(step, time.perf_counter() - t0)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = ckpt.save(ckpt_dir, step,
                                     {"params": params, "opt": opt_state},
                                     blocking=False)
            saves.append(step)
        step += 1
    if pending_save is not None:
        pending_save.join()
    if ckpt_dir and (not saves or saves[-1] != step - 1) and step > start:
        ckpt.save(ckpt_dir, step - 1, {"params": params, "opt": opt_state})
        saves.append(step - 1)
    return TrainLoopResult(final_step=step, losses=losses,
                           resumed_from=resumed_from, retries=retries,
                           stragglers=len(watchdog.flagged),
                           checkpoints=saves)
