"""Step builders: train_step (microbatched grad accumulation + AdamW),
prefill_step and decode_step — the three programs the dry-run lowers and the
train/serve loops execute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models import build, Runtime
from repro.optim.adamw import AdamW, warmup_cosine
from repro.parallel import sharding as shd


def make_runtime(rcfg: RunConfig, *, for_decode: bool = False) -> Runtime:
    gb = rcfg.shape.global_batch
    mb = max(1, gb // max(rcfg.microbatches, 1)) if rcfg.shape.kind == "train" else gb
    excl = ("pod",) if rcfg.ep_over_pod else ()
    act = shd.act_pspec(rcfg.mesh, mb, excl)
    if (rcfg.seq_shard and not for_decode
            and rcfg.shape.seq_len % rcfg.mesh.model_size == 0):
        act = P(act[0], "model", None)  # Megatron-style sequence parallelism
    bspec = shd.batch_spec(rcfg.mesh, mb, excl) or ()
    sizes = dict(zip(rcfg.mesh.axes, rcfg.mesh.shape))
    dp_size = 1
    for a in bspec:
        dp_size *= sizes[a]
    return Runtime(
        attention_backend=rcfg.attention_backend,
        ssm_backend="chunked",
        chunk=rcfg.attention_chunk,
        act_spec=act,
        remat=rcfg.remat,
        mesh_batch_axes=tuple(bspec),
        dp_size=dp_size,
        moe_shardmap=rcfg.model.moe is not None and rcfg.mesh.num_devices > 1,
        ep_axes=("pod", "model") if rcfg.ep_over_pod else ("model",),
        pin_mixer_output=rcfg.pin_mixer_output,
        ssm_factored=rcfg.ssm_factored,
        layers_per_block=rcfg.layers_per_block,
        norm_local=rcfg.norm_local,
        attn_block_q=rcfg.attn_block_q,
        attn_block_k=rcfg.attn_block_k,
        ssm_chunk=rcfg.ssm_chunk,
    )


def make_model(rcfg: RunConfig, *, for_decode: bool = False):
    rt = make_runtime(rcfg, for_decode=for_decode)
    return build(rcfg.model, rt, param_dtype=jnp.dtype(rcfg.param_dtype))


def make_optimizer(rcfg: RunConfig, total_steps: int = 10000) -> AdamW:
    return AdamW(lr_fn=warmup_cosine(rcfg.learning_rate, rcfg.warmup_steps,
                                     total_steps),
                 weight_decay=rcfg.weight_decay, grad_clip=rcfg.grad_clip,
                 state_dtype=rcfg.opt_state_dtype,
                 use_master=rcfg.opt_master)


def build_train_step(rcfg: RunConfig, total_steps: int = 10000):
    """Returns (train_step, model, optimizer). train_step signature:
    (params, opt_state, batch) -> (params, opt_state, metrics).

    The batch leaves carry the full global batch; gradient accumulation
    splits it into `rcfg.microbatches` scanned microbatches, resharding each
    onto the data axes.
    """
    model = make_model(rcfg)
    opt = make_optimizer(rcfg, total_steps)
    n_mb = max(1, rcfg.microbatches)
    mesh_cfg = rcfg.mesh
    gb = rcfg.shape.global_batch
    mb_size = gb // n_mb
    mb_spec = shd.batch_spec(mesh_cfg, mb_size)

    def reshape_mb(x):
        x = x.reshape(n_mb, mb_size, *x.shape[1:])
        return shd.maybe_constrain(
            x, P(None, mb_spec, *([None] * (x.ndim - 2))))

    def train_step(params, opt_state, batch):
        mbs = jax.tree.map(reshape_mb, batch)

        acc_dt = jnp.dtype(rcfg.grad_accum_dtype)

        def mb_body(gsum, mb):
            (loss, aux), g = jax.value_and_grad(model.loss, has_aux=True)(
                params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(acc_dt), gsum, g)
            return gsum, loss

        gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        if n_mb > 1:
            grads, losses = jax.lax.scan(mb_body, gzero, mbs)
            loss = losses.mean()
        else:
            grads, loss = mb_body(gzero, jax.tree.map(lambda x: x[0], mbs))
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        new_params, new_opt, metrics = opt.update(grads, opt_state, params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step, model, opt


def build_prefill_step(rcfg: RunConfig):
    model = make_model(rcfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, rcfg.shape.seq_len)

    return prefill_step, model


def build_serve_steps(rcfg: RunConfig):
    """The serving-engine triple: ``(prefill_fn, decode_fn, model)``.

    ``prefill_fn(params, batch, cache_span)`` and
    ``decode_fn(params, caches, token, pos)`` are the *raw* (unjitted)
    model callables — the engines in :mod:`repro.serving` own jit
    (static ``cache_span``, fused sampling, buffer donation). ``decode_fn``
    accepts per-row ``pos`` vectors, which is what slot-based continuous
    batching schedules on. ``model`` (the prefill-side build) provides
    ``init_params`` and ``cache_init`` for the slot pool.
    """
    _, model = build_prefill_step(rcfg)
    _, dmodel = build_decode_step(rcfg)
    return model.prefill, dmodel.decode_step, model


def build_decode_step(rcfg: RunConfig):
    import dataclasses as _dc
    part = (rcfg.decode_attention == "partitioned"
            and rcfg.model.attention_kind == "full"
            and rcfg.shape.seq_len % rcfg.mesh.model_size == 0)
    bspec = shd.batch_spec(rcfg.mesh, rcfg.shape.global_batch) or ()
    rt = _dc.replace(make_runtime(rcfg, for_decode=True),
                     decode_partitioned=part, mesh_batch_axes=tuple(bspec))
    model = build(rcfg.model, rt, param_dtype=jnp.dtype(rcfg.param_dtype))

    def decode_step(params, caches, token, pos):
        return model.decode_step(params, caches, token, pos)

    return decode_step, model
