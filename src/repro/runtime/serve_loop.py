"""Batched serving loop: prefill once, decode tokens with a jitted step.

This is the *lockstep special case* of the request-level schedulers in
:mod:`repro.serving` — one synchronous batch, every row decodes the same
number of tokens. Request-level serving (continuous batching, per-request
TTFT/latency metrics, EOS termination) lives in ``repro.serving``;
``generate`` is kept as the thin throughput-oriented convenience API
(callables + one batch dict in, tokens out — no Request plumbing) and as
the back-compat surface for pre-jitted ``(params, batch)`` prefill
closures.
"""
from __future__ import annotations

import inspect
import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.engine import decode_lockstep


def _accepts_cache_span(prefill: Callable) -> bool:
    """Whether ``prefill`` takes the 3-arg ``(params, batch, cache_span)``
    contract (dispatch by signature — a try/except on TypeError would
    swallow real TypeErrors raised *inside* a 3-arg prefill and run it
    twice). ``jax.jit`` wrappers expose the wrapped signature."""
    try:
        sig = inspect.signature(prefill)
    except (TypeError, ValueError):
        return True              # uninspectable: assume the new contract
    n_pos = 0
    for p in sig.parameters.values():
        if p.kind == inspect.Parameter.VAR_POSITIONAL:
            return True
        if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                      inspect.Parameter.POSITIONAL_OR_KEYWORD):
            n_pos += 1
    return n_pos >= 3


@dataclass
class ServeResult:
    tokens: np.ndarray           # (B, steps)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


def generate(prefill: Callable, decode_step: Callable, params, batch: dict,
             *, prompt_len: int, max_new_tokens: int,
             cache_span: Optional[int] = None,
             greedy: bool = True, seed: int = 0) -> ServeResult:
    """Prefill ``batch`` then decode ``max_new_tokens`` lockstep tokens.

    ``prefill(params, batch, cache_span)`` sizes the decode cache
    (callables with the legacy two-arg ``(params, batch)`` signature —
    e.g. a jitted closure that already baked the span in — still work).
    Sampling (``greedy=False``) applies to *every* token including the
    first, and tokens accumulate on device with a single host transfer
    after the loop, so decode dispatch is never serialized on a per-token
    ``np.asarray`` sync.
    """
    span = cache_span or (prompt_len + max_new_tokens)
    t0 = time.perf_counter()
    if _accepts_cache_span(prefill):
        logits, caches = prefill(params, batch, span)
    else:                        # legacy prefill(params, batch) closure
        logits, caches = prefill(params, batch)
    logits = jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0
    B = logits.shape[0]
    key = jax.random.PRNGKey(seed)
    if greedy:
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    else:                        # the first token is sampled like the rest
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits[:, -1:]).astype(jnp.int32)
    t0 = time.perf_counter()
    toks, caches, _ = decode_lockstep(
        decode_step, params, caches, tok, start_pos=prompt_len,
        steps=max_new_tokens - 1, greedy=greedy, key=key)
    decode_s = time.perf_counter() - t0
    return ServeResult(tokens=toks, prefill_s=prefill_s, decode_s=decode_s,
                       tokens_per_s=B * max_new_tokens / max(
                           prefill_s + decode_s, 1e-9))
