"""Batched serving loop: prefill once, decode tokens with a jitted step.

Serves synchronous batches (the paper's Tier-2 deployment axis is batch
size, so the loop exposes it directly); returns tokens + tokens/s.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class ServeResult:
    tokens: np.ndarray           # (B, steps)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


def generate(prefill: Callable, decode_step: Callable, params, batch: dict,
             *, prompt_len: int, max_new_tokens: int,
             cache_span: Optional[int] = None,
             greedy: bool = True, seed: int = 0) -> ServeResult:
    span = cache_span or (prompt_len + max_new_tokens)
    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    logits = jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0
    B = logits.shape[0]
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    for i in range(max_new_tokens - 1):
        logits, caches = decode_step(params, caches, tok,
                                     jnp.int32(prompt_len + i))
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    toks = np.concatenate(out, axis=1)
    return ServeResult(tokens=toks, prefill_s=prefill_s, decode_s=decode_s,
                       tokens_per_s=B * max_new_tokens / max(
                           prefill_s + decode_s, 1e-9))
