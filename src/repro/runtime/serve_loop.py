"""Batched serving loop: prefill once, decode tokens with a jitted step.

This is the *lockstep special case* of the request-level schedulers in
:mod:`repro.serving` — one synchronous batch, every row decodes the same
number of tokens. Request-level serving (continuous batching, per-request
TTFT/latency metrics, EOS termination) lives in ``repro.serving``;
``generate`` is kept as the thin throughput-oriented convenience API
(callables + one batch dict in, tokens out — no Request plumbing) and as
the back-compat surface for pre-jitted ``(params, batch)`` prefill
closures.
"""
from __future__ import annotations

import inspect
import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.engine import decode_lockstep


def _accepts_cache_span(prefill: Callable) -> bool:
    """Whether ``prefill`` takes the 3-arg ``(params, batch, cache_span)``
    contract (dispatch by signature — a try/except on TypeError would
    swallow real TypeErrors raised *inside* a 3-arg prefill and run it
    twice). ``jax.jit`` wrappers expose the wrapped signature."""
    try:
        sig = inspect.signature(prefill)
    except (TypeError, ValueError):
        return True              # uninspectable: assume the new contract
    n_pos = 0
    for p in sig.parameters.values():
        if p.kind == inspect.Parameter.VAR_POSITIONAL:
            return True
        if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                      inspect.Parameter.POSITIONAL_OR_KEYWORD):
            n_pos += 1
    return n_pos >= 3


@dataclass
class ServeResult:
    tokens: np.ndarray           # (B, max_new_tokens)
    prefill_s: float
    decode_s: float
    tokens_per_s: float
    # tokens each row actually generated: max_new_tokens, or less when
    # eos_id terminated the row early (trailing tokens are dead weight
    # the lockstep batch still decoded — and still paid for in time, but
    # they are NOT counted as throughput)
    new_tokens: Optional[np.ndarray] = None

    @property
    def total_new_tokens(self) -> int:
        if self.new_tokens is None:
            return int(np.prod(self.tokens.shape))
        return int(self.new_tokens.sum())


def generate(prefill: Callable, decode_step: Callable, params, batch: dict,
             *, prompt_len: int, max_new_tokens: int,
             cache_span: Optional[int] = None, eos_id: Optional[int] = None,
             greedy: bool = True, seed: int = 0) -> ServeResult:
    """Prefill ``batch`` then decode ``max_new_tokens`` lockstep tokens.

    ``prefill(params, batch, cache_span)`` sizes the decode cache
    (callables with the legacy two-arg ``(params, batch)`` signature —
    e.g. a jitted closure that already baked the span in — still work).
    Sampling (``greedy=False``) applies to *every* token including the
    first, and tokens accumulate on device with a single host transfer
    after the loop, so decode dispatch is never serialized on a per-token
    ``np.asarray`` sync.

    ``max_new_tokens`` must be >= 1. At exactly 1 the first (prefill-
    sampled) token is the whole output: no decode step runs and
    ``decode_s`` is 0 rather than the timing of an empty loop.
    ``tokens_per_s`` counts tokens actually generated — rows that hit
    ``eos_id`` early contribute only their live prefix, not the full
    ``max_new_tokens`` they idled through.
    """
    if max_new_tokens < 1:
        raise ValueError(
            f"max_new_tokens must be >= 1, got {max_new_tokens}")
    # this lockstep convenience API *measures* wall time by design — it
    # never runs under a SimClock, hence the RS104 pragmas below
    span = cache_span or (prompt_len + max_new_tokens)
    t0 = time.perf_counter()  # repro: allow=RS104
    if _accepts_cache_span(prefill):
        logits, caches = prefill(params, batch, span)
    else:                        # legacy prefill(params, batch) closure
        logits, caches = prefill(params, batch)
    logits = jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0  # repro: allow=RS104
    key = jax.random.PRNGKey(seed)
    if greedy:
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    else:                        # the first token is sampled like the rest
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits[:, -1:]).astype(jnp.int32)
    if max_new_tokens == 1:      # no decode phase: prefill made the token
        toks, decode_s = np.asarray(jax.block_until_ready(tok)), 0.0
    else:
        t0 = time.perf_counter()  # repro: allow=RS104
        toks, caches, _ = decode_lockstep(
            decode_step, params, caches, tok, start_pos=prompt_len,
            steps=max_new_tokens - 1, greedy=greedy, key=key)
        decode_s = time.perf_counter() - t0  # repro: allow=RS104
    new_tokens = np.full(toks.shape[0], max_new_tokens, np.int64)
    if eos_id is not None:
        hit = toks == eos_id
        new_tokens = np.where(hit.any(axis=1),
                              hit.argmax(axis=1) + 1, new_tokens)
    return ServeResult(tokens=toks, prefill_s=prefill_s, decode_s=decode_s,
                       tokens_per_s=int(new_tokens.sum()) / max(
                           prefill_s + decode_s, 1e-9),
                       new_tokens=new_tokens)
