"""Elastic re-meshing: survive a change in healthy device count.

Protocol (what a 1000-node fleet controller would drive):
1. detect device-count change (node died / capacity returned);
2. pick the best mesh for the new count (`choose_mesh`);
3. rebuild shardings for the new mesh and restore the last committed
   checkpoint against them (`checkpoint.restore` re-lays-out every leaf);
4. resume the deterministic data stream at the restored step.

The cross-mesh portability comes from checkpoints storing full logical
arrays — restore time re-shards, so 8->4 or 4->8 device transitions are a
pure data-placement change. Exercised end-to-end in tests/test_runtime.py.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax

from repro.configs.base import MeshConfig


def choose_mesh(n_devices: int, *, prefer_model: int = 0) -> MeshConfig:
    """Best (data, model) split for a device count: keep `model` a power of
    two no larger than prefer_model (or sqrt n), rest data-parallel."""
    if n_devices == 1:
        return MeshConfig(shape=(1, 1), axes=("data", "model"))
    model = prefer_model or 2 ** int(math.log2(max(1, int(n_devices ** 0.5))))
    while n_devices % model:
        model //= 2
    return MeshConfig(shape=(n_devices // model, model),
                      axes=("data", "model"))


def remesh(ckpt_dir: str, step_tree_template, new_mesh_cfg: MeshConfig,
           pspecs) -> Tuple[object, dict]:
    """Build the new mesh and restore the latest checkpoint resharded onto
    it. Returns (mesh, restored_tree)."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.checkpoint import checkpoint as ckpt
    from repro.launch.mesh import make_mesh

    mesh = make_mesh(new_mesh_cfg)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    step, tree = ckpt.restore_latest(ckpt_dir, step_tree_template, shardings)
    return mesh, {"step": step, "tree": tree}
