"""Per-(arch x shape) run configuration: execution mode, microbatch count
and attention chunking chosen so every cell fits v5e HBM on the production
mesh. These are the BASELINE settings the dry-run lowers; §Perf hillclimbs
override them explicitly.
"""
from __future__ import annotations

from repro.configs import ARCHS, SHAPES, MeshConfig, RunConfig, get_arch
from repro.launch.mesh import mesh_config


def default_microbatches(arch, shape, mesh_cfg: MeshConfig) -> int:
    if shape.kind != "train":
        return 1
    data = mesh_cfg.data_size
    # one sequence per data shard per microbatch for wide models; more for
    # narrow ones. Must divide the global batch.
    if arch.d_model >= 4096:
        per_shard = 1
    elif arch.d_model >= 2048:
        per_shard = 2
    else:
        per_shard = 4
    mb_size = min(shape.global_batch, per_shard * data)
    n_mb = max(1, shape.global_batch // mb_size)
    while shape.global_batch % n_mb:
        n_mb -= 1
    return n_mb


def cell_run_config(arch_name: str, shape_name: str, *,
                    multi_pod: bool = False,
                    exec_mode: str = "streaming",
                    attention_backend: str = "chunked") -> RunConfig:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = mesh_config(multi_pod=multi_pod)
    chunk = 2048 if shape.seq_len > 8192 else 1024
    # 400-480B MoE on 16 GB/chip: f32 AdamW state alone is ~22 GB/chip, so
    # these archs train with blockwise-int8 state, no master copy and bf16
    # gradient accumulation (8-bit-Adam-style memory policy).
    big = arch.param_count() > 2e11
    return RunConfig(
        model=arch,
        shape=shape,
        mesh=mesh,
        exec_mode=exec_mode,
        microbatches=default_microbatches(arch, shape, mesh),
        remat=True,
        attention_backend=attention_backend,
        attention_chunk=chunk,
        decode_attention="partitioned",
        opt_state_dtype="int8" if big else "float32",
        opt_master=not big,
        grad_accum_dtype="bfloat16" if big else "float32",
    )


def valid_cell(arch_name: str, shape_name: str) -> bool:
    arch = get_arch(arch_name)
    if shape_name == "long_500k" and not arch.sub_quadratic:
        return False   # noted skip: full-attention archs (DESIGN.md)
    return True


def all_cells():
    for arch_name in ARCHS:
        for shape_name in SHAPES:
            if valid_cell(arch_name, shape_name):
                yield arch_name, shape_name
