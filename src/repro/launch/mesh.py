"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (jax locks the device count on first init).
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    if multi_pod:
        return MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))
    return MeshConfig(shape=(16, 16), axes=("data", "model"))


def make_mesh(mesh_cfg: MeshConfig):
    """Build a jax Mesh for an arbitrary MeshConfig (tests use small ones)."""
    return jax.make_mesh(
        mesh_cfg.shape, mesh_cfg.axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(mesh_cfg.axes))
