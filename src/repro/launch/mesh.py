"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (jax locks the device count on first init).

Also the jax-version compat seam: newer jax spells the ambient-mesh
context ``jax.set_mesh`` and takes ``axis_types`` in ``jax.make_mesh``;
older releases (<= 0.4.x) have neither, but ``Mesh`` itself is a context
manager with the same ambient-mesh effect. Callers use :func:`set_mesh`
and :func:`make_mesh` from this module and never touch ``jax.set_mesh``
directly.
"""
from __future__ import annotations

import os
import re
from typing import Dict, Optional

import jax

from repro.configs.base import MeshConfig

_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def host_device_env(n_devices: int,
                    base_env: Optional[Dict[str, str]] = None
                    ) -> Dict[str, str]:
    """Env dict for a child process simulating an ``n_devices`` host mesh.

    Rewrites only the device-count flag inside ``XLA_FLAGS`` so any other
    flags already present (e.g. set by a CI matrix cell for the parent)
    survive into the child. The parent's own device count is untouched —
    jax locks it on first init, which is why multi-device measurement is
    subprocess-spawned at all (see bench/runner.run_with_devices).
    """
    env = dict(os.environ if base_env is None else base_env)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(_DEVICE_COUNT_FLAG)]
    flags.append(f"{_DEVICE_COUNT_FLAG}={n_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    return env


def simulated_device_count(env: Optional[Dict[str, str]] = None
                           ) -> Optional[int]:
    """The host-platform device count forced via ``XLA_FLAGS``, if any.
    Reads the env (not jax) so it works before jax initializes."""
    flags = (os.environ if env is None else env).get("XLA_FLAGS", "")
    m = re.search(re.escape(_DEVICE_COUNT_FLAG) + r"=(\d+)", flags)
    return int(m.group(1)) if m else None


def _mk(shape, axes):
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):  # jax < 0.5: no axis_types
        return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax < 0.5: Mesh is its own context manager


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    if multi_pod:
        return MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))
    return MeshConfig(shape=(16, 16), axes=("data", "model"))


def make_mesh(mesh_cfg: MeshConfig):
    """Build a jax Mesh for an arbitrary MeshConfig (tests use small ones)."""
    return _mk(mesh_cfg.shape, mesh_cfg.axes)
