import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) needs 512 placeholder host devices so
# jax.make_mesh can build the production (2,16,16)/(16,16) meshes.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import RunConfig, get_arch, SHAPES  # noqa: E402
from repro.core.hlo_analysis import analyze_hlo        # noqa: E402
from repro.core.roofline import (                      # noqa: E402
    model_flops_decode, model_flops_prefill, model_flops_train, roofline)
from repro.launch.cells import all_cells, cell_run_config  # noqa: E402
from repro.launch.mesh import make_production_mesh, set_mesh  # noqa: E402
from repro.models.frontends import (                   # noqa: E402
    prefill_batch_spec, train_batch_spec)
from repro.optim.adamw import AdamWState               # noqa: E402
from repro.parallel import sharding as shd             # noqa: E402
from repro.runtime.steps import (                      # noqa: E402
    build_decode_step, build_prefill_step, build_train_step, make_model,
    make_optimizer)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DT_BYTES = {"f32": 4, "bf16": 2, "s8": 1, "s32": 4, "pred": 1}


def _cpu_f32_duplicates(text: str, min_bytes: float = 2.56e8) -> float:
    """Total bytes of distinct large f32 shapes that also exist at a narrow
    dtype (bf16/s8) — XLA:CPU float-normalization duplicates that a TPU
    compilation would not materialize. Heuristic: counts each shape once."""
    import re as _re
    shapes: dict = {}
    for m in _re.finditer(r"= ([a-z0-9]+)\[([0-9,]+)\]", text):
        dt, dims = m.groups()
        if dt not in ("f32", "bf16", "s8"):
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        shapes.setdefault(dims, set()).add(dt)
    total = 0.0
    for dims, dts in shapes.items():
        if "f32" not in dts or not ({"bf16", "s8"} & dts):
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return total


def _named(mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def input_specs(rcfg: RunConfig, mesh):
    """ShapeDtypeStruct stand-ins + shardings for every model input of the
    cell's step function. Returns (args, in_shardings, out_shardings,
    donate_argnums, step_fn)."""
    arch, shape = rcfg.model, rcfg.shape
    key = jax.random.PRNGKey(0)
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(rcfg.mesh, B)

    def batch_shardings(spec_dict):
        out = {}
        for name, (shp, _) in spec_dict.items():
            out[name] = NamedSharding(
                mesh, P(bspec, *([None] * (len(shp) - 1))))
        return out

    def batch_structs(spec_dict):
        return {k: jax.ShapeDtypeStruct(shp, dt)
                for k, (shp, dt) in spec_dict.items()}

    if shape.kind == "train":
        step, model, opt = build_train_step(rcfg)
        params_shape = jax.eval_shape(model.init_params, key)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        pspecs = shd.param_pspecs(params_shape, arch, rcfg)
        param_sh = _named(mesh, pspecs)
        opt_sh = shd.opt_state_shardings(opt_shape, pspecs, mesh, rcfg.mesh)
        bspec_dict = train_batch_spec(arch, B, S)
        args = (params_shape, opt_shape, batch_structs(bspec_dict))
        in_sh = (param_sh, opt_sh, batch_shardings(bspec_dict))
        out_sh = (param_sh, opt_sh, None)
        return args, in_sh, out_sh, (0, 1), step

    if shape.kind == "prefill":
        step, model = build_prefill_step(rcfg)
        params_shape = jax.eval_shape(model.init_params, key)
        pspecs = shd.param_pspecs(params_shape, arch, rcfg)
        param_sh = _named(mesh, pspecs)
        bspec_dict = prefill_batch_spec(arch, B, S)
        caches_shape = jax.eval_shape(
            lambda: model.cache_init(B, S, enc_len=S))
        cache_sh = _named(mesh, shd.cache_pspecs(caches_shape, arch, rcfg, B))
        args = (params_shape, batch_structs(bspec_dict))
        in_sh = (param_sh, batch_shardings(bspec_dict))
        out_sh = (None, cache_sh)
        return args, in_sh, out_sh, (), step

    # decode
    step, model = build_decode_step(rcfg)
    params_shape = jax.eval_shape(model.init_params, key)
    pspecs = shd.param_pspecs(params_shape, arch, rcfg)
    param_sh = _named(mesh, pspecs)
    caches_shape = jax.eval_shape(lambda: model.cache_init(B, S, enc_len=S))
    cache_sh = _named(mesh, shd.cache_pspecs(caches_shape, arch, rcfg, B))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params_shape, caches_shape, token, pos)
    in_sh = (param_sh, cache_sh, NamedSharding(mesh, P(bspec, None)),
             NamedSharding(mesh, P()))
    out_sh = (None, cache_sh)
    return args, in_sh, out_sh, (1,), step


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             save_hlo: bool = False, rcfg: RunConfig = None,
             tag: str = "") -> dict:
    if rcfg is not None and rcfg.mesh != (
            cell_run_config(arch_name, shape_name,
                            multi_pod=multi_pod).mesh):
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(rcfg.mesh)      # §Perf mesh-split exploration
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rcfg = rcfg or cell_run_config(arch_name, shape_name,
                                   multi_pod=multi_pod)
    arch, shape = rcfg.model, rcfg.shape
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(map(str, rcfg.mesh.shape)),
        "devices": rcfg.mesh.num_devices,
        "exec_mode": rcfg.exec_mode, "microbatches": rcfg.microbatches,
        "multi_pod": multi_pod,
    }
    t0 = time.time()
    with set_mesh(mesh):
        args, in_sh, out_sh, donate, step = input_specs(rcfg, mesh)
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)
        ma = compiled.memory_analysis()
        print(ma)
        ca = compiled.cost_analysis()
        print({k: v for k, v in (ca or {}).items()
               if k in ("flops", "bytes accessed")})
    text = compiled.as_text()
    if ma is not None:
        rec["memory"] = {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "alias_gb": ma.alias_size_in_bytes / 1e9,
            "peak_gb": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                        + ma.output_size_in_bytes
                        - ma.alias_size_in_bytes) / 1e9,
        }
        # XLA:CPU's float-normalization materializes full f32 duplicates of
        # bf16/int8 buffers (TPU computes bf16 natively and tiles the int8
        # optimizer decode). Discount one instance of each distinct >=256MB
        # f32 shape that has a narrow twin; report both raw and adjusted.
        rec["memory"]["cpu_f32_dup_gb"] = _cpu_f32_duplicates(text) / 1e9
        rec["memory"]["tpu_adjusted_peak_gb"] = (
            rec["memory"]["peak_gb"] - rec["memory"]["cpu_f32_dup_gb"])
    if ca:
        rec["xla_cost"] = {"flops_once_through": ca.get("flops", 0.0),
                           "bytes_once_through": ca.get("bytes accessed", 0.0)}
    report = analyze_hlo(text)
    n_act = arch.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        mf = model_flops_train(n_act, tokens)
    elif shape.kind == "prefill":
        mf = model_flops_prefill(n_act, tokens)
    else:
        mf = model_flops_decode(n_act, shape.global_batch)
    rl = roofline(report, chips=rcfg.mesh.num_devices, model_flops=mf)
    rec["roofline"] = rl.to_dict()
    rec["hlo"] = {
        "flops_per_device": report.flops,
        "dot_flops_per_device": report.dot_flops,
        "bytes_per_device": report.bytes,
        "collective_bytes": report.collective_bytes,
        "collective_ici_bytes": report.collective_ici_bytes,
        "collective_breakdown": report.collective_summary(),
        "n_collectives": len(report.collectives),
        "warnings": report.warnings[:10],
    }
    if save_hlo:
        hdir = RESULTS_DIR / "hlo"
        hdir.mkdir(parents=True, exist_ok=True)
        (hdir / f"{arch_name}_{shape_name}_{rec['mesh']}{tag}.hlo.txt"
         ).write_text(text)
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{arch_name}_{shape_name}_{rec['mesh']}{tag}.json"
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    failures = []
    for arch_name, shape_name in cells:
        try:
            rec = run_cell(arch_name, shape_name, multi_pod=args.multi_pod,
                           save_hlo=args.save_hlo)
            rl = rec["roofline"]
            print(f"OK {arch_name:28s} {shape_name:12s} mesh={rec['mesh']:8s} "
                  f"compile={rec['compile_s']:6.1f}s "
                  f"peak={rec.get('memory', {}).get('peak_gb', -1):7.2f}GB "
                  f"dominant={rl['dominant']:10s} "
                  f"terms(c/m/n)=({rl['compute_s']:.3e},{rl['memory_s']:.3e},"
                  f"{rl['collective_s']:.3e})s", flush=True)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch_name, shape_name, str(e)[:300]))
            print(f"FAIL {arch_name} {shape_name}: {e}", flush=True)
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells passed "
          f"({'multi-pod' if args.multi_pod else 'single-pod'})")
    for f in failures:
        print("FAILED:", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
