"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]` —
prefill a batch of prompts and decode with the jitted single-token step."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, ShapeConfig, get_arch, reduced
from repro.launch.mesh import make_mesh, set_mesh
from repro.models.frontends import synth_batch
from repro.runtime.elastic import choose_mesh
from repro.runtime.serve_loop import generate
from repro.runtime.steps import build_decode_step, build_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    span = args.prompt_len + args.max_new_tokens
    mesh_cfg = choose_mesh(jax.device_count())
    shape = ShapeConfig("serve", "decode", span, args.batch)
    rcfg = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                     attention_backend="dense", param_dtype="float32",
                     decode_attention="simple")
    mesh = make_mesh(mesh_cfg)
    with set_mesh(mesh):
        prefill_fn, model = build_prefill_step(rcfg)
        decode_fn, dmodel = build_decode_step(rcfg)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = synth_batch(cfg, args.batch, args.prompt_len, kind="prefill")
        jit_prefill = jax.jit(lambda p, b: model.prefill(p, b, span))
        jit_decode = jax.jit(dmodel.decode_step, donate_argnums=(1,))
        res = generate(jit_prefill, jit_decode, params, batch,
                       prompt_len=args.prompt_len,
                       max_new_tokens=args.max_new_tokens, cache_span=span)
    print(f"generated {res.tokens.shape} tokens  "
          f"prefill={res.prefill_s:.3f}s decode={res.decode_s:.3f}s "
          f"throughput={res.tokens_per_s:.1f} tok/s")
    return res


if __name__ == "__main__":
    main()
