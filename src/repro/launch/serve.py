"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]` —
request-level serving with a static (lockstep) or continuous-batching
scheduler over a synthetic Poisson request stream.

    --scheduler continuous --offered-load 32 --num-requests 8

prints per-request TTFT / per-token latency percentiles, goodput, and
slot occupancy (the Tier-2 deployment metrics); `--scheduler static`
runs the same workload through the lockstep baseline for comparison.

For the paged scheduler, `--prefix-cache` turns on the prefix-sharing
radix cache, and `--num-sessions N --turns T` swaps the Poisson request
stream for a multi-turn session-replay workload (each turn arrives with
its accumulated history — the pattern prefix sharing accelerates).

`--scheduler disaggregated` runs the paged model path under separate
prefill and decode worker pools over one shared page pool
(`--prefill-workers N --decode-workers M`); the report gains per-role
utilization, handoff latency percentiles, and decode stall times — the
P/D-disaggregation interference comparison.

SLO / robustness knobs: `--deadline S` gives every request a finish-by
budget (missed = outcome `timed_out`, pages reaped); `--priority-mix
"0:3,5:1"` assigns priorities by weight (higher preempts lower in the
paged engine); `--fault-plan default|plan.json` runs the paged engine
under a deterministic fault-injection schedule (see
:mod:`repro.serving.faults`).
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import RunConfig, ShapeConfig, get_arch, reduced
from repro.data.pipeline import synth_requests, synth_sessions
from repro.launch.mesh import make_mesh, set_mesh
from repro.runtime.elastic import choose_mesh
from repro.runtime.steps import build_serve_steps
from repro.serving import make_engine, resolve_fault_plan


def apply_slo(requests, *, deadline_s: float = 0.0,
              priority_mix: str = "", seed: int = 0):
    """Decorate a workload with SLO fields: a uniform per-request
    deadline (0 = none) and priorities drawn from a weighted mix
    ``"prio:weight,prio:weight"`` (e.g. ``"0:3,5:1"`` = a quarter of
    requests at priority 5). Deterministic in ``seed``; returns the
    same Request objects, mutated in place."""
    if deadline_s > 0:
        for r in requests:
            r.deadline_s = deadline_s
    if priority_mix:
        pairs = [p.split(":") for p in priority_mix.split(",")]
        prios = np.array([int(p) for p, _ in pairs])
        w = np.array([float(x) for _, x in pairs])
        rng = np.random.default_rng(seed)
        draw = rng.choice(len(prios), size=len(requests), p=w / w.sum())
        for r, i in zip(requests, draw):
            r.priority = int(prios[i])
    return requests


def build_engine(arch: str, *, batch: int, prompt_len: int,
                 max_new_tokens: int, scheduler: str = "continuous",
                 use_reduced: bool = True, reduce_kw=None,
                 greedy: bool = True, eos_id=None, seed: int = 0,
                 clock=None, page_size: int = 16, num_pages=None,
                 prefill_chunk_tokens: int = 0,
                 prefix_cache: bool = False, fault_plan=None,
                 reject_invalid: bool = False,
                 prefill_workers: int = 1, decode_workers: int = 1):
    """Build a serving engine for ``arch`` (the launcher's plumbing,
    importable so benchmarks and tests share it). ``reduce_kw`` overrides
    the reduction sizes (layers/d_model/vocab/d_ff — the benchmarks use a
    smaller cell than the CLI default). For ``scheduler="paged"`` the
    engine is wired to the model's paged triple (chunked prefill + the
    block-table decode path) and ``page_size``/``num_pages``/
    ``prefill_chunk_tokens``/``prefix_cache`` apply. Returns
    (engine, cfg)."""
    cfg = get_arch(arch)
    if use_reduced:
        cfg = reduced(cfg, **(reduce_kw or {}))
    span = prompt_len + max_new_tokens
    mesh_cfg = choose_mesh(jax.device_count())
    shape = ShapeConfig("serve", "decode", span, batch)
    rcfg = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                     attention_backend="dense", param_dtype="float32",
                     decode_attention="simple")
    mesh = make_mesh(mesh_cfg)
    with set_mesh(mesh):
        prefill_fn, decode_fn, model = build_serve_steps(rcfg)
        params = model.init_params(jax.random.PRNGKey(seed))
    common = dict(slots=batch, cache_span=span, eos_id=eos_id,
                  greedy=greedy, seed=seed, clock=clock,
                  reject_invalid=reject_invalid)
    if scheduler in ("paged", "disaggregated"):
        paged_kw = dict(page_size=page_size, num_pages=num_pages,
                        prefill_chunk_tokens=prefill_chunk_tokens,
                        prefix_cache=prefix_cache, fault_plan=fault_plan)
        if scheduler == "disaggregated":
            paged_kw.update(prefill_workers=prefill_workers,
                            decode_workers=decode_workers)
        engine = make_engine(
            scheduler, model.prefill_chunk, model.decode_step_paged,
            params, model.paged_cache_init, **paged_kw, **common)
    else:
        engine = make_engine(scheduler, prefill_fn, decode_fn, params,
                             model.cache_init, **common)
    return engine, cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="KV slots (continuous) / batch size (static)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--scheduler",
                    choices=("static", "continuous", "paged",
                             "disaggregated"),
                    default="continuous")
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="prefill worker pool size (disaggregated "
                         "scheduler)")
    ap.add_argument("--decode-workers", type=int, default=1,
                    help="decode worker pool size (disaggregated "
                         "scheduler); must divide --batch")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV tokens per page (paged scheduler)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="total KV pool pages incl. the null page "
                         "(0 = match the monolithic slots*span budget)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill tokens per chunk (0 = one shot)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="prefix-sharing radix cache (paged scheduler); "
                         "disabled, the paged engine's output is "
                         "byte-identical to the cache-free scheduler")
    ap.add_argument("--num-sessions", type=int, default=0,
                    help="multi-turn session-replay workload: number of "
                         "chat sessions (0 = plain Poisson requests)")
    ap.add_argument("--turns", type=int, default=3,
                    help="turns per session (with --num-sessions); each "
                         "turn replays the accumulated history")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--offered-load", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = burst at t=0)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request finish-by budget in seconds from "
                         "arrival (0 = no deadline); missed deadlines "
                         "are reaped with outcome timed_out")
    ap.add_argument("--priority-mix", default="",
                    help="weighted priority classes as 'prio:weight,...' "
                         "e.g. '0:3,5:1'; higher priority preempts lower "
                         "under page pressure (paged scheduler)")
    ap.add_argument("--fault-plan", default="none",
                    help="'none', 'default' (the seeded standard chaos "
                         "mix), or a FaultPlan JSON path; paged/"
                         "disaggregated schedulers only")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="EOS token id for early termination (<0 disables)")
    ap.add_argument("--sample", action="store_true",
                    help="sample tokens instead of greedy argmax")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args(argv)

    # session replay grows each turn's prompt by its history; size the
    # span (and block tables) for the longest final-turn prompt
    session_prompt_len = 32 + args.turns * 16    # synth_sessions defaults
    prompt_len = (session_prompt_len if args.num_sessions
                  else args.prompt_len)
    fault_plan = resolve_fault_plan(args.fault_plan, args.seed)
    if (fault_plan is not None
            and args.scheduler not in ("paged", "disaggregated")):
        ap.error("--fault-plan requires --scheduler paged or disaggregated")
    engine, cfg = build_engine(
        args.arch, batch=args.batch, prompt_len=prompt_len,
        max_new_tokens=args.max_new_tokens, scheduler=args.scheduler,
        use_reduced=args.reduced, greedy=not args.sample,
        eos_id=args.eos_id if args.eos_id >= 0 else None, seed=args.seed,
        page_size=args.page_size, num_pages=args.num_pages or None,
        prefill_chunk_tokens=args.prefill_chunk,
        prefix_cache=args.prefix_cache, fault_plan=fault_plan,
        prefill_workers=args.prefill_workers,
        decode_workers=args.decode_workers)
    if args.num_sessions:
        requests = synth_sessions(cfg, args.num_sessions, args.turns,
                                  max_new_tokens=args.max_new_tokens,
                                  rate_per_s=args.offered_load,
                                  seed=args.seed)
    else:
        requests = synth_requests(cfg, args.num_requests, args.prompt_len,
                                  max_new_tokens=args.max_new_tokens,
                                  rate_per_s=args.offered_load,
                                  seed=args.seed)
    apply_slo(requests, deadline_s=args.deadline,
              priority_mix=args.priority_mix, seed=args.seed)
    engine.warmup(prompt_len)
    report = engine.run(requests)
    s = report.summary()
    print(f"[{s['scheduler']}] {s['completed']}/{len(requests)} requests, "
          f"{s['total_new_tokens']} tokens in {s['makespan_s']:.3f}s  "
          f"goodput={s['goodput_rps']:.2f} req/s "
          f"({s['goodput_tps']:.1f} tok/s)")
    print(f"  ttft p50={s['ttft_p50_s'] * 1e3:.1f}ms "
          f"p95={s['ttft_p95_s'] * 1e3:.1f}ms  "
          f"tok p50={s['tok_p50_s'] * 1e3:.2f}ms "
          f"p95={s['tok_p95_s'] * 1e3:.2f}ms")
    print(f"  decode_steps={s['decode_steps']} prefills={s['prefills']} "
          f"occupancy={s['occupancy']:.2f} "
          f"slot_balance={s['slot_balance']:.2f}")
    if s.get("num_pages"):
        print(f"  pages={s['num_pages']}x{s['page_size']}tok "
              f"page_occ={s['page_occupancy_mean']:.2f} "
              f"(peak {s['page_occupancy_peak']:.2f}) "
              f"frag={s['fragmentation_mean']:.2f} "
              f"peak_concurrency={s['peak_concurrency']}")
    if (args.deadline > 0 or args.priority_mix
            or s.get("faults_injected")):
        print(f"  outcomes: timed_out={s['n_timed_out']} "
              f"preempted={s['n_preempted']} rejected={s['n_rejected']} "
              f"failed={s['n_failed']}  "
              f"preemptions={s['preemption_events']} "
              f"requeues={s['requeues']} retries={s['retries']}")
    if s.get("faults_injected"):
        print(f"  faults: injected={s['faults_injected']} "
              f"recovered={s['fault_recoveries']} "
              f"recovery_steps mean={s['recovery_steps_mean']:.1f} "
              f"max={s['recovery_steps_max']}  "
              f"pages_leaked={s['pages_leaked']}")
    if s.get("prefill_workers"):
        print(f"  roles: prefill_workers={s['prefill_workers']} "
              f"(util {s['prefill_util']:.2f}) "
              f"decode_workers={s['decode_workers']} "
              f"(util {s['decode_util']:.2f})  "
              f"handoffs={s['handoffs']} "
              f"handoff p50={s['handoff_p50_s'] * 1e3:.2f}ms "
              f"p95={s['handoff_p95_s'] * 1e3:.2f}ms  "
              f"queue_depth peak={s['queue_depth_peak']} "
              f"mean={s['queue_depth_mean']:.1f}")
    if s.get("prefix_lookups") is not None:
        print(f"  prefix hit_rate={s['prefix_hit_rate']:.2f} "
              f"({s['prefix_hits']}/{s['prefix_lookups']}) "
              f"saved={s['prefill_tokens_saved']}tok "
              f"shared_peak={s['pages_shared_peak']} "
              f"evictions={s['prefix_evictions']} "
              f"ttft warm_p50={s['ttft_warm_p50_s'] * 1e3:.1f}ms "
              f"cold_p50={s['ttft_cold_p50_s'] * 1e3:.1f}ms")
    return report


if __name__ == "__main__":
    main()
