"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]` —
request-level serving with a static (lockstep) or continuous-batching
scheduler over a synthetic Poisson request stream.

    --scheduler continuous --offered-load 32 --num-requests 8

prints per-request TTFT / per-token latency percentiles, goodput, and
slot occupancy (the Tier-2 deployment metrics); `--scheduler static`
runs the same workload through the lockstep baseline for comparison.

For the paged scheduler, `--prefix-cache` turns on the prefix-sharing
radix cache, and `--num-sessions N --turns T` swaps the Poisson request
stream for a multi-turn session-replay workload (each turn arrives with
its accumulated history — the pattern prefix sharing accelerates).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import RunConfig, ShapeConfig, get_arch, reduced
from repro.data.pipeline import synth_requests, synth_sessions
from repro.launch.mesh import make_mesh, set_mesh
from repro.runtime.elastic import choose_mesh
from repro.runtime.steps import build_serve_steps
from repro.serving import make_engine


def build_engine(arch: str, *, batch: int, prompt_len: int,
                 max_new_tokens: int, scheduler: str = "continuous",
                 use_reduced: bool = True, reduce_kw=None,
                 greedy: bool = True, eos_id=None, seed: int = 0,
                 clock=None, page_size: int = 16, num_pages=None,
                 prefill_chunk_tokens: int = 0,
                 prefix_cache: bool = False):
    """Build a serving engine for ``arch`` (the launcher's plumbing,
    importable so benchmarks and tests share it). ``reduce_kw`` overrides
    the reduction sizes (layers/d_model/vocab/d_ff — the benchmarks use a
    smaller cell than the CLI default). For ``scheduler="paged"`` the
    engine is wired to the model's paged triple (chunked prefill + the
    block-table decode path) and ``page_size``/``num_pages``/
    ``prefill_chunk_tokens``/``prefix_cache`` apply. Returns
    (engine, cfg)."""
    cfg = get_arch(arch)
    if use_reduced:
        cfg = reduced(cfg, **(reduce_kw or {}))
    span = prompt_len + max_new_tokens
    mesh_cfg = choose_mesh(jax.device_count())
    shape = ShapeConfig("serve", "decode", span, batch)
    rcfg = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                     attention_backend="dense", param_dtype="float32",
                     decode_attention="simple")
    mesh = make_mesh(mesh_cfg)
    with set_mesh(mesh):
        prefill_fn, decode_fn, model = build_serve_steps(rcfg)
        params = model.init_params(jax.random.PRNGKey(seed))
    common = dict(slots=batch, cache_span=span, eos_id=eos_id,
                  greedy=greedy, seed=seed, clock=clock)
    if scheduler == "paged":
        engine = make_engine(
            scheduler, model.prefill_chunk, model.decode_step_paged,
            params, model.paged_cache_init, page_size=page_size,
            num_pages=num_pages,
            prefill_chunk_tokens=prefill_chunk_tokens,
            prefix_cache=prefix_cache, **common)
    else:
        engine = make_engine(scheduler, prefill_fn, decode_fn, params,
                             model.cache_init, **common)
    return engine, cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="KV slots (continuous) / batch size (static)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--scheduler", choices=("static", "continuous", "paged"),
                    default="continuous")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV tokens per page (paged scheduler)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="total KV pool pages incl. the null page "
                         "(0 = match the monolithic slots*span budget)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill tokens per chunk (0 = one shot)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="prefix-sharing radix cache (paged scheduler); "
                         "disabled, the paged engine's output is "
                         "byte-identical to the cache-free scheduler")
    ap.add_argument("--num-sessions", type=int, default=0,
                    help="multi-turn session-replay workload: number of "
                         "chat sessions (0 = plain Poisson requests)")
    ap.add_argument("--turns", type=int, default=3,
                    help="turns per session (with --num-sessions); each "
                         "turn replays the accumulated history")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--offered-load", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0 = burst at t=0)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="EOS token id for early termination (<0 disables)")
    ap.add_argument("--sample", action="store_true",
                    help="sample tokens instead of greedy argmax")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args(argv)

    # session replay grows each turn's prompt by its history; size the
    # span (and block tables) for the longest final-turn prompt
    session_prompt_len = 32 + args.turns * 16    # synth_sessions defaults
    prompt_len = (session_prompt_len if args.num_sessions
                  else args.prompt_len)
    engine, cfg = build_engine(
        args.arch, batch=args.batch, prompt_len=prompt_len,
        max_new_tokens=args.max_new_tokens, scheduler=args.scheduler,
        use_reduced=args.reduced, greedy=not args.sample,
        eos_id=args.eos_id if args.eos_id >= 0 else None, seed=args.seed,
        page_size=args.page_size, num_pages=args.num_pages or None,
        prefill_chunk_tokens=args.prefill_chunk,
        prefix_cache=args.prefix_cache)
    if args.num_sessions:
        requests = synth_sessions(cfg, args.num_sessions, args.turns,
                                  max_new_tokens=args.max_new_tokens,
                                  rate_per_s=args.offered_load,
                                  seed=args.seed)
    else:
        requests = synth_requests(cfg, args.num_requests, args.prompt_len,
                                  max_new_tokens=args.max_new_tokens,
                                  rate_per_s=args.offered_load,
                                  seed=args.seed)
    engine.warmup(prompt_len)
    report = engine.run(requests)
    s = report.summary()
    print(f"[{s['scheduler']}] {s['completed']}/{len(requests)} requests, "
          f"{s['total_new_tokens']} tokens in {s['makespan_s']:.3f}s  "
          f"goodput={s['goodput_rps']:.2f} req/s "
          f"({s['goodput_tps']:.1f} tok/s)")
    print(f"  ttft p50={s['ttft_p50_s'] * 1e3:.1f}ms "
          f"p95={s['ttft_p95_s'] * 1e3:.1f}ms  "
          f"tok p50={s['tok_p50_s'] * 1e3:.2f}ms "
          f"p95={s['tok_p95_s'] * 1e3:.2f}ms")
    print(f"  decode_steps={s['decode_steps']} prefills={s['prefills']} "
          f"occupancy={s['occupancy']:.2f} "
          f"slot_balance={s['slot_balance']:.2f}")
    if s.get("num_pages"):
        print(f"  pages={s['num_pages']}x{s['page_size']}tok "
              f"page_occ={s['page_occupancy_mean']:.2f} "
              f"(peak {s['page_occupancy_peak']:.2f}) "
              f"frag={s['fragmentation_mean']:.2f} "
              f"peak_concurrency={s['peak_concurrency']}")
    if s.get("prefix_lookups") is not None:
        print(f"  prefix hit_rate={s['prefix_hit_rate']:.2f} "
              f"({s['prefix_hits']}/{s['prefix_lookups']}) "
              f"saved={s['prefill_tokens_saved']}tok "
              f"shared_peak={s['pages_shared_peak']} "
              f"evictions={s['prefix_evictions']} "
              f"ttft warm_p50={s['ttft_warm_p50_s'] * 1e3:.1f}ms "
              f"cold_p50={s['ttft_cold_p50_s'] * 1e3:.1f}ms")
    return report


if __name__ == "__main__":
    main()
