"""Trace CLI: capture a step trace, replay it, ask what-if questions.

    PYTHONPATH=src python -m repro.launch.trace capture \
        --arch granite-3-8b --split 1x1 --out results/traces/t.json
    PYTHONPATH=src python -m repro.launch.trace replay t.json \
        [--scale-op dot=0.5] [--scale-kind collective=2.0]
    PYTHONPATH=src python -m repro.launch.trace whatif t.json --split 2x4
    PYTHONPATH=src python -m repro.launch.trace advise t.json --devices 8

``capture`` runs the real (reduced) train step on this host at the
requested (data, model) split — spawning a simulated mesh child when
the split needs more devices than the host shows — and writes the trace
JSON (DESIGN.md §3). The other three subcommands never run the model:
they load a trace and work on its DAG, printing one JSON object to
stdout.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _parse_split(text: str):
    try:
        dp, tp = (int(x) for x in text.lower().split("x"))
    except ValueError:
        raise SystemExit(f"bad --split {text!r}: expected DPxTP like 2x4")
    return dp, tp


def _parse_edit(text: str, what: str):
    if "=" not in text:
        raise SystemExit(f"bad --scale-{what} {text!r}: expected NAME=FACTOR")
    name, factor = text.split("=", 1)
    return name, float(factor)


def cmd_capture(args) -> int:
    from repro.trace import capture_matrix_cell, capture_train_trace

    split = _parse_split(args.split)
    n = split[0] * split[1]
    import jax

    if jax.device_count() >= n:
        trace = capture_train_trace(
            args.arch, split=split, batch=args.batch, seq=args.seq,
            iters=args.iters)
    else:
        trace = capture_matrix_cell(
            n, [split], arch=args.arch, batch=args.batch, seq=args.seq,
            iters=args.iters)[0]
    out = Path(args.out)
    trace.save(out)
    print(json.dumps({
        "trace": str(out),
        "name": trace.name,
        "events": len(trace.events),
        "measured_us": round(trace.measured_step_s * 1e6, 1),
        "lanes_us": {k: round(v * 1e6, 1)
                     for k, v in trace.lane_seconds().items()},
    }, indent=2))
    return 0


def cmd_replay(args) -> int:
    from repro.trace import load_trace, replay, scale_kind, scale_op

    trace = load_trace(args.trace)
    edits = []
    for spec in args.scale_op or ():
        edits.append(scale_op(*_parse_edit(spec, "op")))
    for spec in args.scale_kind or ():
        edits.append(scale_kind(*_parse_edit(spec, "kind")))
    res = replay(trace, edits=edits)
    measured_us = trace.measured_step_s * 1e6
    out = {
        "trace": trace.name,
        "predicted_us": round(res.predicted_s * 1e6, 1),
        "measured_us": round(measured_us, 1),
        "dominant": res.dominant_lane,
        "critical_path": res.critical_path,
        "edits": len(edits),
    }
    if measured_us > 0 and not edits:
        out["identity_rel_err"] = round(
            abs(res.predicted_s * 1e6 - measured_us) / measured_us, 6)
    print(json.dumps(out, indent=2))
    return 0


def cmd_whatif(args) -> int:
    from repro.trace import load_trace, predict_split

    trace = load_trace(args.trace)
    split = _parse_split(args.split)
    res = predict_split(trace, split)
    print(json.dumps({
        "trace": trace.name,
        "split": f"{split[0]}x{split[1]}",
        "predicted_us": round(res.predicted_s * 1e6, 1),
        "dominant": res.dominant_lane,
        "lanes_us": {eid: round(t * 1e6, 1)
                     for eid, t in res.finish_s.items()
                     if eid not in ("root", "sink")},
    }, indent=2))
    return 0


def cmd_advise(args) -> int:
    from repro.trace import advise_from_trace, load_trace

    trace = load_trace(args.trace)
    ranked = advise_from_trace(trace, args.devices)
    print(json.dumps({
        "trace": trace.name,
        "devices": args.devices,
        "calibration": {k: round(v, 4) if k.endswith("ratio") else round(v, 1)
                        for k, v in trace.calibration().items()},
        "ranking": [{
            "split": "x".join(map(str, a.mesh.shape)),
            "step_us": round(a.step_s * 1e6, 1),
            "dominant": a.dominant,
            "fits": a.fits,
        } for a in ranked],
    }, indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.trace", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    cap = sub.add_parser("capture", help="capture a train-step trace")
    cap.add_argument("--arch", default="granite-3-8b")
    cap.add_argument("--split", default="1x1", help="DPxTP, e.g. 2x4")
    cap.add_argument("--batch", type=int, default=8)
    cap.add_argument("--seq", type=int, default=64)
    cap.add_argument("--iters", type=int, default=5)
    cap.add_argument("--out", default="results/traces/trace.json")
    cap.set_defaults(fn=cmd_capture)

    rep = sub.add_parser("replay", help="replay a trace, optionally edited")
    rep.add_argument("trace")
    rep.add_argument("--scale-op", action="append", metavar="OP=FACTOR")
    rep.add_argument("--scale-kind", action="append", metavar="KIND=FACTOR")
    rep.set_defaults(fn=cmd_replay)

    wi = sub.add_parser("whatif", help="predict step time at another split")
    wi.add_argument("trace")
    wi.add_argument("--split", required=True, help="DPxTP, e.g. 2x4")
    wi.set_defaults(fn=cmd_whatif)

    adv = sub.add_parser("advise", help="trace-calibrated mesh advisor")
    adv.add_argument("trace")
    adv.add_argument("--devices", type=int, default=8)
    adv.set_defaults(fn=cmd_advise)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
