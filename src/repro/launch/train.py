"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

On this CPU container it runs reduced configs end-to-end (the e2e example
trains a ~100M model for a few hundred steps); on a TPU fleet the same
driver runs the full configs (the mesh adapts to jax.device_count()).
"""
from __future__ import annotations

import argparse
import logging

import jax
from jax.sharding import NamedSharding

from repro.configs import RunConfig, ShapeConfig, get_arch, reduced
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_mesh, set_mesh
from repro.parallel import sharding as shd
from repro.runtime import train_loop
from repro.runtime.steps import build_train_step
from repro.runtime.elastic import choose_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg, layers=args.layers, d_model=args.d_model)
    mesh_cfg = choose_mesh(jax.device_count())
    shape = ShapeConfig("custom", "train", args.seq, args.batch)
    rcfg = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                     microbatches=args.microbatches,
                     attention_backend="dense" if args.seq <= 512 else "chunked",
                     learning_rate=args.lr, param_dtype="float32",
                     warmup_steps=max(10, args.steps // 10))
    mesh = make_mesh(mesh_cfg)
    data = SyntheticLM(cfg, args.batch, args.seq)

    with set_mesh(mesh):
        step_fn, model, opt = build_train_step(rcfg, total_steps=args.steps)
        params = model.init_params(jax.random.PRNGKey(rcfg.seed))
        pspecs = shd.param_pspecs(params, cfg, rcfg)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: not isinstance(x, dict))
        opt_state = opt.init(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        def data_at(step):
            return data.batch_at(step)

        result = train_loop.run(
            jit_step, params, opt_state, data_at,
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every)
    print(f"final_step={result.final_step} "
          f"first_loss={result.losses[0]:.4f} "
          f"last_loss={result.losses[-1]:.4f} "
          f"resumed_from={result.resumed_from} retries={result.retries} "
          f"stragglers={result.stragglers}")
    return result


if __name__ == "__main__":
    main()
