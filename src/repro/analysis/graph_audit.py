"""Jitted hot-path auditor: purity checks on the step-loop graphs.

The serving and training hot loops are only as fast as their jitted
graphs are clean: a stray host callback serializes the device queue, an
f64 leak doubles every bandwidth-bound op, a python-scalar argument
recompiles the step per distinct value, and a collective in a
single-device graph means the partitioner was misconfigured. This
module traces the real step functions — the train step from
:func:`repro.runtime.steps.build_train_step`, the serve-side decode
callable, and the paged engine's jitted helpers (admit / evict / fused
pool step) — and audits them at three levels:

* **jaxpr walk** (:func:`audit_function`) — flags host-callback
  primitives (RG001) and f64/c128 values (RG002), recursing into every
  sub-jaxpr (scan/while/cond bodies, nested pjit calls);
* **steady-state compile counts** (:func:`audit_engine_steady_state`) —
  runs an identical tiny workload through a paged engine twice and
  requires every jitted helper's compile-cache size to stay flat on the
  second pass (RG003: shape/weak-type churn recompiles);
* **optimized-HLO accounting** (:func:`audit_hlo`) — lowers + compiles a
  step and feeds ``compiled.as_text()`` to
  :func:`repro.core.hlo_analysis.analyze_hlo`, flagging collectives on
  single-device graphs (RG004) and infeed/outfeed host transfers
  (RG005).

All audits run on tiny reduced models (the tier-1 test cell) so the
whole pass is seconds, not minutes.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

from .findings import Finding

# primitive names that host-sync a jitted graph when hit in the step loop
_CALLBACK_PRIMS = (
    "debug_callback",
    "pure_callback",
    "io_callback",
    "callback",
    "host_callback",
)
_BAD_DTYPES = ("float64", "complex128")


# ------------------------------------------------------------- jaxpr audit
def _iter_eqns(jaxpr):
    """All equations of a jaxpr, recursing into sub-jaxprs in params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _iter_eqns(sub)


def _sub_jaxprs(val):
    import jax.core as jcore

    closed = getattr(jcore, "ClosedJaxpr", None)
    open_ = getattr(jcore, "Jaxpr", None)
    vals = val if isinstance(val, (tuple, list)) else (val,)
    for v in vals:
        if closed is not None and isinstance(v, closed):
            yield v.jaxpr
        elif open_ is not None and isinstance(v, open_):
            yield v


def audit_jaxpr(name: str, closed_jaxpr, path: str = "<jaxpr>") -> List[Finding]:
    """RG001 (host callbacks) + RG002 (f64/c128) over one traced jaxpr."""
    findings: List[Finding] = []
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    seen_cb: set = set()
    seen_dt: set = set()
    for eqn in _iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if any(marker in prim for marker in _CALLBACK_PRIMS):
            if prim not in seen_cb:
                seen_cb.add(prim)
                findings.append(
                    Finding(
                        "RG001",
                        path,
                        0,
                        f"{name}: host callback primitive `{prim}` inside the "
                        "jitted hot path (serializes the device queue)",
                    )
                )
        for var in tuple(eqn.outvars) + tuple(eqn.invars):
            aval = getattr(var, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in _BAD_DTYPES and (prim, dt) not in seen_dt:
                seen_dt.add((prim, dt))
                findings.append(
                    Finding(
                        "RG002",
                        path,
                        0,
                        f"{name}: {dt} value flows through `{prim}` — double "
                        "the bytes of every op it touches",
                    )
                )
    return findings


def audit_function(
    name: str, fn: Callable, *args, path: str = "<traced>", **kwargs
) -> List[Finding]:
    """Trace ``fn(*args, **kwargs)`` with make_jaxpr and audit it."""
    import jax

    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return audit_jaxpr(name, closed, path=path)


# --------------------------------------------------------------- HLO audit
def audit_hlo_text(
    name: str, text: str, *, expect_single_device: bool = True, path: str = "<hlo>"
) -> List[Finding]:
    """RG004/RG005 over one optimized-HLO dump, using the shared parser
    from :mod:`repro.core.hlo_analysis` for the collective accounting."""
    from repro.core.hlo_analysis import analyze_hlo

    findings: List[Finding] = []
    report = analyze_hlo(text)
    if expect_single_device and report.collectives:
        kinds = sorted({c.opcode for c in report.collectives})
        findings.append(
            Finding(
                "RG004",
                path,
                0,
                f"{name}: single-device step graph emits collectives "
                f"{kinds} ({report.collective_bytes} B) — partitioning is "
                "misconfigured",
            )
        )
    lowered = text.lower()
    for marker in ("infeed", "outfeed"):
        if marker in lowered:
            findings.append(
                Finding(
                    "RG005",
                    path,
                    0,
                    f"{name}: `{marker}` in optimized HLO — host transfer "
                    "inside the compiled step",
                )
            )
    return findings


def audit_hlo(
    name: str,
    fn: Callable,
    *args,
    expect_single_device: bool = True,
    path: str = "<hlo>",
    **kwargs,
) -> List[Finding]:
    """Lower + compile ``fn`` and audit the optimized HLO."""
    import jax

    compiled = jax.jit(fn).lower(*args, **kwargs).compile()
    text = compiled.as_text()
    return audit_hlo_text(
        name, text, expect_single_device=expect_single_device, path=path
    )


# ------------------------------------------------------ steady-state audit
def _cache_size(jitted) -> Optional[int]:
    try:
        return int(jitted._cache_size())
    except Exception:
        return None


def _tiny_engine(scheduler: str = "paged"):
    from repro.launch.serve import build_engine
    from repro.serving.request import SimClock

    return build_engine(
        "granite-3-8b",
        batch=2,
        prompt_len=16,
        max_new_tokens=8,
        scheduler=scheduler,
        reduce_kw=dict(layers=2, d_model=64, vocab=128, d_ff=128),
        clock=SimClock(),
        page_size=8,
        num_pages=32,
    )


def _tiny_requests(cfg, n: int = 3, prompt_len: int = 16, new_tokens: int = 6):
    import numpy as np

    from repro.serving.request import Request

    rng = np.random.default_rng(0)
    return [
        Request(
            rid=i,
            arrival_s=0.0,
            prompt=rng.integers(1, 100, size=prompt_len, dtype=np.int32),
            max_new_tokens=new_tokens,
        )
        for i in range(n)
    ]


def audit_engine_steady_state(
    path: str = "src/repro/serving/paged.py",
) -> List[Finding]:
    """RG003: run the same workload twice through one paged engine; every
    jitted helper's compile cache must stay flat on the second pass."""
    engine, cfg = _tiny_engine()
    engine.run(_tiny_requests(cfg))
    helpers = {
        "_pool_step": getattr(engine, "_pool_step", None),
        "_admit": getattr(engine, "_admit", None),
        "_jit_evict": getattr(engine, "_jit_evict", None),
        "_jit_chunk": getattr(engine, "_jit_chunk", None),
    }
    first = {k: _cache_size(v) for k, v in helpers.items() if v is not None}
    engine.run(_tiny_requests(cfg))
    findings: List[Finding] = []
    for k, v in helpers.items():
        if v is None or first.get(k) is None:
            continue
        second = _cache_size(v)
        if second is not None and second > first[k]:
            findings.append(
                Finding(
                    "RG003",
                    path,
                    0,
                    f"PagedEngine.{k}: compile cache grew {first[k]} -> "
                    f"{second} on an identical second run — python-scalar or "
                    "weak-type churn in the call signature",
                )
            )
    return findings


def check_cache_growth(
    name: str, jitted, calls: Sequence[tuple], path: str = "<jit>"
) -> List[Finding]:
    """Generic RG003 probe: after the first call compiles, every further
    same-shape call must hit the cache. ``calls`` is a list of argument
    tuples considered shape-identical by the caller."""
    findings: List[Finding] = []
    if not calls:
        return findings
    jitted(*calls[0])
    base = _cache_size(jitted)
    for args in calls[1:]:
        jitted(*args)
    final = _cache_size(jitted)
    if base is not None and final is not None and final > base:
        findings.append(
            Finding(
                "RG003",
                path,
                0,
                f"{name}: compile cache grew {base} -> {final} across "
                "shape-identical calls (recompilation hazard)",
            )
        )
    return findings


# ------------------------------------------------------------ repo targets
def audit_train_step() -> List[Finding]:
    """Trace the tier-1 tiny train step and audit jaxpr + optimized HLO."""
    import jax

    from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
    from repro.data.pipeline import SyntheticLM
    from repro.runtime.steps import build_train_step

    cfg = reduced(ARCHS["granite-3-8b"], layers=2, d_model=64, vocab=256, d_ff=128)
    rcfg = RunConfig(
        model=cfg,
        shape=ShapeConfig("t", "train", 32, 2),
        mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
        param_dtype="float32",
        attention_backend="dense",
        learning_rate=1e-3,
        warmup_steps=2,
    )
    step_fn, model, opt = build_train_step(rcfg, total_steps=8)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = SyntheticLM(rcfg.model, rcfg.shape.global_batch, rcfg.shape.seq_len)
    batch = batch.batch_at(0)
    path = "src/repro/runtime/steps.py"
    findings = audit_function(
        "train_step", step_fn, params, opt_state, batch, path=path
    )
    single = jax.device_count() == 1
    findings += audit_hlo(
        "train_step",
        step_fn,
        params,
        opt_state,
        batch,
        expect_single_device=single,
        path=path,
    )
    return findings


def audit_decode_step() -> List[Finding]:
    """Trace the raw serve-side decode callable on the tiny model."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, MeshConfig, RunConfig, ShapeConfig, reduced
    from repro.runtime.steps import build_serve_steps

    cfg = reduced(ARCHS["granite-3-8b"], layers=2, d_model=64, vocab=128, d_ff=128)
    rcfg = RunConfig(
        model=cfg,
        shape=ShapeConfig("serve", "decode", 32, 2),
        mesh=MeshConfig(shape=(1, 1), axes=("data", "model")),
        param_dtype="float32",
        attention_backend="dense",
        decode_attention="simple",
    )
    prefill_fn, decode_fn, model = build_serve_steps(rcfg)
    params = model.init_params(jax.random.PRNGKey(0))
    caches = model.cache_init(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    path = "src/repro/runtime/steps.py"
    findings = audit_function(
        "decode_step", decode_fn, params, caches, tok, pos, path=path
    )
    single = jax.device_count() == 1
    findings += audit_hlo(
        "decode_step",
        decode_fn,
        params,
        caches,
        tok,
        pos,
        expect_single_device=single,
        path=path,
    )
    return findings


def audit_all(include_steady_state: bool = True) -> List[Finding]:
    findings = audit_train_step() + audit_decode_step()
    if include_steady_state:
        findings += audit_engine_steady_state()
    return findings
