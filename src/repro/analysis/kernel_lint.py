"""Static contract checker for the Pallas kernel tile configs.

Each kernel entry point (``flash_attention``, ``rwkv6``, ``rmsnorm``,
``paged_attention``) tiles its operands with BlockSpecs whose legality
depends on the target backend — MXU alignment, VMEM capacity, dtype
support. A bad tile config fails late (Mosaic lowering error on
hardware) or worse, silently (interpret mode happily runs tiles a real
core cannot hold), which invalidates every downstream benchmark number.
This module re-derives each kernel's tiling *plan* — grid, block shapes,
index maps, scratch — from a (dims, config) pair without tracing any
jax, and checks it against the backend capability table
(:func:`repro.kernels.tuning.capabilities`):

* **RK001** every operand dim must be an exact multiple of its block dim
  (after the wrapper's own clamping/padding, which is modeled here);
* **RK002** pipelined blocks (× ``pipeline_buffers``) + scratch (+ the
  kernel's known implicit intermediates) must fit ``vmem_bytes``;
* **RK003** block dims larger than the dtype's minimum (sublane, lane)
  tile must be whole multiples of it;
* **RK004** every index_map must stay in bounds over the full grid
  (sampled exhaustively on small grids, corners + midpoints on large);
* **RK005** operand dtypes must appear in the backend's tile table.

Checked configs: :data:`repro.kernels.tuning.DEFAULTS` against canonical
model shapes, plus every entry in the tuned cache for the backend
(signatures are parsed back into concrete dims). ``check_config`` is the
single-config entry point the tests use to plant illegal tiles.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import tuning

from .findings import Finding

Dims = Dict[str, Any]


@dataclass
class Block:
    """One BlockSpec use: operand array, block shape, and index map."""

    name: str
    array_shape: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    dtype: str = "float32"
    pipelined: bool = True  # charged x pipeline_buffers in VMEM


@dataclass
class Plan:
    """A statically re-derived pallas_call: what the checker validates."""

    kernel: str
    path: str  # display path for findings
    grid: Tuple[int, ...]
    blocks: List[Block] = field(default_factory=list)
    scratch: List[Tuple[str, Tuple[int, ...], str]] = field(default_factory=list)
    # known in-kernel intermediates that live in VMEM but are not
    # declared scratch (e.g. rwkv6's pairwise-decay fallback tensor)
    implicit: List[Tuple[str, Tuple[int, ...], str]] = field(default_factory=list)
    notes: str = ""


# ------------------------------------------------------------ plan builders
def _grid_error(kernel: str, path: str, message: str) -> Plan:
    plan = Plan(kernel=kernel, path=path, grid=())
    plan.notes = message
    return plan


def plan_flash_attention(dims: Dims, config: Dict[str, int]) -> List[Plan]:
    """Forward + both backward pallas_calls for one tile config."""
    B, Sq, Sk = int(dims["B"]), int(dims["Sq"]), int(dims["Sk"])
    Hq, Hkv, D = int(dims["Hq"]), int(dims["Hkv"]), int(dims["D"])
    dt = str(dims.get("dtype", "float32"))
    path = "src/repro/kernels/flash_attention.py"
    if Hkv <= 0 or Hq % Hkv:
        return [
            _grid_error(
                "flash_attention", path, f"Hq={Hq} not divisible by Hkv={Hkv}"
            )
        ]
    g = Hq // Hkv
    bq = min(int(config["block_q"]), Sq)
    bk = min(int(config["block_k"]), Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)

    def qmap(b, h, i, j):
        return (b, h, i, 0)

    def kvmap(b, h, i, j):
        return (b, h // g, j, 0)

    def rowmap(b, h, i, j):
        return (b, h, i)

    def kvmap_t(b, h, j, i):
        return (b, h // g, j, 0)

    def qmap_t(b, h, j, i):
        return (b, h, i, 0)

    def rowmap_t(b, h, j, i):
        return (b, h, i)

    def outk_t(b, h, j, i):
        return (b, h, j, 0)

    q_arr, kv_arr = (B, Hq, Sq, D), (B, Hkv, Sk, D)
    row_arr = (B, Hq, Sq)
    fwd = Plan(
        kernel="flash_attention_fwd",
        path=path,
        grid=(B, Hq, nq, nk),
        blocks=[
            Block("q", q_arr, (1, 1, bq, D), qmap, dt),
            Block("k", kv_arr, (1, 1, bk, D), kvmap, dt),
            Block("v", kv_arr, (1, 1, bk, D), kvmap, dt),
            Block("o", q_arr, (1, 1, bq, D), qmap, dt),
            Block("lse", row_arr, (1, 1, bq), rowmap, "float32"),
        ],
        scratch=[
            ("m", (bq, 1), "float32"),
            ("l", (bq, 1), "float32"),
            ("acc", (bq, D), "float32"),
        ],
        implicit=[
            ("s", (bq, bk), "float32"),
            ("p", (bq, bk), "float32"),
            ("mask", (bq, bk), "float32"),
        ],
    )
    dq = Plan(
        kernel="flash_attention_bwd_dq",
        path=path,
        grid=(B, Hq, nq, nk),
        blocks=[
            Block("q", q_arr, (1, 1, bq, D), qmap, dt),
            Block("k", kv_arr, (1, 1, bk, D), kvmap, dt),
            Block("v", kv_arr, (1, 1, bk, D), kvmap, dt),
            Block("do", q_arr, (1, 1, bq, D), qmap, dt),
            Block("lse", row_arr, (1, 1, bq), rowmap, "float32"),
            Block("delta", row_arr, (1, 1, bq), rowmap, "float32"),
            Block("dq", q_arr, (1, 1, bq, D), qmap, dt),
        ],
        scratch=[("acc", (bq, D), "float32")],
        implicit=[
            ("s", (bq, bk), "float32"),
            ("p", (bq, bk), "float32"),
            ("ds", (bq, bk), "float32"),
        ],
    )
    dkv_arr = (B, Hq, Sk, D)  # per-q-head partials, summed outside
    dkv = Plan(
        kernel="flash_attention_bwd_dkv",
        path=path,
        grid=(B, Hq, nk, nq),
        blocks=[
            Block("q", q_arr, (1, 1, bq, D), qmap_t, dt),
            Block("k", kv_arr, (1, 1, bk, D), kvmap_t, dt),
            Block("v", kv_arr, (1, 1, bk, D), kvmap_t, dt),
            Block("do", q_arr, (1, 1, bq, D), qmap_t, dt),
            Block("lse", row_arr, (1, 1, bq), rowmap_t, "float32"),
            Block("delta", row_arr, (1, 1, bq), rowmap_t, "float32"),
            Block("dk", dkv_arr, (1, 1, bk, D), outk_t, "float32"),
            Block("dv", dkv_arr, (1, 1, bk, D), outk_t, "float32"),
        ],
        scratch=[
            ("dk_acc", (bk, D), "float32"),
            ("dv_acc", (bk, D), "float32"),
        ],
        implicit=[
            ("s", (bq, bk), "float32"),
            ("p", (bq, bk), "float32"),
            ("ds", (bq, bk), "float32"),
        ],
    )
    return [fwd, dq, dkv]


def plan_rwkv6(dims: Dims, config: Dict[str, int]) -> List[Plan]:
    B, T, H = int(dims["B"]), int(dims["T"]), int(dims["H"])
    K, V = int(dims["K"]), int(dims["V"])
    dt = str(dims.get("dtype", "float32"))
    path = "src/repro/kernels/rwkv6.py"
    c = min(int(config["chunk"]), T)
    n = -(-T // c)

    def seqmap(b, h, i):
        return (b, h, i, 0)

    def umap(b, h, i):
        return (h, 0)

    def statemap(b, h, i):
        return (b, h, 0, 0)

    return [
        Plan(
            kernel="wkv6_fwd",
            path=path,
            grid=(B, H, n),
            blocks=[
                Block("q", (B, H, T, K), (1, 1, c, K), seqmap, dt),
                Block("k", (B, H, T, K), (1, 1, c, K), seqmap, dt),
                Block("v", (B, H, T, V), (1, 1, c, V), seqmap, dt),
                Block("ld", (B, H, T, K), (1, 1, c, K), seqmap, dt),
                Block("u", (H, K), (1, K), umap, "float32"),
                Block("o", (B, H, T, V), (1, 1, c, V), seqmap, dt),
                Block("state", (B, H, K, V), (1, 1, K, V), statemap, "float32"),
            ],
            scratch=[("S", (K, V), "float32")],
            # the masked pairwise-decay fallback path materializes (c, c, K)
            # twice (diff and its exp) plus the (c, c) attention matrix
            implicit=[
                ("a", (c, c), "float32"),
                ("diff", (c, c, K), "float32"),
                ("exp_diff", (c, c, K), "float32"),
            ],
        )
    ]


def plan_rmsnorm(dims: Dims, config: Dict[str, int]) -> List[Plan]:
    rows, d = int(dims["rows"]), int(dims["d"])
    dt = str(dims.get("dtype", "float32"))
    path = "src/repro/kernels/rmsnorm.py"
    br = min(int(config["block_rows"]), rows)
    rows_p = -(-rows // br) * br  # the wrapper zero-pads rows
    n = rows_p // br

    def rowmap(i):
        return (i, 0)

    def scalemap(i):
        return (0,)

    return [
        Plan(
            kernel="rmsnorm_fwd",
            path=path,
            grid=(n,),
            blocks=[
                Block("x", (rows_p, d), (br, d), rowmap, dt),
                Block("scale", (d,), (d,), scalemap, dt),
                Block("o", (rows_p, d), (br, d), rowmap, dt),
            ],
            implicit=[
                ("ms", (br, 1), "float32"),
                ("xf32", (br, d), "float32"),
            ],
        )
    ]


def plan_paged_attention(dims: Dims, config: Dict[str, int]) -> List[Plan]:
    B, Hq, Hkv = int(dims["B"]), int(dims["Hq"]), int(dims["Hkv"])
    D, P, ps = int(dims["D"]), int(dims["P"]), int(dims["ps"])
    npag = int(dims["npag"])
    dt = str(dims.get("dtype", "float32"))
    path = "src/repro/kernels/paged_attention.py"
    if Hkv <= 0 or Hq % Hkv:
        return [
            _grid_error(
                "paged_attention", path, f"Hq={Hq} not divisible by Hkv={Hkv}"
            )
        ]
    g = Hq // Hkv
    # the resolver clamps to [1, npag]; model the same so the checker
    # judges the tiling that would actually run
    ppb = max(1, min(int(config["pages_per_block"]), npag))
    nb = -(-npag // ppb)
    # worst-case synthetic block table: every live entry points at the
    # highest physical page, padding at the null page — the same bounds
    # the scalar-prefetch index_map sees at runtime
    btab = np.zeros((B, nb * ppb), dtype=np.int64)
    btab[:, :npag] = P - 1

    def qmap(b, h, j):
        return (b, h, 0, 0)

    def kvmap(p):
        def index_map(b, h, j, p=p):
            return (int(btab[b, j * ppb + p]), 0, h, 0)

        return index_map

    pages_arr = (P, ps, Hkv, D)
    blocks = [Block("q", (B, Hkv, g, D), (1, 1, g, D), qmap, dt)]
    for side in ("k", "v"):
        for p in range(ppb):
            blocks.append(
                Block(f"{side}_pages[{p}]", pages_arr, (1, ps, 1, D), kvmap(p), dt)
            )
    blocks.append(Block("o", (B, Hkv, g, D), (1, 1, g, D), qmap, dt))
    return [
        Plan(
            kernel="paged_attention_fwd",
            path=path,
            grid=(B, Hkv, nb),
            blocks=blocks,
            scratch=[
                ("m", (g, 1), "float32"),
                ("l", (g, 1), "float32"),
                ("acc", (g, D), "float32"),
            ],
            implicit=[("s", (g, ps), "float32"), ("pe", (g, ps), "float32")],
        )
    ]


PLANNERS: Dict[str, Callable[[Dims, Dict[str, int]], List[Plan]]] = {
    "flash_attention_fwd": plan_flash_attention,
    "flash_attention_bwd": plan_flash_attention,
    "wkv6_fwd": plan_rwkv6,
    "rmsnorm_fwd": plan_rmsnorm,
    "paged_attention_fwd": plan_paged_attention,
}

# representative full-model shapes the DEFAULTS must be legal for
CANONICAL_DIMS: Dict[str, List[Dims]] = {
    "flash_attention_fwd": [
        dict(
            B=1,
            Sq=2048,
            Sk=2048,
            Hq=32,
            Hkv=8,
            D=128,
            dtype="float32",
            causal=1,
            window=0,
        ),
        dict(
            B=1,
            Sq=2048,
            Sk=2048,
            Hq=32,
            Hkv=8,
            D=128,
            dtype="bfloat16",
            causal=1,
            window=0,
        ),
    ],
    "wkv6_fwd": [dict(B=1, T=2048, H=32, K=64, V=64, dtype="float32", u=1)],
    "rmsnorm_fwd": [
        dict(rows=8192, d=4096, dtype="float32"),
        dict(rows=8192, d=4096, dtype="bfloat16"),
    ],
    "paged_attention_fwd": [
        dict(B=8, Hq=32, Hkv=8, D=128, P=512, ps=16, npag=128, dtype="float32"),
    ],
}


# --------------------------------------------------------------- the checks
def _ctx(plan: Plan, sig: str) -> str:
    return f"[{plan.kernel} {sig}]" if sig else f"[{plan.kernel}]"


def _check_plan(
    plan: Plan, caps: "tuning.BackendCaps", sig: str = ""
) -> List[Finding]:
    out: List[Finding] = []
    ctx = _ctx(plan, sig)
    if plan.notes and not plan.grid:
        out.append(
            Finding("RK001", plan.path, 0, f"{ctx} unplannable config: {plan.notes}")
        )
        return out

    # RK005: dtype support
    for blk in plan.blocks:
        if not caps.supports(blk.dtype):
            out.append(
                Finding(
                    "RK005",
                    plan.path,
                    0,
                    f"{ctx} operand {blk.name} dtype {blk.dtype} not in "
                    f"backend '{caps.name}' tile table",
                )
            )

    # RK001: block shapes must tile the operand exactly
    for blk in plan.blocks:
        if len(blk.block_shape) != len(blk.array_shape):
            out.append(
                Finding(
                    "RK001",
                    plan.path,
                    0,
                    f"{ctx} {blk.name} block rank {len(blk.block_shape)} != "
                    f"operand rank {len(blk.array_shape)}",
                )
            )
            continue
        for ax, (adim, bdim) in enumerate(zip(blk.array_shape, blk.block_shape)):
            if bdim <= 0 or adim % bdim:
                out.append(
                    Finding(
                        "RK001",
                        plan.path,
                        0,
                        f"{ctx} {blk.name} axis {ax}: block {bdim} does not "
                        f"tile operand dim {adim}",
                    )
                )

    # RK003: MXU/min-tile alignment on the last two block dims
    for blk in plan.blocks:
        shape = blk.block_shape
        if not shape:
            continue
        lane = caps.lane
        last = shape[-1]
        if last > lane and last % lane:
            out.append(
                Finding(
                    "RK003",
                    plan.path,
                    0,
                    f"{ctx} {blk.name} lane dim {last} exceeds {lane} without "
                    f"being a multiple (backend '{caps.name}')",
                )
            )
        if len(shape) >= 2:
            sub = caps.sublane(blk.dtype)
            second = shape[-2]
            if second > sub and second % sub:
                out.append(
                    Finding(
                        "RK003",
                        plan.path,
                        0,
                        f"{ctx} {blk.name} sublane dim {second} not a multiple "
                        f"of {sub} for {blk.dtype} (backend '{caps.name}')",
                    )
                )

    # RK002: VMEM footprint
    total = 0
    for blk in plan.blocks:
        nbytes = caps.padded_bytes(blk.block_shape, blk.dtype)
        total += nbytes * (caps.pipeline_buffers if blk.pipelined else 1)
    for _, shape, dt in plan.scratch:
        total += caps.padded_bytes(shape, dt)
    for _, shape, dt in plan.implicit:
        total += caps.padded_bytes(shape, dt)
    if total > caps.vmem_bytes:
        out.append(
            Finding(
                "RK002",
                plan.path,
                0,
                f"{ctx} VMEM footprint {total} B exceeds backend "
                f"'{caps.name}' budget {caps.vmem_bytes} B "
                f"({total / caps.vmem_bytes:.1f}x)",
            )
        )

    # RK004: index maps in bounds over the (sampled) grid
    out.extend(_check_index_maps(plan, ctx))
    return out


def _grid_samples(grid: Sequence[int]) -> List[Tuple[int, ...]]:
    """Cartesian product of per-dim samples: exhaustive for small dims,
    {0, 1, mid, last} corners for large ones."""
    axes = []
    for size in grid:
        size = int(size)
        if size <= 0:
            return []
        if size <= 16:
            axes.append(range(size))
        else:
            axes.append(sorted({0, 1, size // 2, size - 1}))
    return list(itertools.product(*axes))


def _check_index_maps(plan: Plan, ctx: str) -> List[Finding]:
    out: List[Finding] = []
    samples = _grid_samples(plan.grid)
    for blk in plan.blocks:
        if len(blk.block_shape) != len(blk.array_shape):
            continue  # already an RK001
        # max legal block index per axis (ceil handles non-covering
        # blocks, already flagged by RK001)
        limits = [
            -(-adim // bdim) if bdim else 0
            for adim, bdim in zip(blk.array_shape, blk.block_shape)
        ]
        for point in samples:
            try:
                idx = blk.index_map(*point)
            except Exception as e:
                out.append(
                    Finding(
                        "RK004",
                        plan.path,
                        0,
                        f"{ctx} {blk.name} index_map raised at grid {point}: "
                        f"{type(e).__name__}: {e}",
                    )
                )
                break
            if len(idx) != len(limits):
                out.append(
                    Finding(
                        "RK004",
                        plan.path,
                        0,
                        f"{ctx} {blk.name} index_map rank {len(idx)} != "
                        f"operand rank {len(limits)}",
                    )
                )
                break
            bad = [
                ax
                for ax, (i, lim) in enumerate(zip(idx, limits))
                if not 0 <= int(i) < max(lim, 1)
            ]
            if bad:
                out.append(
                    Finding(
                        "RK004",
                        plan.path,
                        0,
                        f"{ctx} {blk.name} index_map out of bounds at grid "
                        f"{point}: block index {tuple(int(i) for i in idx)} "
                        f"vs limits {tuple(limits)} (axes {bad})",
                    )
                )
                break
    return out


# ---------------------------------------------------------------- frontends
def check_config(
    kernel: str,
    dims: Dims,
    config: Dict[str, int],
    backend: Optional[str] = None,
    sig: str = "",
) -> List[Finding]:
    """Check one (kernel, dims, tile-config) triple against a backend."""
    caps = tuning.capabilities(backend)
    planner = PLANNERS.get(kernel)
    if planner is None:
        return [Finding("RK001", "src/repro/kernels", 0, f"unknown kernel '{kernel}'")]
    findings: List[Finding] = []
    for plan in planner(dims, config):
        findings.extend(_check_plan(plan, caps, sig or tuning.signature(**dims)))
    return findings


def _sig_dims(kernel: str, sig: str) -> Optional[Dims]:
    """Parse a tuned-cache signature string back into planner dims."""
    dims: Dims = {}
    try:
        for part in sig.split(","):
            key, val = part.split("=", 1)
            dims[key] = val if key == "dtype" else int(val)
    except ValueError:
        return None
    needed = {
        "flash_attention_fwd": {"B", "Sq", "Sk", "Hq", "Hkv", "D"},
        "flash_attention_bwd": {"B", "Sq", "Sk", "Hq", "Hkv", "D"},
        "wkv6_fwd": {"B", "T", "H", "K", "V"},
        "rmsnorm_fwd": {"rows", "d"},
        "paged_attention_fwd": {"B", "Hq", "Hkv", "D", "P", "ps", "npag"},
    }.get(kernel, set())
    return dims if needed <= set(dims) else None


def _auto_config(
    kernel: str, dims: Dims, config: Dict[str, int], backend: Optional[str]
) -> Dict[str, int]:
    """The config the *auto* resolution path would actually run: model
    the resolver-side clamps (rmsnorm's VMEM clamp; the paged ppb clamp
    is already inside the planner) so defaults and tuned entries are
    judged as applied, while explicit configs stay raw."""
    cfg = dict(config)
    if kernel == "rmsnorm_fwd" and "block_rows" in cfg:
        cfg["block_rows"] = tuning.clamp_rmsnorm_rows(
            cfg["block_rows"],
            d=int(dims["d"]),
            dtype=str(dims.get("dtype", "float32")),
            backend=backend,
        )
    return cfg


def check_defaults(backend: Optional[str] = None) -> List[Finding]:
    """Every DEFAULTS entry must be legal for the canonical shapes."""
    findings: List[Finding] = []
    for kernel, shapes in CANONICAL_DIMS.items():
        config = tuning.DEFAULTS[kernel]
        for dims in shapes:
            findings.extend(
                check_config(
                    kernel, dims, _auto_config(kernel, dims, config, backend), backend
                )
            )
    return findings


def check_tuned_cache(backend: Optional[str] = None) -> List[Finding]:
    """Every tuned-cache entry must be legal for its own signature."""
    be = backend or tuning.backend_name()
    path = tuning.cache_path(be)
    display = f"results/tuned/{be}.json"
    findings: List[Finding] = []
    try:
        import json

        data = json.loads(path.read_text())
    except (OSError, ValueError):
        return findings  # no cache for this backend: nothing to do
    for key, entry in (data.get("entries") or {}).items():
        kernel, _, sig = key.partition("|")
        config = dict(tuning.DEFAULTS.get(kernel, {}))
        config.update({k: int(v) for k, v in (entry.get("config") or {}).items()})
        if not config:
            continue
        dims = _sig_dims(kernel, sig)
        if dims is None:
            findings.append(
                Finding(
                    "RK001", display, 0, f"unparseable tuned-cache signature '{key}'"
                )
            )
            continue
        config = _auto_config(kernel, dims, config, be)
        for f in check_config(kernel, dims, config, be, sig=sig):
            findings.append(Finding(f.rule, display, 0, f.message))
    return findings


def check_all(backend: Optional[str] = None) -> List[Finding]:
    return check_defaults(backend) + check_tuned_cache(backend)
