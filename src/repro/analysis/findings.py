"""Finding records shared by every static-analysis layer.

A :class:`Finding` is one machine-readable violation: rule id, rule
name, ``file:line`` location, and a message naming the offender. The CLI
(:mod:`repro.analysis.__main__`) prints findings either human-readable
or as JSON lines (one object per finding), and exits non-zero when any
survive — the same contract as every other gate in ``tools/ci_checks.py``.

Suppression is per-line and explicit: a ``# repro: allow=<RULE>`` pragma
on the offending line (or the line directly above it) silences that rule
there, and ``# repro: allow=*`` silences every rule. Pragmas are for the
rare intentional exception; the catalog in ``benchmarks/README.md``
documents each rule and when suppressing it is legitimate.
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

REPO = Path(__file__).resolve().parents[3]

# rule id -> one-line description; every layer registers its rules here
# so the CLI's --list-rules and the README catalog stay in one place
RULES = {
    # kernel contract checker (repro.analysis.kernel_lint)
    "RK001": "kernel block shape must tile the operand dims exactly",
    "RK002": "kernel blocks + scratch must fit the backend VMEM budget",
    "RK003": "tile dims must align to the backend's minimum tile",
    "RK004": "index_map must stay in bounds over the full grid",
    "RK005": "kernel operand dtype must be supported by the backend",
    # jitted hot-path auditor (repro.analysis.graph_audit)
    "RG001": "no host callbacks inside a jitted hot-path function",
    "RG002": "no f64/c128 values inside a jitted hot-path function",
    "RG003": "steady-state engine steps must not recompile",
    "RG004": "single-device step graphs must not emit collectives",
    "RG005": "step graphs must not host-transfer (infeed/outfeed)",
    # repo-seam AST lint (repro.analysis.seams)
    "RS101": "runtime invariants must raise, not bare-assert",
    "RS102": "page frees only through PagedEngine._release_pages",
    "RS103": "engine admission must route through admission_error "
             "(self._validate or the Scheduler.validate seam)",
    "RS104": "no wall-clock time.* calls in Sim-clock code paths",
    "RS105": "no numpy host ops inside jitted step functions",
}

_PRAGMA_RE = re.compile(r"#\s*repro:\s*allow=([A-Z*][A-Z0-9*]*)")


@dataclass
class Finding:
    """One violation: where it is, which rule, and what it says."""

    rule: str  # e.g. "RS101"
    path: str  # repo-relative (or synthetic) source
    line: int  # 1-indexed; 0 = whole-target finding
    message: str

    @property
    def name(self) -> str:
        return RULES.get(self.rule, "unknown-rule")

    def to_json(self) -> str:
        d = asdict(self)
        d["name"] = self.name
        return json.dumps(d, sort_keys=True)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def relpath(path: Path) -> str:
    """Repo-relative display path (absolute when outside the repo)."""
    try:
        return str(Path(path).resolve().relative_to(REPO))
    except ValueError:
        return str(path)


def suppressed(source_lines: Sequence[str], line: int, rule: str) -> bool:
    """Whether ``rule`` is pragma-silenced at 1-indexed ``line`` (pragma
    on the line itself or the one above)."""
    for ln in (line, line - 1):
        if 1 <= ln <= len(source_lines):
            for m in _PRAGMA_RE.finditer(source_lines[ln - 1]):
                if m.group(1) in (rule, "*"):
                    return True
    return False


def apply_pragmas(
    findings: Iterable[Finding], source_lines: Sequence[str]
) -> List[Finding]:
    """Drop findings whose location carries an allow pragma."""
    return [f for f in findings if not suppressed(source_lines, f.line, f.rule)]


def render(findings: Sequence[Finding], *, as_json: bool = False, out=None) -> None:
    """Print findings (JSONL or human) to ``out`` (default stdout)."""
    import sys

    out = out or sys.stdout
    for f in findings:
        print(f.to_json() if as_json else str(f), file=out)


def summarize(findings: Sequence[Finding]) -> str:
    by_rule: dict = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    parts = " ".join(f"{r}={n}" for r, n in sorted(by_rule.items()))
    return f"{len(findings)} finding(s) [{parts}]" if findings else "clean"


def load_source(path: Path) -> Optional[str]:
    try:
        return Path(path).read_text()
    except (OSError, UnicodeDecodeError):
        return None
