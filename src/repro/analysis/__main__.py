"""CLI for the static-analysis subsystem.

``python -m repro.analysis`` runs all three layers over the repo and
exits 0 when clean, 1 when any finding survives, 2 on an internal error.
``--layer`` selects a subset (``seams`` is pure AST and runs in
milliseconds; ``kernels`` is arithmetic only; ``graphs`` traces the tiny
step functions and takes a few seconds). ``--json`` emits JSON lines —
one finding per line with rule id, file, line, and message — for CI
consumption; ``--list-rules`` prints the catalog.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List

from .findings import RULES, Finding, render, summarize

LAYERS = ("seams", "kernels", "graphs")


def run_layers(
    layers, *, root=None, backend=None, steady_state: bool = True
) -> List[Finding]:
    findings: List[Finding] = []
    if "seams" in layers:
        from . import seams

        findings += seams.scan_tree(Path(root) if root else None)
    if "kernels" in layers:
        from . import kernel_lint

        findings += kernel_lint.check_all(backend)
    if "graphs" in layers:
        from . import graph_audit

        findings += graph_audit.audit_all(include_steady_state=steady_state)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="kernel-tile, hot-path, and seam static analysis",
    )
    ap.add_argument(
        "--layer",
        action="append",
        choices=LAYERS,
        help="run only this layer (repeatable; default: all)",
    )
    ap.add_argument(
        "--root", default=None, help="tree for the seam lint (default: src/repro)"
    )
    ap.add_argument(
        "--backend",
        default=None,
        help="capability table entry for the kernel lint "
        "(default: the executing jax backend)",
    )
    ap.add_argument(
        "--no-steady-state",
        action="store_true",
        help="skip the engine double-run recompile audit (the slowest graph check)",
    )
    ap.add_argument("--json", action="store_true", help="emit findings as JSON lines")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    layers = tuple(args.layer) if args.layer else LAYERS
    try:
        findings = run_layers(
            layers,
            root=args.root,
            backend=args.backend,
            steady_state=not args.no_steady_state,
        )
    except Exception as e:  # internal error, not a finding
        print(f"analysis error: {type(e).__name__}: {e}", file=sys.stderr)
        return 2
    render(findings, as_json=args.json)
    print(
        f"repro.analysis [{','.join(layers)}]: {summarize(findings)}", file=sys.stderr
    )
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
