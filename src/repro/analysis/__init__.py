"""Static-analysis subsystem: kernel tile contracts, jitted hot-path
purity, and repo-seam discipline.

Three layers, one CLI (``python -m repro.analysis``; also reachable as
``tools/ci_checks.py static-analysis``):

* :mod:`repro.analysis.kernel_lint` — Pallas tile-config legality
  against the backend capability table (RK rules);
* :mod:`repro.analysis.graph_audit` — traced step-graph purity:
  callbacks, f64 leaks, recompiles, collectives (RG rules);
* :mod:`repro.analysis.seams` — AST lint for the serving-seam
  conventions (RS rules).

Rule catalog lives in :data:`repro.analysis.findings.RULES` and is
documented in ``benchmarks/README.md``. Suppress a finding with an
inline ``# repro: allow=<RULE>`` pragma.
"""

from .findings import RULES, Finding  # noqa: F401
