"""Repo-seam AST lint: rule-based checks over ``src/repro``.

The serving and kernel layers keep their invariants behind narrow seams
— every page free goes through ``PagedEngine._release_pages``, every
admission decision through ``admission_error``, runtime invariants raise
real exceptions (bare ``assert`` dies under ``python -O``), and
Sim-clock code never reads the wall clock. Until now those were
conventions; this module makes them mechanical.

Rules (catalog in :data:`repro.analysis.findings.RULES`):

* **RS101** — a bare ``assert`` statement anywhere in the scanned tree.
  Runtime invariants must ``raise`` so they survive ``python -O``.
* **RS102** — an attribute call ``*.free(...)`` outside
  ``_release_pages`` / the ``PageAllocator`` class itself. Going around
  the seam breaks leak accounting and chaos parity.
* **RS103** — an ``*Engine`` class whose ``run`` never calls
  ``self._validate(...)`` (directly or via the extracted
  ``Scheduler.validate`` seam), or whose ``admission_error`` override
  never defers to ``super().admission_error(...)``.
* **RS104** — ``time.time/perf_counter/monotonic/sleep`` calls in
  serving-scoped modules outside a ``*Clock`` class. Sim-clock runs
  must stay deterministic.
* **RS105** — ``np.``/``numpy.`` usage inside a function that is passed
  to ``jax.jit`` in the same module: a host round-trip in the hot path.

Findings are pragma-suppressible per line (``# repro: allow=RSxxx``).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence

from .findings import REPO, Finding, apply_pragmas, relpath

_TIME_FUNCS = {"time", "perf_counter", "monotonic", "sleep", "process_time"}
_NUMPY_ALIASES = {"np", "numpy", "onp"}
_JIT_NAMES = ("jax.jit", "jit", "pjit", "jax.pjit")


def _call_name(node: ast.AST) -> Optional[str]:
    """Dotted name of a call target (``a.b.c`` -> "a.b.c") or None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_serving_scoped(path: Path, tree: ast.Module) -> bool:
    """Modules bound by the Sim-clock discipline: anything under
    ``serving/`` plus any module importing the serving request layer."""
    if "serving" in Path(path).parts:
        return True
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.startswith("repro.serving") or mod == "serving.request":
                return True
        elif isinstance(node, ast.Import):
            if any(a.name.startswith("repro.serving") for a in node.names):
                return True
    return False


def _jitted_local_functions(tree: ast.Module) -> set:
    """Names of module-local defs referenced from a ``jax.jit(...)`` call
    anywhere in the module (covers ``jax.jit(fn)``, ``jit(fn, ...)`` and
    ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators)."""
    jitted: set = set()

    def _mark(arg: ast.AST) -> None:
        if isinstance(arg, ast.Name):
            jitted.add(arg.id)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _call_name(node.func)
            if name in _JIT_NAMES:
                for arg in node.args[:1]:
                    _mark(arg)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                dname = _call_name(dec if not isinstance(dec, ast.Call) else dec.func)
                if dname in _JIT_NAMES:
                    jitted.add(node.name)
                elif (
                    isinstance(dec, ast.Call)
                    and dname in ("partial", "functools.partial")
                    and dec.args
                    and _call_name(dec.args[0]) in _JIT_NAMES
                ):
                    jitted.add(node.name)
    return jitted


class _SeamVisitor(ast.NodeVisitor):
    def __init__(self, path: str, serving_scoped: bool, jitted: set):
        self.path = path
        self.serving_scoped = serving_scoped
        self.jitted = jitted
        self.findings: List[Finding] = []
        self._class_stack: List[str] = []
        self._func_stack: List[str] = []

    # -- stack bookkeeping -------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        if node.name.endswith("Engine"):
            self._check_engine(node)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_func(self, node) -> None:
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    # -- RS101: bare assert ------------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        self.findings.append(
            Finding(
                "RS101",
                self.path,
                node.lineno,
                "bare assert guards a runtime invariant; raise an exception "
                "instead (asserts vanish under python -O)",
            )
        )
        self.generic_visit(node)

    # -- RS102 / RS104 / RS105: call-site rules ----------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "free"
            and "_release_pages" not in self._func_stack
            and "PageAllocator" not in self._class_stack
        ):
            self.findings.append(
                Finding(
                    "RS102",
                    self.path,
                    node.lineno,
                    f"direct page free `{name or 'free'}(...)` outside "
                    "PagedEngine._release_pages bypasses leak accounting",
                )
            )
        if (
            self.serving_scoped
            and name is not None
            and name.startswith("time.")
            and name.split(".", 1)[1] in _TIME_FUNCS
            and not any(c.endswith("Clock") for c in self._class_stack)
        ):
            self.findings.append(
                Finding(
                    "RS104",
                    self.path,
                    node.lineno,
                    f"wall-clock call `{name}()` in a Sim-clock code path; "
                    "route timing through the engine's Clock",
                )
            )
        if (
            name is not None
            and name.split(".", 1)[0] in _NUMPY_ALIASES
            and self._func_stack
            and any(f in self.jitted for f in self._func_stack)
        ):
            self.findings.append(
                Finding(
                    "RS105",
                    self.path,
                    node.lineno,
                    f"numpy host op `{name}(...)` inside jitted function "
                    f"`{self._func_stack[-1]}`; use jnp or hoist out of jit",
                )
            )
        self.generic_visit(node)

    # -- RS103: engine admission seam --------------------------------------
    def _check_engine(self, cls: ast.ClassDef) -> None:
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name == "run":
                if (
                    self._body_is_stub(item)
                    or self._calls(item, "self._validate")
                    or self._calls_suffix(item, ".validate")
                ):
                    continue
                self.findings.append(
                    Finding(
                        "RS103",
                        self.path,
                        item.lineno,
                        f"{cls.name}.run never calls self._validate(...) or "
                        "the Scheduler.validate seam; requests enter the "
                        "pool without admission checks",
                    )
                )
            elif item.name == "admission_error" and cls.bases:
                if self._calls(item, "super"):
                    continue
                self.findings.append(
                    Finding(
                        "RS103",
                        self.path,
                        item.lineno,
                        f"{cls.name}.admission_error override never defers to "
                        "super().admission_error(...); base checks are lost",
                    )
                )

    @staticmethod
    def _body_is_stub(fn) -> bool:
        body = [
            n
            for n in fn.body
            if not (isinstance(n, ast.Expr) and isinstance(n.value, ast.Constant))
        ]
        return all(isinstance(n, (ast.Raise, ast.Pass)) for n in body)

    @staticmethod
    def _calls(fn, prefix: str) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _call_name(node.func)
                if name is not None and name.startswith(prefix):
                    return True
        return False

    @staticmethod
    def _calls_suffix(fn, suffix: str) -> bool:
        """Any call whose dotted target ends with ``suffix`` — how the
        role-composed engines reach admission checks through an
        extracted ``Scheduler`` (``sched.validate(requests)``)."""
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _call_name(node.func)
                if name is not None and name.endswith(suffix):
                    return True
        return False


def scan_source(
    source: str, path: str = "<string>", *, serving_scoped: Optional[bool] = None
) -> List[Finding]:
    """Lint one module's source; returns pragma-filtered findings."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("RS101", path, e.lineno or 0, f"unparseable module: {e.msg}")]
    scoped = (
        serving_scoped
        if serving_scoped is not None
        else _is_serving_scoped(Path(path), tree)
    )
    visitor = _SeamVisitor(path, scoped, _jitted_local_functions(tree))
    visitor.visit(tree)
    return apply_pragmas(visitor.findings, source.splitlines())


def scan_file(path: Path) -> List[Finding]:
    try:
        source = Path(path).read_text()
    except (OSError, UnicodeDecodeError):
        return []
    return scan_source(source, relpath(path))


def scan_tree(root: Optional[Path] = None) -> List[Finding]:
    """Lint every ``.py`` file under ``root`` (default ``src/repro``)."""
    root = Path(root) if root is not None else REPO / "src" / "repro"
    findings: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        findings.extend(scan_file(path))
    return findings


def scan_paths(paths: Sequence[Path]) -> List[Finding]:
    findings: List[Finding] = []
    for p in paths:
        p = Path(p)
        findings.extend(scan_tree(p) if p.is_dir() else scan_file(p))
    return findings
