"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    qkv_bias=False,
    norm="rmsnorm",
    activation="swiglu",
    rope="rope",
    rope_theta=5e5,
    moe=MoEConfig(num_experts=128, top_k=1, expert_ff=8192,
                  capacity_factor=2.0),
    frontend="vision_stub",  # early-fusion multimodal: patch embeddings stubbed
    notes="MoE 128 experts top-1; early-fusion frontend stubbed per assignment",
)
