"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only; the vision patch-embedding frontend is a stub — input_specs()
provides precomputed patch embeddings plus 3-component (t,h,w) M-RoPE
position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    norm="rmsnorm",
    activation="swiglu",
    rope="mrope",
    rope_theta=1e6,
    frontend="vision_stub",
    notes="M-RoPE sections (t=16,h=24,w=24) over half head_dim; vision stub",
)
