"""Configuration system for the DABench-LLM reproduction.

ModelConfig describes an architecture (one file per assigned arch in this
package); ShapeConfig describes one of the assigned input-shape cells;
RunConfig binds a model to a shape, a mesh, and execution-policy knobs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                 # d_ff of each expert
    capacity_factor: float = 1.25
    dense_residual_ff: int = 0     # arctic: dense residual MLP alongside MoE
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    kind: str                      # 'rwkv6' | 'ssd' (mamba-2 style, used by hymba)
    head_size: int = 64
    state_size: int = 16           # ssd: N per head; rwkv6 uses head_size x head_size
    chunk_size: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|audio|vlm|hybrid|ssm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads; 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    activation: str = "swiglu"     # swiglu | gelu
    rope: str = "rope"             # rope | mrope | sinusoidal | none
    rope_theta: float = 1e6
    attention_kind: str = "full"   # full | sliding | none
    window: int = 0                # sliding-window size (tokens)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder_layers: int = 0        # >0 -> encoder-decoder (whisper)
    frontend: str = "none"         # none | audio_stub | vision_stub
    tie_embeddings: bool = False
    # hymba: fraction of heads that are SSM vs attention happens via ssm!=None
    # and attention_kind == 'sliding'; both branches run in parallel per layer.
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 64

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.attention_kind == "none"

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch can run the 500k-token decode cell."""
        return self.is_attention_free or (
            self.attention_kind == "sliding" and self.window > 0
        )

    def param_count(self) -> int:
        """Analytic total parameter count (used for 6ND model flops)."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        per_layer = 0
        if not self.is_attention_free and self.attention_kind != "none":
            per_layer += d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
            if self.qkv_bias:
                per_layer += (nq + 2 * nkv) * hd
        if self.ssm is not None:
            h = d // self.ssm.head_size
            if self.ssm.kind == "rwkv6":
                # r,k,v,g,w projections + output
                per_layer += 5 * d * d + d * d
            else:  # ssd
                per_layer += d * (2 * d + 2 * h * self.ssm.state_size + h) + d * d
        if self.moe is not None:
            e = self.moe
            per_layer += d * e.num_experts  # router
            mult = 3 if self.activation == "swiglu" else 2
            per_layer += e.num_experts * mult * d * e.expert_ff
            if e.dense_residual_ff:
                per_layer += mult * d * e.dense_residual_ff
        else:
            mult = 3 if self.activation == "swiglu" else 2
            per_layer += mult * d * f
        per_layer += 2 * d  # norms
        total = self.num_layers * per_layer
        if self.encoder_layers:
            # encoder layers: self-attn + mlp; decoder layers add cross-attn
            enc_layer = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
            mult = 3 if self.activation == "swiglu" else 2
            enc_layer += mult * d * f + 2 * d
            total += self.encoder_layers * enc_layer
            # cross attention in each decoder layer
            total += self.num_layers * (d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d + d)
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        mult = 3 if self.activation == "swiglu" else 2
        all_expert = self.num_layers * e.num_experts * mult * self.d_model * e.expert_ff
        active_expert = self.num_layers * e.top_k * mult * self.d_model * e.expert_ff
        return self.param_count() - all_expert + active_expert


@dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """Axes batch shards over (everything named pod/data)."""
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def data_size(self) -> int:
        return int(
            __import__("math").prod(
                s for s, a in zip(self.shape, self.axes) if a in ("pod", "data")
            )
        )

    @property
    def model_size(self) -> int:
        return int(
            __import__("math").prod(
                s for s, a in zip(self.shape, self.axes) if a == "model"
            )
        )


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()
    # execution policy
    exec_mode: str = "resident"    # resident | streaming (ZeRO-3) | pipeline
    pp_stages: int = 1
    microbatches: int = 1          # gradient-accumulation steps (train)
    remat: bool = True
    attention_backend: str = "chunked"  # dense | chunked | pallas
    attention_chunk: int = 1024
    # Pallas tile overrides (backend='pallas' only). None = auto: the
    # kernels resolve tiles from the tuned-config cache written by
    # `python -m benchmarks.run --tune` (see repro.kernels.tuning).
    attn_block_q: Optional[int] = None
    attn_block_k: Optional[int] = None
    ssm_chunk: Optional[int] = None
    decode_attention: str = "partitioned"  # simple | partitioned (lse-combine)
    # §Perf opt-in flags (baseline keeps all False; see EXPERIMENTS §Perf)
    pin_mixer_output: bool = False   # bf16 TP psum before residual
    ssm_factored: bool = False       # two-level intra-chunk linear attention
    ep_over_pod: bool = False        # shard experts over (pod, model)
    layers_per_block: int = 1        # remat block size (saved stack / k)
    ssm_tp: bool = False             # TP rwkv/ssd projections (reshard wkv)
    norm_local: bool = False         # psum-free device-local norms
    seq_shard: bool = False  # sequence-parallel residual/norm activations
                             # (Megatron-SP): shards (B,S,d) seq over `model`.
                             # Off by default: XLA inserts gather/scatter
                             # thrash around blunt per-layer constraints
                             # (measured 6x collective regression, §Perf).
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    grad_compression: str = "none"  # none | int8
    # optimizer
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    grad_clip: float = 1.0
    opt_state_dtype: str = "float32"   # float32 | bfloat16 | int8 (blockwise)
    opt_master: bool = True            # keep f32 master copy
    grad_accum_dtype: str = "float32"
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 128,
            vocab: int = 512, d_ff: int = 256, experts: int = 4,
            window: int = 64) -> ModelConfig:
    """Shrink a full architecture config to a CPU-smoke-testable size,
    preserving its structural family (GQA ratio, MoE, SSM, enc-dec, ...)."""
    nq = max(1, min(cfg.num_heads, 4)) if cfg.num_heads else 0
    nkv = max(1, min(cfg.num_kv_heads, nq)) if cfg.num_kv_heads else 0
    if nq and nkv:
        while nq % nkv:
            nkv -= 1
    hd = d_model // nq if nq else 32
    kw: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=layers,
        d_model=d_model,
        num_heads=nq,
        num_kv_heads=nkv,
        head_dim=hd,
        d_ff=d_ff,
        vocab_size=vocab,
        window=min(cfg.window, window) if cfg.window else 0,
        encoder_layers=min(cfg.encoder_layers, layers),
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=experts,
            expert_ff=d_ff,
            dense_residual_ff=d_ff if cfg.moe.dense_residual_ff else 0,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, head_size=hd if cfg.ssm.kind == "rwkv6" else 32,
            chunk_size=16)
    return dataclasses.replace(cfg, **kw)
