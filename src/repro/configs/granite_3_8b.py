"""granite-3-8b [dense] — GQA kv=8. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    qkv_bias=False,
    norm="rmsnorm",
    activation="swiglu",
    rope="rope",
    rope_theta=1e4,
    tie_embeddings=True,
    notes="Granite-3: GQA kv=8, tied embeddings",
)
