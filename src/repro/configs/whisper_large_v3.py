"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]

The assignment specifies the transformer backbone only (32L d_model=1280 20H
d_ff=5120); the conv/mel frontend is a stub — input_specs() provides
precomputed frame embeddings. Whisper-large has 32 encoder + 32 decoder
layers; we honour the enc-dec structure with 32 of each.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    encoder_layers=32,        # encoder layers (bidirectional attention)
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,          # MHA (kv == q heads)
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    qkv_bias=True,
    norm="layernorm",
    activation="gelu",
    rope="sinusoidal",
    frontend="audio_stub",
    tie_embeddings=True,
    notes="Enc-dec; conv frontend stubbed (precomputed frame embeddings)",
)
