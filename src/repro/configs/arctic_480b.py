"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    qkv_bias=False,
    norm="rmsnorm",
    activation="swiglu",
    rope="rope",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=128, top_k=2, expert_ff=4864,
                  capacity_factor=1.25, dense_residual_ff=4864),
    notes="Dense-MoE hybrid: every layer = dense residual MLP + 128e top-2 MoE",
)
