"""rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    norm="layernorm",
    activation="rwkv",            # channel-mix (squared-relu gated)
    rope="none",
    attention_kind="none",
    ssm=SSMConfig(kind="rwkv6", head_size=64, chunk_size=64),
    notes="Attention-free; WKV6 data-dependent per-channel decay; constant-size "
          "recurrent state => long_500k decode runs",
)
