"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import (
    MeshConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    reduced,
)

from repro.configs.qwen2_5_32b import CONFIG as _qwen25_32b
from repro.configs.stablelm_12b import CONFIG as _stablelm_12b
from repro.configs.granite_3_8b import CONFIG as _granite_3_8b
from repro.configs.qwen1_5_110b import CONFIG as _qwen15_110b
from repro.configs.llama4_maverick import CONFIG as _llama4
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.qwen2_vl_72b import CONFIG as _qwen2vl
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.rwkv6_3b import CONFIG as _rwkv6

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _qwen25_32b,
        _stablelm_12b,
        _granite_3_8b,
        _qwen15_110b,
        _llama4,
        _arctic,
        _whisper,
        _qwen2vl,
        _hymba,
        _rwkv6,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells() -> list[tuple[ModelConfig, ShapeConfig]]:
    """All (arch x shape) cells, excluding noted long_500k skips
    (full-attention archs; see DESIGN.md §Shape-matrix skips)."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not arch.sub_quadratic:
                continue
            out.append((arch, shape))
    return out


__all__ = [
    "ARCHS", "SHAPES", "MeshConfig", "ModelConfig", "MoEConfig", "RunConfig",
    "ShapeConfig", "SSMConfig", "cells", "get_arch", "reduced",
]
