"""hymba-1.5b [hybrid] — parallel attn + mamba heads, sliding window.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    qkv_bias=False,
    norm="rmsnorm",
    activation="swiglu",
    rope="rope",
    rope_theta=1e4,
    attention_kind="sliding",
    window=1024,
    ssm=SSMConfig(kind="ssd", head_size=64, state_size=16, chunk_size=64),
    notes="Parallel attention + Mamba(SSD) heads per layer; sliding window "
          "keeps the KV cache O(window) so long_500k runs",
)
