"""repro.trace — trace-driven op-level profiling and DAG replay.

The measure->compare->gate loop applied to *prediction* (DESIGN.md §3):

* :mod:`repro.trace.schema`  — the serializable trace format: a DAG of
  timed :class:`TraceEvent` nodes inside a schema-versioned,
  env-fingerprinted :class:`Trace` (JSON on disk, like ``BenchRecord``);
* :mod:`repro.trace.capture` — recorders: the real train step (own
  timers over the jitted boundary + a per-op breakdown lifted from
  ``core/hlo_analysis`` on the lowered module) and the serving engines'
  prefill/decode dispatches (via :class:`TracingClock`, recorded at the
  clock seam — no engine changes);
* :mod:`repro.trace.replay`  — the critical-path replayer: an
  earliest-start walk over the DAG predicting step time under edits;
* :mod:`repro.trace.whatif`  — edits (scale an op, re-split the mesh)
  and the trace-calibrated ``mesh_advisor`` bridge.

Validated cell-by-cell against the measured DP/TP/PP scaling matrix
(``benchmarks/bench_trace.py``; ``tools/ci_checks.py trace-replay-error``
gates <= 25% relative error per cell in CI).
"""

from repro.trace.capture import (
    TracingClock,
    capture_matrix_cell,
    capture_train_trace,
    dag_from_cost_summary,
    trace_from_cell_payload,
)
from repro.trace.replay import ReplayResult, replay, toposort
from repro.trace.schema import (
    TRACE_SCHEMA_VERSION,
    Trace,
    TraceError,
    TraceEvent,
    load_trace,
)
from repro.trace.whatif import (
    advise_from_trace,
    predict_split,
    scale_kind,
    scale_op,
    set_cost,
)

__all__ = [
    "TRACE_SCHEMA_VERSION",
    "Trace",
    "TraceError",
    "TraceEvent",
    "TracingClock",
    "ReplayResult",
    "advise_from_trace",
    "capture_matrix_cell",
    "capture_train_trace",
    "dag_from_cost_summary",
    "load_trace",
    "predict_split",
    "replay",
    "scale_kind",
    "scale_op",
    "set_cost",
    "toposort",
    "trace_from_cell_payload",
]
